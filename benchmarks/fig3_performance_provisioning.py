"""Paper Fig. 3 + Table 2: performance-provisioned clusters at
10 ms / 100 ms / 1 s SLAs — power breakdown + memory capacity."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_performance)
from repro.core.systems import TiB

WL = Workload(16 * TiB, 0.20)
SLAS = (0.010, 0.100, 1.000)


def rows():
    out = []
    for sla in SLAS:
        for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
            d, us = timed(provision_performance, s, WL, sla)
            out.append((
                f"fig3/sla{int(sla*1e3)}ms/{s.name}", us,
                f"power={d.power/1e3:.1f}kW;capacity={d.memory_capacity/TiB:.0f}TiB;"
                f"overprov={d.overprovision_factor:.1f}x;blades={d.blades};"
                f"chips={d.compute_chips}"))
    # Table 2 cluster bandwidths at 10 ms
    for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
        d, us = timed(provision_performance, s, WL, 0.010)
        out.append((f"table2/10ms/{s.name}", us,
                    f"blades={d.blades};chips={d.compute_chips};"
                    f"bandwidth={d.aggregate_bandwidth/1e12:.0f}TBps"))
    return out
