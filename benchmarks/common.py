"""Benchmark harness plumbing: timing, CSV rows, trajectory files.

Contract: every benchmark module exposes `rows() -> list[tuple]` of
(name, us_per_call, derived) and run.py prints them all as CSV.
"""
from __future__ import annotations

import json
import time


def append_trajectory(path, rec: dict) -> None:
    """Append one run's record to a BENCH_*.json trajectory file (a JSON
    list future PRs diff against to catch regressions)."""
    try:
        hist = json.loads(path.read_text())
        if not isinstance(hist, list):
            hist = []
    except (OSError, ValueError):
        hist = []
    hist.append(rec)
    path.write_text(json.dumps(hist, indent=1))


def obs_digest(engine, tracer=None):
    """The repro.obs.diff digest a trajectory row carries under
    rec["obs"] — the canonical baseline the trace-diff explainer
    (check_regress.py --explain) diffs against. Returns None when the
    repro package is not importable (standalone CSV runs), keeping old
    rows and old invocations loadable — the digest is additive."""
    try:
        from repro.obs.diff import digest
    except ImportError:
        return None
    return digest(engine, tracer)


def timed(fn, *args, repeat: int = 5, **kw):
    """Returns (result, microseconds per call)."""
    fn(*args, **kw)                     # warm (jit/cache)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
