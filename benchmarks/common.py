"""Benchmark harness plumbing: timing + CSV row emission.

Contract: every benchmark module exposes `rows() -> list[tuple]` of
(name, us_per_call, derived) and run.py prints them all as CSV.
"""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 5, **kw):
    """Returns (result, microseconds per call)."""
    fn(*args, **kw)                     # warm (jit/cache)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
