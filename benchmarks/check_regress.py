"""Bench-trajectory regression gate + trace-diff explainer.

Every benchmark module appends one record per run to `BENCH_*.json`, but
until now nothing *read* the trajectory — a silent 10x throughput loss
would sail through CI as long as the newest record was internally sane
(`check_append.py` checks shape, not level). This script closes the loop:

    python benchmarks/check_regress.py            # every known bench
    python benchmarks/check_regress.py tier store # a subset
    python benchmarks/check_regress.py --explain --out bench_diff.json

For each bench it extracts one *headline* metric (higher is better:
GB/s, SLA attainment, hit rate) from every record, takes the median of
the whole trajectory, and fails (exit 1) if the newest record sits more
than `THRESHOLD` (30%) below that median. A missing trajectory file is
skipped with a note — not every CI job runs every bench — but a present
file must parse and yield the metric.

The gate is also an *explainer*: bench records carry a `rec["obs"]`
digest (repro.obs.diff) — per-(shape, category) critical-path seconds
plus snapshot scalars — so when the gate trips, the failure message
names the dominant regressing span category instead of just the level
drop. `--explain` diffs the newest record against the previous one for
every bench and prints the full attribution (optionally writing a JSON
artifact with `--out`), without gating.

The median (not the max) is the baseline on purpose: trajectories mix
machines and modes, and a one-off fast outlier should not permanently
ratchet the gate; a sustained drop still moves the newest record far
below the median of everything that came before it.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

THRESHOLD = 0.30      # fail when newest < (1 - THRESHOLD) * median


def _resilience_headline(rec: dict) -> float:
    """Best recovered-policy attainment at the worst nonzero fault rate —
    the number BENCH_resilience exists to defend."""
    sweep = rec["sweep"]
    rates = [r for r in sweep if float(r) > 0]
    worst = max(rates, key=float) if rates else max(sweep, key=float)
    per = sweep[worst]
    return max(v["attainment"] for k, v in per.items() if k != "norecover")


HEADLINES = {
    # bench -> (label, extractor); every metric is higher-is-better
    "kernels": ("tuned_gbps", lambda r: r["tuned_gbps"]),
    "queries": ("scan_agg_gbps", lambda r: r["scan_agg_gbps"]),
    "tier": ("memcache hit_rate @skew=1.1",
             lambda r: r["policies"]["memcache"]["1.1"]["hit_rate"]),
    "energy": ("capped attainment",
               lambda r: r["replay"]["capped"]["attainment"]),
    "store": ("trace physical_gbps",
              lambda r: r["trace"]["physical_gbps"]),
    "resilience": ("recovered attainment @worst rate",
                   _resilience_headline),
}


def _diff_digests_fn():
    """repro.obs.diff.diff_digests, importable even when this script
    runs without PYTHONPATH=src (the bare CI invocation)."""
    try:
        from repro.obs.diff import diff_digests
    except ImportError:
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.obs.diff import diff_digests
        except ImportError:
            return None
    return diff_digests


def _diff_obs(prev: dict, new: dict):
    """DiffReport between two records' obs digests, or None when either
    side predates the digest (old rows stay loadable) or repro is
    unimportable."""
    if not isinstance(prev.get("obs"), dict) \
            or not isinstance(new.get("obs"), dict):
        return None
    diff_digests = _diff_digests_fn()
    if diff_digests is None:
        return None
    return diff_digests(prev["obs"], new["obs"])


def _load(name: str):
    path = ROOT / f"BENCH_{name}.json"
    if not path.exists():
        return None
    hist = json.loads(path.read_text())
    return hist if isinstance(hist, list) else []


def check_bench(name: str) -> tuple[bool, str]:
    """Returns (ok, message) for one bench trajectory."""
    label, extract = HEADLINES[name]
    hist = _load(name)
    if hist is None:
        return True, f"SKIP (BENCH_{name}.json absent — bench not run here)"
    if not hist:
        return False, f"BENCH_{name}.json holds no records"
    values = [extract(rec) for rec in hist]
    newest = values[-1]
    med = statistics.median(values)
    floor = (1.0 - THRESHOLD) * med
    detail = (f"{label}: newest={newest:.6g} median={med:.6g} "
              f"over {len(values)} record(s), floor={floor:.6g}")
    if med > 0 and newest < floor:
        drop = 1.0 - newest / med
        msg = (f"REGRESSION {detail} — newest is {drop:.0%} below "
               f"the trajectory median (>{THRESHOLD:.0%} gate)")
        # name the culprit: diff the newest digest against the previous
        # record's, and lead with the dominant regressing span category
        rep = _diff_obs(hist[-2], hist[-1]) if len(hist) >= 2 else None
        if rep is not None:
            dom = rep.dominant()
            if dom is not None:
                msg += (f"\n  dominant regressing span category: "
                        f"{dom.key} ({dom.base_s:.6g} -> {dom.new_s:.6g} "
                        f"s/query, {dom.delta_s:+.3g})")
            else:
                msg += ("\n  no span category regressed — the headline "
                        "moved without the modeled ledgers (snapshot "
                        "deltas below)")
            for line in rep.render().splitlines():
                msg += f"\n  | {line}"
        else:
            msg += ("\n  (no obs digest on both records yet — rerun the "
                    "bench twice to enable trace-diff explanations)")
        return False, msg
    return True, f"ok  {detail}"


def explain_bench(name: str) -> tuple[str, dict | None]:
    """Diff the last two records' digests (no gating). Returns
    (message, JSON-safe payload or None)."""
    hist = _load(name)
    if hist is None:
        return f"SKIP (BENCH_{name}.json absent)", None
    if len(hist) < 2:
        return f"SKIP (only {len(hist)} record(s); need 2 to diff)", None
    rep = _diff_obs(hist[-2], hist[-1])
    if rep is None:
        return "SKIP (records predate the obs digest)", None
    dom = rep.dominant()
    payload = {
        "bench": name,
        "exact": rep.exact,
        "dominant": dom.key if dom is not None else None,
        "dominant_delta_s_per_query": dom.delta_s if dom is not None
        else None,
        "delta_total_s_per_query": rep.delta_total_s,
        "rows": [{"key": r.key, "base_s": r.base_s, "new_s": r.new_s,
                  "delta_s": r.delta_s} for r in rep.rows],
        "snapshot_deltas": {k: list(v)
                            for k, v in rep.snapshot_deltas.items()},
    }
    return rep.render(), payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*",
                    help=f"subset of {sorted(HEADLINES)} (default: all)")
    ap.add_argument("--explain", action="store_true",
                    help="diff the last two records per bench instead of "
                         "gating; always exits 0")
    ap.add_argument("--out", default=None,
                    help="with --explain: write the diff payloads as a "
                         "JSON artifact to this path")
    args = ap.parse_args(argv)
    names = args.benches or sorted(HEADLINES)
    unknown = [n for n in names if n not in HEADLINES]
    if unknown:
        raise SystemExit(f"unknown benches {unknown}; known: "
                         f"{sorted(HEADLINES)}")
    if args.explain:
        payloads = []
        for name in names:
            msg, payload = explain_bench(name)
            print(f"BENCH_{name}.json:")
            for line in msg.splitlines():
                print(f"  {line}")
            if payload is not None:
                payloads.append(payload)
        if args.out:
            Path(args.out).write_text(json.dumps(payloads, indent=1))
            print(f"wrote {len(payloads)} diff payload(s) to {args.out}")
        return 0
    failed = False
    for name in names:
        ok, msg = check_bench(name)
        print(f"BENCH_{name}.json: {msg}")
        failed = failed or not ok
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
