"""Bench-trajectory regression gate: newest record vs trajectory median.

Every benchmark module appends one record per run to `BENCH_*.json`, but
until now nothing *read* the trajectory — a silent 10x throughput loss
would sail through CI as long as the newest record was internally sane
(`check_append.py` checks shape, not level). This script closes the loop:

    python benchmarks/check_regress.py            # every known bench
    python benchmarks/check_regress.py tier store # a subset

For each bench it extracts one *headline* metric (higher is better:
GB/s, SLA attainment, hit rate) from every record, takes the median of
the whole trajectory, and fails (exit 1) if the newest record sits more
than `THRESHOLD` (30%) below that median. A missing trajectory file is
skipped with a note — not every CI job runs every bench — but a present
file must parse and yield the metric.

The median (not the max) is the baseline on purpose: trajectories mix
machines and modes, and a one-off fast outlier should not permanently
ratchet the gate; a sustained drop still moves the newest record far
below the median of everything that came before it.
"""
from __future__ import annotations

import json
import statistics
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

THRESHOLD = 0.30      # fail when newest < (1 - THRESHOLD) * median


def _resilience_headline(rec: dict) -> float:
    """Best recovered-policy attainment at the worst nonzero fault rate —
    the number BENCH_resilience exists to defend."""
    sweep = rec["sweep"]
    rates = [r for r in sweep if float(r) > 0]
    worst = max(rates, key=float) if rates else max(sweep, key=float)
    per = sweep[worst]
    return max(v["attainment"] for k, v in per.items() if k != "norecover")


HEADLINES = {
    # bench -> (label, extractor); every metric is higher-is-better
    "kernels": ("tuned_gbps", lambda r: r["tuned_gbps"]),
    "queries": ("scan_agg_gbps", lambda r: r["scan_agg_gbps"]),
    "tier": ("memcache hit_rate @skew=1.1",
             lambda r: r["policies"]["memcache"]["1.1"]["hit_rate"]),
    "energy": ("capped attainment",
               lambda r: r["replay"]["capped"]["attainment"]),
    "store": ("trace physical_gbps",
              lambda r: r["trace"]["physical_gbps"]),
    "resilience": ("recovered attainment @worst rate",
                   _resilience_headline),
}


def check_bench(name: str) -> tuple[bool, str]:
    """Returns (ok, message) for one bench trajectory."""
    label, extract = HEADLINES[name]
    path = ROOT / f"BENCH_{name}.json"
    if not path.exists():
        return True, f"SKIP ({path.name} absent — bench not run here)"
    hist = json.loads(path.read_text())
    if not isinstance(hist, list) or not hist:
        return False, f"{path.name} holds no records"
    values = [extract(rec) for rec in hist]
    newest = values[-1]
    med = statistics.median(values)
    floor = (1.0 - THRESHOLD) * med
    detail = (f"{label}: newest={newest:.6g} median={med:.6g} "
              f"over {len(values)} record(s), floor={floor:.6g}")
    if med > 0 and newest < floor:
        drop = 1.0 - newest / med
        return False, (f"REGRESSION {detail} — newest is {drop:.0%} below "
                       f"the trajectory median (>{THRESHOLD:.0%} gate)")
    return True, f"ok  {detail}"


def main(argv=None) -> int:
    names = (argv if argv is not None else sys.argv[1:]) or sorted(HEADLINES)
    unknown = [n for n in names if n not in HEADLINES]
    if unknown:
        raise SystemExit(f"unknown benches {unknown}; known: "
                         f"{sorted(HEADLINES)}")
    failed = False
    for name in names:
        ok, msg = check_bench(name)
        print(f"BENCH_{name}.json: {msg}")
        failed = failed or not ok
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
