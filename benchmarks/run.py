"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit

MODULES = (
    "benchmarks.fig1_bandwidth_capacity",
    "benchmarks.fig3_performance_provisioning",
    "benchmarks.fig4_power_provisioning",
    "benchmarks.fig5_capacity_provisioning",
    "benchmarks.fig6_energy",
    "benchmarks.crossover",
    "benchmarks.advisor_tpu",
    "benchmarks.kernels_bench",
    "benchmarks.roofline_table",
)


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for modname in MODULES:
        try:
            mod = __import__(modname, fromlist=["rows"])
            emit(mod.rows())
        except Exception:
            failed.append(modname)
            traceback.print_exc(file=sys.stderr)
            print(f"{modname},0.0,ERROR")
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
