"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV rows, or a JSON array with
``--json``. ``--only substr`` restricts to matching module names (CI runs
``--only kernels --json`` as the smoke invocation).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks.common import emit

MODULES = (
    "benchmarks.fig1_bandwidth_capacity",
    "benchmarks.fig3_performance_provisioning",
    "benchmarks.fig4_power_provisioning",
    "benchmarks.fig5_capacity_provisioning",
    "benchmarks.fig6_energy",
    "benchmarks.crossover",
    "benchmarks.advisor_tpu",
    "benchmarks.kernels_bench",
    "benchmarks.queries_bench",
    "benchmarks.tier_bench",
    "benchmarks.energy_bench",
    "benchmarks.store_bench",
    "benchmarks.resilience_bench",
    "benchmarks.roofline_table",
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON array instead of CSV rows")
    ap.add_argument("--only", default="",
                    help="run only modules whose name contains this")
    args = ap.parse_args(argv)

    modules = [m for m in MODULES if args.only in m]
    records = []
    if not args.json:
        print("name,us_per_call,derived")
    failed = []
    for modname in modules:
        try:
            mod = __import__(modname, fromlist=["rows"])
            rows = mod.rows()
        except Exception:
            failed.append(modname)
            traceback.print_exc(file=sys.stderr)
            rows = [(modname, 0.0, "ERROR")]
        if args.json:
            records += [{"name": n, "us_per_call": us, "derived": d}
                        for n, us, d in rows]
        else:
            emit(rows)
    if args.json:
        print(json.dumps(records, indent=1))
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
