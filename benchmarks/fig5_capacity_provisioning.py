"""Paper Fig. 5: capacity-provisioned clusters (160/32/16 TiB, constant
3.2 TiB accessed) — response time + power."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_capacity)
from repro.core.systems import TiB

WL = Workload(16 * TiB, 0.20)
SIZES = (160 * TiB, 32 * TiB, 16 * TiB)


def rows():
    out = []
    for size in SIZES:
        for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
            d, us = timed(provision_capacity, s, WL, capacity=size)
            out.append((
                f"fig5/{int(size/TiB)}TiB/{s.name}", us,
                f"rt={d.response_time*1e3:.1f}ms;power={d.power/1e3:.1f}kW;"
                f"chips={d.compute_chips}"))
    # headline speedups at 16 TiB
    ds = {s.name: provision_capacity(s, WL) for s in
          (TRADITIONAL, BIG_MEMORY, DIE_STACKED)}
    out.append(("fig5/speedup_die_vs_big", 0.0,
                f"{ds['big-memory'].response_time/ds['die-stacked'].response_time:.0f}x"))
    out.append(("fig5/speedup_die_vs_trad", 0.0,
                f"{ds['traditional'].response_time/ds['die-stacked'].response_time:.0f}x"))
    return out
