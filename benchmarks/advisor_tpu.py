"""Beyond-paper: the paper's provisioning questions answered for TPU pods
serving the assigned architectures (repro.core.advisor)."""
from __future__ import annotations

from benchmarks.common import timed
from repro.configs import get_config
from repro.core import advisor

CASES = (
    ("llama3-405b", 128, 32768),
    ("mixtral-8x22b", 128, 32768),
    ("mamba2-1.3b", 128, 32768),
    ("internlm2-1.8b", 128, 32768),
)


def rows():
    out = []
    for arch, batch, seq in CASES:
        cfg = get_config(arch)
        a, us = timed(advisor.advise_decode_sla, cfg, batch, seq, 0.020)
        d = a.design
        out.append((f"advisor/sla20ms/{arch}", us,
                    f"chips={d.compute_chips};power={d.power/1e3:.1f}kW;"
                    f"rt={d.response_time*1e3:.2f}ms"))
    cfg = get_config("llama3-405b")
    table, us = timed(advisor.when_to_use_tpu, cfg, 128, 32768, repeat=1)
    for row in table:
        out.append((f"advisor/tpu_vs_host/llama3-405b/{row['sla_ms']:g}ms",
                    us / len(table),
                    f"tpu={row['tpu_power_kw']:.0f}kW;"
                    f"host={row['host_power_kw']:.0f}kW;"
                    f"tpu_wins={row['tpu_wins_power']};"
                    f"host_overprov={row['host_overprovision_x']:.0f}x"))
    return out
