"""Resilience benchmarks: SLA attainment and modeled MTTR under faults.

The paper's verdict hinges on a strict response-time SLA, but the other
benchmarks measure a fault-free machine. This one replays the same seeded
zipf trace through the tiered QueryEngine with a ChaosHarness injecting
tier-read stalls and bit-flipped chunk payloads, and sweeps the fault
rate three ways per rate:

- *norecover*: faults on, recovery off — stalls ride to completion at
  stall_factor x, corrupt chunks fail the query typed-degraded (counted
  as a miss), and admission prices the full expected stall slowdown;
- *patient* / *aggressive*: recovery on under two RetryPolicy variants
  (long vs short timeout relative to one clean chunk read) — stalled
  reads are abandoned and re-issued, corruption is repaired from the
  oracle, and every recovery byte lands on the kind="recovery" ledger.

Attainment is the fault-adjusted number (typed-degraded answers and
admission rejections count as misses); MTTR is the harness's modeled
extra-seconds-per-recovered-fault. The acceptance bar checked by
check_append.py: recovery-enabled attainment strictly above the
no-recovery baseline at every non-zero fault rate, and bit-equal at
rate zero (a fault-free chaos run is the plain tiered path).

Each run rebuilds the encoded table from the same seed, so injected
corruption never leaks between configurations and the whole sweep is
reproducible from the spec seeds. Appends one record per run to
BENCH_resilience.json. Set REPRO_RESILIENCE_BENCH_QUICK=1 for a smaller
table/trace (CI smoke).
"""
from __future__ import annotations

import os
import time
from pathlib import Path

import jax

from benchmarks.common import append_trajectory, obs_digest
from benchmarks.store_bench import compressible_table
from repro.query import physical
from repro.resilience import ChaosHarness, ChunkGuard, FaultSpec, RetryPolicy
from repro.store import EncodedTable
from repro.tier import (Policy, TraceSpec, make_trace, measured_fast_gbps,
                        paper_tiers, replay_trace)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_resilience.json"

STALL_RATES = (0.0, 0.05, 0.15)
CORRUPT_RATE = 0.05          # of chunks, whenever stalls are injected
FAST_FRACTION = 0.25
SLA_SLACK = 2.0
PLACEMENT = Policy.CACHE
FAULT_SEED = 11


def _sizes() -> tuple[int, int, int, int]:
    """(columns, rows, chunk_rows, n_queries); quick mode for CI/tests."""
    if os.environ.get("REPRO_RESILIENCE_BENCH_QUICK"):
        return 8, 4096, 512, 30
    return 12, 16384, 1024, 100


def _retry_policies(clean_chunk_s: float) -> dict[str, RetryPolicy | None]:
    """Retry knobs scaled to one clean fast-tier chunk read, so the same
    policy names mean the same thing at any table size."""
    return {
        "norecover": None,
        "patient": RetryPolicy(timeout_s=2.5 * clean_chunk_s,
                               backoff_s=0.5 * clean_chunk_s,
                               backoff_cap_s=2.0 * clean_chunk_s,
                               max_retries=3),
        "aggressive": RetryPolicy(timeout_s=1.5 * clean_chunk_s,
                                  backoff_s=0.25 * clean_chunk_s,
                                  backoff_cap_s=clean_chunk_s,
                                  max_retries=2),
    }


def _run(spec, retry, recover, trace, tiers, chunk_rows, sla_s,
         n_cols, n_rows):
    # fresh table per run: corruption must not leak across configurations
    encoded = EncodedTable.from_table(compressible_table(n_cols, n_rows,
                                                         seed=0),
                                      chunk_rows=chunk_rows)
    guard = ChunkGuard(encoded)
    chaos = ChaosHarness(spec, retry=retry, guard=guard, recover=recover)
    if spec.corrupt_rate > 0:
        chaos.inject_corruption()
    t0 = time.perf_counter()
    pe, eng, att = replay_trace(encoded, trace, tiers, PLACEMENT,
                                sla_s=sla_s, chunk_rows=chunk_rows,
                                chaos=chaos)
    wall_us = (time.perf_counter() - t0) / len(trace) * 1e6
    s = chaos.summary()
    es = eng.summary()
    return {
        "attainment": round(att, 4),
        "mttr_ms": (round(s["mttr_s"] * 1e3, 6)
                    if s["mttr_s"] is not None else None),
        "stalls": s["stalls"],
        "retries": s["retries"],
        "failovers": s["failovers"],
        "repairs": s["repairs"],
        "degraded": s["degraded_queries"],
        "rejected": es["rejected"],
        "recovery_j": round(pe.meter.recovery_j, 6),
        "recovery_bytes": pe.recovery_bytes_total,
    }, wall_us, eng


def rows():
    n_cols, n_rows, chunk_rows, n_queries = _sizes()
    table = compressible_table(n_cols, n_rows, seed=0)
    encoded = EncodedTable.from_table(table, chunk_rows=chunk_rows)
    fast_gbps = measured_fast_gbps(default=8.0)
    tiers = paper_tiers(table.nbytes * FAST_FRACTION, fast_gbps=fast_gbps)
    trace = make_trace(table, TraceSpec(n_queries=n_queries, skew=1.1,
                                        seed=7))
    bytes_typ = sum(
        physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                  encoded.columns)
        for tq in trace) / len(trace)
    sla_s = SLA_SLACK * bytes_typ / tiers.fast.bandwidth
    n_chunks = sum(len(c.chunks) for c in encoded.columns.values())
    clean_chunk_s = (encoded.nbytes / n_chunks) / tiers.fast.bandwidth
    policies = _retry_policies(clean_chunk_s)

    out = []
    record: dict = {"sweep": {}}
    for rate in STALL_RATES:
        spec = FaultSpec(seed=FAULT_SEED, stall_rate=rate,
                         corrupt_rate=CORRUPT_RATE if rate else 0.0)
        per_rate: dict = {}
        for name, retry in policies.items():
            r, wall_us, eng = _run(spec, retry, recover=retry is not None,
                                   trace=trace, tiers=tiers,
                                   chunk_rows=chunk_rows, sla_s=sla_s,
                                   n_cols=n_cols, n_rows=n_rows)
            per_rate[name] = r
            if name == "patient" and rate == max(STALL_RATES):
                # the worst-rate recovered run feeds the gated headline;
                # its digest is the trace-diff explainer's baseline
                record["obs"] = obs_digest(eng)
            out.append((f"resilience/{name}/rate={rate:g}", wall_us,
                        f"att={r['attainment']:.2f},"
                        f"stalls={r['stalls']},deg={r['degraded']},"
                        f"mttr={r['mttr_ms']}ms"))
        record["sweep"][f"{rate:g}"] = per_rate

    record.update({
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "columns": n_cols, "rows": n_rows, "chunk_rows": chunk_rows,
        "n_queries": n_queries, "fast_fraction": FAST_FRACTION,
        "placement_policy": PLACEMENT.value,
        "fault_seed": FAULT_SEED, "corrupt_rate": CORRUPT_RATE,
        "stall_rates": list(STALL_RATES),
        "sla_ms": round(sla_s * 1e3, 6),
        "clean_chunk_us": round(clean_chunk_s * 1e6, 4),
        "fast_gbps": round(tiers.fast.gbps, 4),
    })
    append_trajectory(BENCH_PATH, record)
    return out
