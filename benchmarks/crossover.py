"""Paper §5.1/§6.1 crossover sensitivity: base (~60 ms), 50% accessed
(~170 ms), 8x-denser die stacks (~800 ms band), 10x lower compute power."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import (DIE_STACKED, TRADITIONAL, Workload,
                        power_crossover_sla)
from repro.core.systems import TiB

WL = Workload(16 * TiB, 0.20)


def rows():
    out = []
    t, us = timed(power_crossover_sla, TRADITIONAL, DIE_STACKED, WL,
                  repeat=1)
    out.append(("crossover/base", us, f"{t*1e3:.0f}ms(paper~60)"))
    t, us = timed(power_crossover_sla, TRADITIONAL, DIE_STACKED,
                  Workload(16 * TiB, 0.50), repeat=1)
    out.append(("crossover/50pct_accessed", us, f"{t*1e3:.0f}ms(paper~170)"))
    t, us = timed(power_crossover_sla, TRADITIONAL,
                  DIE_STACKED.with_density(8), WL, repeat=1)
    out.append(("crossover/8x_density", us,
                f"{t*1e3:.0f}ms(paper~800,band)"))
    t, us = timed(power_crossover_sla, TRADITIONAL,
                  DIE_STACKED.with_compute_power(0.1), WL, repeat=1)
    out.append(("crossover/0.1x_core_power", us,
                f"{(t or 0)*1e3:.0f}ms(§6.1 lever)"))
    return out
