"""Paper Fig. 1: time to read a fraction of memory capacity per system.

Derived values: the bandwidth-capacity ratios (die/trad = 80x,
die/big = 341x) and the 20%-of-capacity read times.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core import BIG_MEMORY, DIE_STACKED, TRADITIONAL

FRACTIONS = np.logspace(-3, 0, 16)


def curve(system):
    """Seconds to read `f` of one socket's capacity, per fraction."""
    return {f: f * system.chip_capacity / system.chip_bandwidth
            for f in FRACTIONS}


def rows():
    out = []
    for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
        c, us = timed(curve, s)
        t20 = 0.2 * s.chip_capacity / s.chip_bandwidth
        out.append((f"fig1/read20pct/{s.name}", us, f"{t20*1e3:.2f}ms"))
    r_trad = DIE_STACKED.bandwidth_capacity_ratio / TRADITIONAL.bandwidth_capacity_ratio
    r_big = DIE_STACKED.bandwidth_capacity_ratio / BIG_MEMORY.bandwidth_capacity_ratio
    out.append(("fig1/bw_cap_ratio_die_vs_trad", 0.0, f"{r_trad:.0f}x"))
    out.append(("fig1/bw_cap_ratio_die_vs_big", 0.0, f"{r_big:.0f}x"))
    return out
