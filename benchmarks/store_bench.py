"""Compressed-store benchmarks: ratio, effective bandwidth, hit-rate
delta, and the compression axis of the decision surface.

Four experiments over one compressible table (a realistic column mix:
sorted low-cardinality -> RLE, clustered 8/16-bit -> FOR, uniform ->
plain), all appended to BENCH_store.json at the repo root:

1. *Ratio*: per-column encoding choices and the table's logical/physical
   ratio (the selector's never-worse-than-plain guarantee in numbers).
2. *Scan-over-compressed bandwidth*: a zipf(1.1) multi-tenant trace
   replayed through the tiered QueryEngine over the plain and the
   encoded table — physical (compressed) vs logical (effective) GB/s and
   the trace's physical/logical byte fraction (the acceptance bar:
   <= 0.5x on this mix).
3. *Tier hit-rate delta*: the same trace, same absolute fast-tier
   capacity — compressed chunks are smaller, so the fast tier holds
   1/ratio more of the table and the hit rate strictly rises.
4. *Decision surface*: the 16 TiB paper workload at compression ratios
   (1.0, measured) plus `compression_crossover_ratio` at the 10 ms SLA —
   at what ratio does a software-compressed traditional system beat the
   die-stacked baseline?
5. *Batched launches*: kernel launches per query over the encoded table —
   the batched executor issues one launch per (column group, encoding),
   not one per chunk, so launches/query stays below the chunk count.
6. *Overlap*: the encoded trace replayed with the async prefetch
   pipeline across a fast-capacity sweep — modeled service is
   max(scan, stream) per stage instead of the sum, so blended GB/s
   climbs toward the fast tier's rate as the hit rate rises, with the
   pipeline's own traffic visible on the prefetch ledger.

Both replay timings are taken warm (one untimed pass first): the store
measures the scan path, not XLA compile amortization.

Set REPRO_STORE_BENCH_QUICK=1 for a smaller table/trace (CI smoke).
"""
from __future__ import annotations

import os
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import append_trajectory, obs_digest
from repro.db.columnar import BitPackedColumn, Table
from repro.energy.tco import (cheapest_architecture,
                              compression_crossover_ratio)
from repro.core.systems import TiB
from repro.kernels import dispatch
from repro.query import physical
from repro.store import EncodedTable
from repro.store.exec import execute_encoded
from repro.tier import (Policy, TraceSpec, make_trace, measured_fast_gbps,
                        paper_tiers, replay_trace)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_store.json"

SKEW = 1.1
FAST_FRACTION = 0.25
SLA_SLACK = 2.0
PAPER_DB = 16 * TiB
PAPER_ACCESSED = 0.20


def _sizes() -> tuple[int, int, int, int]:
    """(columns, rows, chunk_rows, n_queries); quick mode for CI/tests."""
    if os.environ.get("REPRO_STORE_BENCH_QUICK"):
        return 8, 4096, 512, 40
    return 16, 32768, 2048, 150


def compressible_table(n_cols: int, n_rows: int, seed: int = 0) -> Table:
    """The column mix compression was built for — mostly sorted
    low-cardinality (RLE) and clustered 8/16-bit (FOR at 4 bits), with
    one uniform full-payload column per eight that stays plain — a
    zipfian dashboard workload's shape (timestamps cluster, statuses
    repeat, only payload hashes resist)."""
    rng = np.random.default_rng(seed)
    t = Table("store")
    for i in range(n_cols):
        name = f"c{i:02d}"
        kind = i % 8
        if kind in (0, 4):       # sorted, 8 distinct values -> RLE
            t.add(BitPackedColumn.from_values(
                name, np.sort(rng.integers(0, 8, n_rows)), 8))
        elif kind in (1, 5, 7):  # 16-bit clustered, span 7 -> FOR, 4 bits
            t.add(BitPackedColumn.from_values(
                name, 9000 + rng.integers(0, 8, n_rows), 16))
        elif kind in (2, 6):     # 8-bit clustered, span 7 -> FOR, 4 bits
            t.add(BitPackedColumn.from_values(
                name, 40 + rng.integers(0, 8, n_rows), 8))
        else:                    # uniform full-payload -> plain
            t.add(BitPackedColumn.from_values(
                name, rng.integers(0, 128, n_rows), 8))
    return t


def _overlap_sweep(encoded, trace, tiers, chunk_rows):
    """Replay the encoded trace sync vs pipelined across a fast-capacity
    sweep. Returns the overlap record: per fast-fraction point, modeled
    service with and without prefetch, the blended GB/s trajectory
    toward the fast rate, and the prefetch ledger."""
    points = []
    for frac in (0.125, 0.25, 0.5):
        tw = paper_tiers(max(1, int(encoded.logical_nbytes * frac)),
                         fast_gbps=tiers.fast.gbps)
        pe_s, eng_s, _ = replay_trace(encoded, trace, tw, Policy.CACHE,
                                      chunk_rows=chunk_rows)
        # a double buffer needs ~one chunk of staging depth, not a cache's
        # worth: the reservation evicts residents, so an oversized buffer
        # trades hit rate for overlap and can lose on net
        buf = max(1, int(tw.fast.capacity / 16))
        pe_o, eng_o, _ = replay_trace(encoded, trace, tw, Policy.CACHE,
                                      chunk_rows=chunk_rows,
                                      prefetch_bytes=buf)
        ps = pe_o.stats()
        points.append({
            "fast_fraction": frac,
            "hit_rate": round(pe_o.hit_rate, 4),
            "sync_s": eng_s.seconds_total,
            "pipelined_s": eng_o.seconds_total,
            "sync_gbps": round(eng_s.summary()["measured_gbps"], 4),
            "pipelined_gbps": round(eng_o.summary()["measured_gbps"], 4),
            "staged_chunks": eng_o.prefetch.stats()["staged_chunks"],
            "prefetch_reserved_bytes": ps["prefetch_reserved_bytes"],
            "fast_capacity_bytes": int(tw.fast.capacity),
            "prefetch_streamed_bytes": ps["prefetch_streamed_bytes"],
            "prefetch_wasted_bytes": ps["prefetch_wasted_bytes"],
            "prefetch_j": pe_o.meter.prefetch_j,
        })
    return {"fast_gbps": tiers.fast.gbps,
            "capacity_gbps": tiers.capacity.gbps,
            "points": points}


def rows():
    n_cols, n_rows, chunk_rows, n_queries = _sizes()
    table = compressible_table(n_cols, n_rows, seed=0)
    t0 = time.perf_counter()
    encoded = EncodedTable.from_table(table, chunk_rows=chunk_rows)
    encode_us = (time.perf_counter() - t0) * 1e6
    ratio = encoded.ratio
    enc_counts: dict[str, int] = {}
    for col in encoded.columns.values():
        for k, v in col.encodings().items():
            enc_counts[k] = enc_counts.get(k, 0) + v

    fast_gbps = measured_fast_gbps(default=8.0)
    # fixed *absolute* fast capacity: 25% of the PLAIN table for both runs
    tiers = paper_tiers(table.nbytes * FAST_FRACTION, fast_gbps=fast_gbps)
    trace = make_trace(table, TraceSpec(n_queries=n_queries, skew=SKEW,
                                        seed=7))
    sla_s = SLA_SLACK * (table.nbytes / n_cols * 2) / tiers.fast.bandwidth

    # warm both execution paths first: one untimed pass compiles every
    # query shape, so the timed replays measure scans, not XLA compiles
    slices = physical.table_slices(table)
    for tq in trace:
        physical.finalize_aggs(physical.execute(
            tq.query.plan(), tq.query.aggregates, slices, mode="xla_ref"))
        execute_encoded(tq.query.plan(), tq.query.aggregates, encoded,
                        mode="xla_ref")

    t0 = time.perf_counter()
    pe_p, eng_p, att_p = replay_trace(table, trace, tiers, Policy.CACHE,
                                      sla_s=sla_s, chunk_rows=chunk_rows)
    plain_us = (time.perf_counter() - t0) / len(trace) * 1e6
    dispatch.reset_launch_counts()
    t0 = time.perf_counter()
    pe_e, eng_e, att_e = replay_trace(encoded, trace, tiers, Policy.CACHE,
                                      sla_s=sla_s, chunk_rows=chunk_rows)
    enc_us = (time.perf_counter() - t0) / len(trace) * 1e6
    launches = {
        "per_query": round(dispatch.total_launches() / len(trace), 2),
        "n_chunks": encoded.n_chunks,
        "by_family": dispatch.launch_counts(),
    }
    se, sp = eng_e.summary(), eng_p.summary()

    overlap = _overlap_sweep(encoded, trace, tiers, chunk_rows)

    surf_ratio1 = cheapest_architecture(
        PAPER_DB, PAPER_ACCESSED * PAPER_DB, 0.010, 1e6,
        compression_ratio=1.0)
    surf_measured = cheapest_architecture(
        PAPER_DB, PAPER_ACCESSED * PAPER_DB, 0.010, 1e6,
        compression_ratio=max(ratio, 1.0))
    crossover = compression_crossover_ratio(
        PAPER_DB, PAPER_ACCESSED * PAPER_DB, 0.010, 1e6)

    record = {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "columns": n_cols, "rows": n_rows, "chunk_rows": chunk_rows,
        "n_queries": n_queries, "skew": SKEW,
        "ratio": round(ratio, 4),
        "encodings": enc_counts,
        "physical_bytes": encoded.nbytes,
        "logical_bytes": encoded.logical_nbytes,
        "trace": {
            "physical_bytes": se["bytes_scanned"],
            "logical_bytes": se["logical_bytes"],
            "physical_fraction": round(se["bytes_scanned"]
                                       / se["logical_bytes"], 4),
            "physical_gbps": round(se["measured_gbps"], 4),
            "effective_gbps": round(se["effective_gbps"], 4),
            "plain_gbps": round(sp["measured_gbps"], 4),
        },
        "tier": {
            "fast_fraction_of_plain": FAST_FRACTION,
            "plain_hit_rate": round(pe_p.hit_rate, 4),
            "encoded_hit_rate": round(pe_e.hit_rate, 4),
            "plain_attainment": att_p,
            "encoded_attainment": att_e,
        },
        "surface": {
            "verdict_ratio1_10ms": surf_ratio1["winner"],
            "verdict_measured_10ms": surf_measured["winner"],
            "crossover_ratio_10ms": crossover,
        },
        "launches": launches,
        "plain_us_per_query": round(plain_us, 1),
        "encoded_us_per_query": round(enc_us, 1),
        "overlap": overlap,
        # the encoded replay produces the gated physical_gbps headline;
        # its digest is the trace-diff explainer's baseline
        "obs": obs_digest(eng_e),
    }
    append_trajectory(BENCH_PATH, record)
    last = overlap["points"][-1]
    return [
        ("store/encode", encode_us,
         f"ratio={ratio:.2f}x,"
         + ",".join(f"{k}={v}" for k, v in sorted(enc_counts.items()))),
        ("store/trace/plain", plain_us,
         f"hit={pe_p.hit_rate:.2f},{sp['measured_gbps']:.2f}GBps,"
         f"att={att_p:.2f}"),
        ("store/trace/encoded", enc_us,
         f"hit={pe_e.hit_rate:.2f},"
         f"phys={se['measured_gbps']:.2f}GBps,"
         f"eff={se['effective_gbps']:.2f}GBps,att={att_e:.2f},"
         f"launches/q={launches['per_query']}"
         f"(chunks={launches['n_chunks']})"),
        ("store/overlap", 0.0,
         ",".join(f"f={p['fast_fraction']}:"
                  f"{p['sync_gbps']}->{p['pipelined_gbps']}GBps"
                  for p in overlap["points"])
         + f",staged={last['staged_chunks']}"),
        ("store/surface/10ms", 0.0,
         f"ratio1={surf_ratio1['winner']},"
         f"measured={surf_measured['winner']},"
         f"crossover={crossover and round(crossover, 2)}"),
    ]
