"""The 40-cell roofline table, read from the dry-run artifacts
(artifacts/dryrun/<mesh>/<arch>__<shape>.json). Also used to regenerate
EXPERIMENTS.md §Roofline (python -m benchmarks.roofline_table --markdown).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str = "single"):
    cells = []
    d = ART / mesh
    if not d.exists():
        return cells
    for p in sorted(d.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def rows():
    out = []
    for mesh in ("single", "multi", "single-opt", "multi-opt"):
        for c in load(mesh):
            name = f"roofline/{mesh}/{c['arch']}/{c['shape']}"
            if c.get("status") == "skipped-by-design":
                out.append((name, 0.0, "skipped-by-design"))
                continue
            if c.get("status") != "ok":
                out.append((name, 0.0, f"ERROR:{c.get('error','?')[:60]}"))
                continue
            r = c.get("roofline")
            if not r:
                out.append((name, c.get("compile_s", 0) * 1e6, "compiled"))
                continue
            u = c.get("utilization", {})
            out.append((
                name, c.get("compile_s", 0) * 1e6,
                f"compute={r['compute_s']*1e3:.2f}ms;"
                f"mem={r['memory_s']*1e3:.2f}ms;"
                f"coll={r['collective_s']*1e3:.2f}ms;"
                f"dom={r['dominant']};mfu={u.get('roofline_mfu', 0):.3f}"))
    return out


def markdown(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | roofline MFU | useful/HLO FLOPs | bytes/dev (GiB) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load(mesh):
        if c.get("status") == "skipped-by-design":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"skipped-by-design | — | — | — |")
            continue
        if c.get("status") != "ok" or "roofline" not in c:
            lines.append(f"| {c['arch']} | {c['shape']} | ? | ? | ? | "
                         f"{c.get('status')} | ? | ? | ? |")
            continue
        r, u, m = c["roofline"], c["utilization"], c.get("memory", {})
        dev_bytes = (m.get("argument_size_in_bytes", 0)
                     + m.get("temp_size_in_bytes", 0)) / 2**30
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {u['roofline_mfu']:.3f} | "
            f"{u['useful_vs_hlo_flops']:.2f} | {dev_bytes:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if "--markdown" in sys.argv:
        mesh = "multi" if "--multi" in sys.argv else "single"
        print(markdown(mesh))
    else:
        from benchmarks.common import emit
        emit(rows())
