"""Energy & cost benchmarks: metered watts, power-capped replay, $/query.

Two experiments, both appended to BENCH_energy.json at the repo root:

1. *Power-capped replay*: the tier bench's seeded zipfian trace replayed
   through the MEMCACHE policy with the full energy model (per-byte tier
   energy + compute-chip watts over modeled busy time), uncapped to
   establish the demand power, then under a PowerCap at 70% of that
   demand. Recorded: SLA attainment with and without the cap, the max
   window-average watts over the whole replay (the contract: <= budget),
   throttle/rejection counts, and the per-tenant joules bill.

2. *Decision surface*: the paper's 16 TiB / 20%-accessed workload swept
   over SLA x skew x power budget (Fig. 4's 50 kW / 250 kW / 1 MW
   operating points), winners priced from the CostSheet — with the fast
   tier at the autotune cache's measured rate when one exists, so the
   surface answers for the system we actually built.

Set REPRO_ENERGY_BENCH_QUICK=1 for a smaller table/trace (CI smoke).
"""
from __future__ import annotations

import os
import time
from pathlib import Path

import jax

from benchmarks.common import append_trajectory, obs_digest
from repro.core.advisor import advise_cost
from repro.core.systems import DIE_STACKED, TiB
from repro.db import Table
from repro.energy import PowerCap, chip_compute_watts, decision_surface
from repro.tier import (Policy, TraceSpec, make_trace, measured_fast_gbps,
                        paper_tiers, replay_trace)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_energy.json"

SKEW = 1.1
FAST_FRACTION = 0.25
SLA_SLACK = 2.0
CAP_FRACTION = 0.7        # budget = 70% of the uncapped demand power
PAPER_DB = 16 * TiB
PAPER_ACCESSED = 0.20


def _sizes() -> tuple[int, int, int, int]:
    """(columns, rows, chunk_rows, n_queries); quick mode for CI/tests."""
    if os.environ.get("REPRO_ENERGY_BENCH_QUICK"):
        return 8, 4096, 256, 40
    return 16, 32768, 1024, 150


def _capped_replay() -> tuple[list, dict]:
    n_cols, n_rows, chunk_rows, n_queries = _sizes()
    table = Table.synthetic("energy", n_rows,
                            {f"c{i:02d}": 8 for i in range(n_cols)}, seed=0)
    fast_gbps = measured_fast_gbps(default=8.0)
    tiers = paper_tiers(table.nbytes * FAST_FRACTION, fast_gbps=fast_gbps)
    trace = make_trace(table, TraceSpec(n_queries=n_queries, skew=SKEW,
                                        seed=7))
    compute_w = chip_compute_watts(DIE_STACKED)
    sla_s = SLA_SLACK * (table.nbytes / n_cols * 2) / tiers.fast.bandwidth

    t0 = time.perf_counter()
    pe, eng, att = replay_trace(table, trace, tiers, Policy.MEMCACHE,
                                sla_s=sla_s, chunk_rows=chunk_rows,
                                compute_w=compute_w)
    uncapped_us = (time.perf_counter() - t0) / len(trace) * 1e6
    energy = eng.summary()["energy"]
    demand_w = energy["total_j"] / eng.seconds_total
    budget_w = CAP_FRACTION * demand_w
    window_s = 20 * sla_s

    cap = PowerCap(budget_w=budget_w, window_s=window_s)
    t0 = time.perf_counter()
    _, ceng, catt = replay_trace(table, trace, tiers, Policy.MEMCACHE,
                                 sla_s=sla_s, chunk_rows=chunk_rows,
                                 compute_w=compute_w, power_cap=cap)
    capped_us = (time.perf_counter() - t0) / len(trace) * 1e6
    rep = cap.report(now=ceng.clock())
    assert rep["max_window_w"] <= budget_w * (1 + 1e-9), \
        f"power cap violated: {rep['max_window_w']} > {budget_w}"

    record = {
        "sla_ms": sla_s * 1e3,
        "compute_w_per_chip": compute_w,
        "demand_w": demand_w,
        "budget_w": budget_w,
        "window_s": window_s,
        "uncapped": {"attainment": att,
                     "energy_j": energy["total_j"],
                     "j_per_query": energy["j_per_query"],
                     "hit_rate": pe.hit_rate},
        "capped": {"attainment": catt,
                   "max_window_w": rep["max_window_w"],
                   "budget_utilization": rep["budget_utilization"],
                   "throttled_queries": rep["throttled_queries"],
                   "throttle_s_total": rep["throttle_s_total"],
                   "rejected": ceng.summary()["rejected"]},
        "by_tenant": {str(k): v for k, v in
                      sorted(ceng.summary()["energy"]["by_tenant"].items())},
        # the capped replay is the gated headline; its digest is the
        # trace-diff explainer's baseline
        "obs": obs_digest(ceng),
    }
    rows = [
        ("energy/replay/uncapped", uncapped_us,
         f"att={att:.2f},{demand_w:.1f}W,"
         f"{energy['j_per_query']:.2e}J/q"),
        ("energy/replay/capped70", capped_us,
         f"att={catt:.2f},peak={rep['max_window_w']:.1f}W"
         f"<=budget={budget_w:.1f}W,"
         f"throttled={rep['throttled_queries']}"),
    ]
    return rows, record


def _surface() -> tuple[list, dict]:
    fast_gbps = measured_fast_gbps()       # None -> datasheet Eq. 4 rates
    quick = bool(os.environ.get("REPRO_ENERGY_BENCH_QUICK"))
    slas = (0.010, 0.060, 1.0) if quick else (0.005, 0.010, 0.060, 0.250,
                                              1.0)
    t0 = time.perf_counter()
    surf = decision_surface(PAPER_DB, PAPER_ACCESSED * PAPER_DB,
                            slas=slas, skews=(None, SKEW),
                            fast_gbps=fast_gbps)
    us = (time.perf_counter() - t0) / max(len(surf["cells"]), 1) * 1e6
    rows = []
    for cell in surf["cells"]:
        if cell["skew"] is None and cell["power_budget_w"] == 1e6:
            rows.append((
                f"energy/surface/sla={cell['sla_s']:g}s/1MW", us,
                f"winner={cell['winner']}"))
    cheapest = advise_cost(PAPER_DB, PAPER_ACCESSED * PAPER_DB, 0.010, 1e6,
                           skew=SKEW, fast_gbps=fast_gbps)
    rows.append(("energy/advise_cost/10ms/1MW/zipf1.1", 0.0,
                 f"winner={cheapest['winner']},"
                 f"${(cheapest['usd_per_query'] or 0):.4f}/q"))
    record = {
        "fast_gbps": fast_gbps,
        "winners": {f"sla={c['sla_s']:g};skew={c['skew']};"
                    f"budget={c['power_budget_w']:g}": c["winner"]
                    for c in surf["cells"]},
        "advise_cost_10ms_1mw": {"winner": cheapest["winner"],
                                 "usd_per_query":
                                     cheapest["usd_per_query"]},
    }
    return rows, record


def rows():
    replay_rows, replay_rec = _capped_replay()
    surface_rows, surface_rec = _surface()
    record = {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "replay": replay_rec,
        "surface": surface_rec,
        # every bench record carries its digest at the top level — the
        # one place check_regress.py's explainer looks
        "obs": replay_rec.pop("obs"),
    }
    append_trajectory(BENCH_PATH, record)
    return replay_rows + surface_rows
