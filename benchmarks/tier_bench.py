"""Tier placement benchmarks: hit-rate, blended GB/s, SLA attainment.

Runs the same seeded multi-tenant trace through the three placement
policies (STATIC memory-style pinning, CACHE LRU, MEMCACHE frequency-aware
admission) at three skew levels, with the fast tier capped at 25% of the
table — the regime where the paper's question ("is the bandwidth-rich,
capacity-poor tier worth it?") has a non-trivial answer. The fast tier
runs at the autotuned kernel sweep's measured rate (repro.tier.tiers.
measured_fast_gbps); the capacity tier is derated by the Table 1 bandwidth
ratio. Deadlines ride a VirtualClock on the modeled tiered latency, so the
numbers are CPU-speed-independent and reproducible.

Appends one record per run to BENCH_tier.json at the repo root — a
trajectory future PRs diff to catch placement/accounting regressions.
Set REPRO_TIER_BENCH_QUICK=1 for a smaller table/trace (test smoke).
"""
from __future__ import annotations

import os
import time
from pathlib import Path

import jax

from benchmarks.common import append_trajectory, obs_digest
from repro.core.advisor import advise_tier_split
from repro.db import Table
from repro.query import physical
from repro.tier import (Policy, TraceSpec, make_trace, measured_fast_gbps,
                        paper_tiers, replay_trace, zipf_hit_curve)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_tier.json"

SKEWS = (0.6, 1.1, 1.5)
FAST_FRACTION = 0.25
SLA_SLACK = 2.0           # deadline = slack x the all-fast service time;
#                           capacity-only service is 2.5x (Table 1 ratio),
#                           so meeting it requires a warm fast tier


def _sizes() -> tuple[int, int, int, int]:
    """(columns, rows, chunk_rows, n_queries); quick mode for CI/tests."""
    if os.environ.get("REPRO_TIER_BENCH_QUICK"):
        return 8, 4096, 256, 40
    return 16, 32768, 1024, 150


def _run_policy(table, trace, tiers, policy, chunk_rows, sla_s):
    """replay_trace warms the placement on the first third (deadline-free)
    and measures steady-state attainment on the rest, rejections counted
    as misses — the same methodology as examples/tiered_store.py."""
    t0 = time.perf_counter()
    pe, eng, att = replay_trace(table, trace, tiers, policy, sla_s=sla_s,
                                chunk_rows=chunk_rows)
    wall_us = (time.perf_counter() - t0) / len(trace) * 1e6
    s = eng.summary()
    return {
        "hit_rate": round(pe.hit_rate, 4),
        "blended_gbps": round(s["tier"]["blended_gbps"], 4),
        "sla_attainment": round(att, 4),
        "served": s["served"],
        "rejected": s["rejected"],
        "energy_j": s["tier"]["energy_j"],
    }, wall_us, eng


def rows():
    n_cols, n_rows, chunk_rows, n_queries = _sizes()
    table = Table.synthetic("tier", n_rows,
                            {f"c{i:02d}": 8 for i in range(n_cols)}, seed=0)
    fast_gbps = measured_fast_gbps(default=8.0)
    tiers = paper_tiers(table.nbytes * FAST_FRACTION, fast_gbps=fast_gbps)

    out = []
    record: dict = {"policies": {}}
    for skew in SKEWS:
        trace = make_trace(table, TraceSpec(n_queries=n_queries, skew=skew,
                                            seed=7))
        bytes_typ = sum(
            physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                      table.columns)
            for tq in trace) / len(trace)
        sla_s = SLA_SLACK * bytes_typ / tiers.fast.bandwidth
        for policy in Policy:
            r, wall_us, eng = _run_policy(table, trace, tiers, policy,
                                          chunk_rows, sla_s)
            out.append((f"tier/{policy.value}/skew={skew:g}", wall_us,
                        f"hit={r['hit_rate']:.2f},"
                        f"{r['blended_gbps']:.2f}GBps,"
                        f"att={r['sla_attainment']:.2f}"))
            record["policies"].setdefault(policy.value, {})[str(skew)] = r
            if policy is Policy.MEMCACHE and skew == 1.1:
                # the headline run (check_regress gates on it) carries
                # the trace-diff baseline digest
                record["obs"] = obs_digest(eng)
        adv = advise_tier_split(
            table.nbytes, bytes_typ, sla_s,
            hit_curve=zipf_hit_curve(n_cols, skew),
            fast_gbps=tiers.fast.gbps, capacity_gbps=tiers.capacity.gbps)
        best = adv["best"]
        record.setdefault("advise", {})[str(skew)] = {
            "sla_ms": sla_s * 1e3,
            "best_fast_fraction": best and best["fast_fraction"],
            "roofline_gbps": adv["roofline_gbps"],
        }
        out.append((f"tier/advise_split/skew={skew:g}", 0.0,
                    f"fast_frac={best and best['fast_fraction']}"))

    record.update({
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "columns": n_cols, "rows": n_rows, "chunk_rows": chunk_rows,
        "n_queries": n_queries, "fast_fraction": FAST_FRACTION,
        "fast_gbps": round(tiers.fast.gbps, 4),
        "capacity_gbps": round(tiers.capacity.gbps, 4),
    })
    append_trajectory(BENCH_PATH, record)
    return out
