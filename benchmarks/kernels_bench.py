"""Kernel micro-benchmarks, wired through the block-size autotuner.

Times the BitWeaving scan at the hardcoded default block size and at the
autotuned one (repro.kernels.tune sweeps candidates and caches the winner
in artifacts/tune_cache.json), and appends the pair to BENCH_kernels.json
at the repo root — a trajectory file future PRs diff against to catch
block-size and dispatch regressions.

On this CPU container the Pallas kernels run in interpret mode, where the
per-grid-step interpreter overhead makes block size matter *more* than on
TPU; the jnp fallback row is kept as the hardware-bandwidth reference
(the paper's ~4 bytes/instr scan regime).
"""
from __future__ import annotations

import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, timed
from repro.db import Predicate, Table, scan_aggregate_query
from repro.kernels import dispatch, tune
from repro.kernels.scan_filter import kernel as K
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter import ref as scan_ref

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"


def _scan_gbps(w2d, block_rows: int, interpret: bool) -> float:
    def run():
        K.scan_packed(w2d, 64, op="ge", code_bits=8,
                      block_rows=block_rows,
                      interpret=interpret).block_until_ready()

    _, us = timed(run, repeat=3)
    return w2d.nbytes / (us / 1e6) / 1e9


def rows():
    out = []
    n = 1 << 22                      # 4M codes
    codes = np.random.default_rng(0).integers(0, 128, n)
    packed = jnp.asarray(scan_ref.pack(codes, 8))
    w2d = packed.reshape(-1, K.LANES)
    nrows = w2d.shape[0]
    interpret = dispatch.resolve("pallas").interpret

    # --- autotune the scan block size (cache hit after the first run) ----
    skey = tune.shape_key(rows=nrows, bits=8)
    candidates = dict(dispatch.get("scan_filter").tunables)

    def bench(params):
        K.scan_packed(w2d, 64, op="ge", code_bits=8,
                      block_rows=min(params["block_rows"], nrows),
                      interpret=interpret).block_until_ready()

    entry = tune.autotune("scan_filter", skey, candidates, bench)
    tuned_br = min(int(entry["params"]["block_rows"]), nrows)

    default_gbps = _scan_gbps(w2d, min(K.DEFAULT_BLOCK_ROWS, nrows),
                              interpret)
    tuned_gbps = _scan_gbps(w2d, tuned_br, interpret)
    speedup = tuned_gbps / default_gbps
    out.append(("kernels/scan8b_4M/pallas_default_block", 0.0,
                f"{default_gbps:.2f}GBps@br={K.DEFAULT_BLOCK_ROWS}"))
    out.append(("kernels/scan8b_4M/pallas_tuned_block", 0.0,
                f"{tuned_gbps:.2f}GBps@br={tuned_br}"))
    out.append(("kernels/scan8b_4M/tuned_speedup", 0.0,
                f"{speedup:.2f}x"))

    # --- hardware-bandwidth reference: the jnp fallback path -------------
    def scan_ref_path():
        return scan_ops.scan_filter(packed, 64, "lt", 8,
                                    mode="xla_ref").block_until_ready()

    _, us = timed(scan_ref_path)
    gbps = packed.nbytes / (us / 1e6) / 1e9
    out.append(("kernels/scan8b_4M/jnp_cpu", us, f"{gbps:.2f}GBps"))
    out.append(("kernels/scan8b/intensity", 0.0,
                "3int_ops_per_4B_word(bandwidth-bound)"))

    append_trajectory(BENCH_PATH, {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "op": "scan_filter",
        "shape_key": skey,
        "default_block_rows": K.DEFAULT_BLOCK_ROWS,
        "default_gbps": round(default_gbps, 3),
        "tuned_block_rows": tuned_br,
        "tuned_gbps": round(tuned_gbps, 3),
        "speedup": round(speedup, 3),
        "jnp_ref_gbps": round(gbps, 3),
        "sweep": entry["sweep"],
        # no engine here — a hand-built digest with the same schema, so
        # the trace-diff explainer can still show snapshot deltas
        "obs": {"v": 1, "queries": 0, "exact": False, "categories": {},
                "snapshot": {
                    "kernels.default_gbps": round(default_gbps, 3),
                    "kernels.tuned_gbps": round(tuned_gbps, 3),
                    "kernels.tuned_block_rows": tuned_br,
                    "kernels.jnp_ref_gbps": round(gbps, 3)}},
    })

    t = Table.synthetic("t", 1 << 20, {"a": 8, "b": 8})

    def q():
        r = scan_aggregate_query(t, [Predicate("a", "lt", 64)], "b",
                                 mode="xla_ref")
        jax.block_until_ready(r["sum"])
        return r

    r, us = timed(q, repeat=3)
    out.append(("db/scan_aggregate_1M", us,
                f"sel={float(r['selectivity']):.3f}"))
    return out
