"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (not
representative), so wall-time rows time the jnp fallback path and `derived`
reports the scan's achieved GB/s plus the analytic arithmetic intensity the
kernel presents to the roofline (the paper's ~4 bytes/instr claim)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.db import Predicate, Table, scan_aggregate_query
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter import ref as scan_ref


def rows():
    out = []
    n = 1 << 22                      # 4M codes
    codes = np.random.default_rng(0).integers(0, 128, n)
    packed = jnp.asarray(scan_ref.pack(codes, 8))

    def scan_ref_path():
        return scan_ops.scan_filter(packed, 64, "lt", 8,
                                    use_kernel=False).block_until_ready()

    _, us = timed(scan_ref_path)
    gbps = packed.nbytes / (us / 1e6) / 1e9
    out.append(("kernels/scan8b_4M/jnp_cpu", us, f"{gbps:.2f}GBps"))
    out.append(("kernels/scan8b/intensity", 0.0,
                "3int_ops_per_4B_word(bandwidth-bound)"))

    t = Table.synthetic("t", 1 << 20, {"a": 8, "b": 8})
    def q():
        r = scan_aggregate_query(t, [Predicate("a", "lt", 64)], "b",
                                 use_kernel=False)
        jax.block_until_ready(r["sum"])
        return r
    r, us = timed(q, repeat=3)
    out.append(("db/scan_aggregate_1M", us,
                f"sel={float(r['selectivity']):.3f}"))
    return out
