"""Paper Fig. 6: (a) energy per query at 16 TiB; (b) power breakdown of a
1 MW-provisioned cluster."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_capacity, provision_power)
from repro.core.systems import TiB

WL = Workload(16 * TiB, 0.20)


def rows():
    out = []
    for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
        d, us = timed(provision_capacity, s, WL)
        out.append((f"fig6a/energy/{s.name}", us,
                    f"{d.energy_per_query:.0f}J"))
    for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
        d, us = timed(provision_power, s, WL, 1e6)
        tot = d.power
        out.append((
            f"fig6b/power_breakdown/{s.name}", us,
            f"compute={d.compute_power/tot:.2f};mem={d.mem_power/tot:.2f};"
            f"overhead={d.overhead_power/tot:.2f}"))
    return out
