"""Query-engine benchmarks: sharded scan GB/s + SLA attainment vs load.

Shards a synthetic table across every available device (CI forces 8 host
devices via XLA_FLAGS), times the sharded scan+aggregate path, compares
attained throughput against the analytical model's roofline
(QueryEngine.model_check), then sweeps offered load: batches of deadline-
carrying queries at 0.5x/1x/2x the engine's measured capacity, recording
attainment and rejections. Appends to BENCH_queries.json at the repo root —
a trajectory future PRs diff to catch sharding/dispatch regressions.

Interpret-mode numbers on CPU: the GB/s is not TPU-representative, but the
sharded-vs-oracle parity and the attainment-vs-load shape are.
"""
from __future__ import annotations

import os
import sys
import time
from pathlib import Path

if "jax" not in sys.modules:          # must precede the first jax import
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from benchmarks.common import append_trajectory, obs_digest, timed
from repro.db import Table
from repro.db.columnar import BitPackedColumn
from repro.launch.mesh import make_mesh
from repro.query import GroupBy, Pred, Query, QueryEngine, ShardedTable
from repro.query import relational

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_queries.json"


def _attainment_vs_load(st, measured_gbps: float, loads=(0.5, 1.0, 2.0),
                        n_queries: int = 12) -> dict:
    """Submit batches whose deadlines assume `load` x the engine's measured
    capacity: load <= 1 should mostly meet, load > 1 must shed/miss."""
    q = Query(Pred("a", "lt", 64), aggregates=("b",))
    out = {}
    for load in loads:
        eng = QueryEngine(st, est_gbps=measured_gbps)
        service = eng.bytes_scanned(q) / (measured_gbps * 1e9)
        t0 = eng.clock()
        for i in range(n_queries):
            # back-to-back arrivals; deadline i assumes the engine drains
            # (i+1) queries at load x capacity
            eng.submit(q, deadline=t0 + (i + 1) * service / load)
        eng.run()
        s = eng.summary()
        out[load] = {"sla_attainment": s["sla_attainment"],
                     "served": s["served"], "rejected": s["rejected"],
                     "latency_p99_s": s["latency_p99_s"]}
    return out


def _grouped_cardinality_sweep(cards=(8, 256, 32768)) -> dict:
    """Grouped-aggregation throughput vs key cardinality on one device:
    low cardinalities run the dense accumulator-plane kernel, anything
    past DENSE_MAX_GROUPS the host sort/hash fallback — the strategy
    cliff the decision surface's grouped axis prices. (The 16-bit
    BitWeaving payload caps codes at 32767, so the high-cardinality
    point is 32768 groups rather than a full 64k.)"""
    rng = np.random.default_rng(7)
    n = 1 << 18
    res = {}
    for card in cards:
        t = Table(f"card{card}")
        t.add(BitPackedColumn.from_values("k", rng.integers(0, card, n),
                                          16))
        t.add(BitPackedColumn.from_values("v", rng.integers(0, 120, n),
                                          8))
        q = GroupBy("k", ("v",))
        relational.execute_grouped(q, t, mode="xla_ref")   # warm jit
        r, us = timed(lambda: relational.execute_grouped(
            q, t, mode="xla_ref"), repeat=3)
        res[card] = {
            "strategy": ("dense" if card <= relational.DENSE_MAX_GROUPS
                         else "fallback"),
            "groups": len(r["groups"]),
            "rows_per_s": round(n / (us / 1e6), 1),
            "groups_per_s": round(len(r["groups"]) / (us / 1e6), 1),
        }
    return res


def _rle_vs_fallback() -> tuple[dict, object]:
    """Count-only GroupBy over a *sorted* low-cardinality key, encoded:
    the fused RLE run-accumulation path (one batched launch, no scatter)
    against the host sort/hash fallback on the same bytes — the
    pre-grouped-data win the RLE strategy exists for. The fallback is
    forced by shrinking the dense cutoff, the documented strategy knob."""
    from repro.kernels import dispatch
    from repro.kernels.group_aggregate import ops as gops
    from repro.store import EncodedTable
    from repro.store.exec import execute_grouped_encoded
    rng = np.random.default_rng(11)
    n = 1 << 18
    t = Table("rle")
    t.add(BitPackedColumn.from_values(
        "k", np.sort(rng.integers(0, 16, n)), 8))
    t.add(BitPackedColumn.from_values("v", rng.integers(0, 120, n), 8))
    store = EncodedTable.from_table(t, chunk_rows=4096)
    assert any(c.encoding.value == "rle"
               for c in store.columns["k"].chunks), \
        "sorted low-cardinality key did not RLE-encode"
    q = GroupBy("k")                              # count-only: RLE-fused
    execute_grouped_encoded(q, store, mode="xla_ref")      # warm
    before = dict(dispatch.launch_counts())
    want, rle_us = timed(lambda: execute_grouped_encoded(
        q, store, mode="xla_ref"), repeat=3)
    # timed() makes 1 warm + 3 timed calls after the snapshot
    launches = {k: (v - before.get(k, 0)) / 4
                for k, v in dispatch.launch_counts().items()
                if v != before.get(k, 0)}
    saved = relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS
    try:
        relational.DENSE_MAX_GROUPS = gops.DENSE_MAX_GROUPS = 0
        execute_grouped_encoded(q, store, mode="xla_ref")  # warm numpy
        got, fb_us = timed(lambda: execute_grouped_encoded(
            q, store, mode="xla_ref"), repeat=3)
    finally:
        relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS = saved
    assert got == want, "RLE-fused and fallback disagree"
    return ({"rle_pregrouped_us": round(rle_us, 1),
             "hash_fallback_us": round(fb_us, 1),
             "speedup": round(fb_us / max(rle_us, 1e-9), 3),
             "rle_launches_per_query": launches.get(
                 "group_aggregate_rle", 0.0),
             "fallback_launches_during_rle": launches.get(
                 "group_aggregate_fallback", 0.0),
             "groups": len(want["groups"])}, want)


def rows():
    out = []
    n_dev = len(jax.devices())
    if n_dev == 1:
        # a prior module already imported jax, so the 8-device override
        # could not apply; shard counts in this record are not comparable
        # with CI's 8-shard rows
        print("queries_bench: jax already initialized, running 1-shard",
              file=sys.stderr)
    mesh = make_mesh((n_dev,), ("data",))
    table = Table.synthetic("bench", 1 << 21, {"a": 8, "b": 8, "c": 16},
                            seed=0)
    st = ShardedTable.shard(table, mesh)
    q = Query(Pred("a", "lt", 64), aggregates=("b",))

    # compile the execution into st's jit cache with a throwaway engine so
    # eng's cumulative totals (model_check/provision below) measure hot
    # scans, not trace+compile
    warm = QueryEngine(st, mode="auto")
    warm.submit(q)
    warm.run()

    eng = QueryEngine(st, mode="auto")

    def once():
        eng.submit(q)
        return eng.run()[-1]

    res, us = timed(once, repeat=3)
    gbps = res.bytes_scanned / (us / 1e6) / 1e9
    out.append((f"queries/sharded_scan_agg_{n_dev}shards", us,
                f"{gbps:.3f}GBps,sel={res.selectivity:.3f}"))

    mc = eng.model_check()
    out.append(("queries/model_vs_measured", 0.0,
                f"{mc['attained_fraction']:.2e}of_{mc['system']}"))
    adv = eng.provision(sla_s=0.100)
    out.append(("queries/provision_100ms_sla", 0.0,
                f"{adv.design.compute_chips}chips_measured_calibrated"))

    sla = _attainment_vs_load(st, max(gbps, 1e-6))
    for load, s in sla.items():
        out.append((f"queries/sla_attainment/load={load:g}", 0.0,
                    f"{s['sla_attainment']:.2f}att,{s['rejected']}rej"))

    # --- grouped aggregation & hash join ---------------------------------
    gq = GroupBy("a", ("b",), where=Pred("c", "lt", 16000))
    warm_g = QueryEngine(st, mode="xla_ref")
    warm_g.submit(gq)
    warm_g.run()
    eng_g = QueryEngine(st, mode="xla_ref")

    def once_grouped():
        eng_g.submit(gq)
        return eng_g.run()[-1]

    res_g, us_g = timed(once_grouped, repeat=3)
    g_rows_per_s = table.num_rows / (us_g / 1e6)
    out.append((f"queries/grouped_sharded_{n_dev}shards", us_g,
                f"{len(res_g.aggregates['groups'])}groups,"
                f"{g_rows_per_s / 1e6:.1f}Mrows/s"))

    cards = _grouped_cardinality_sweep()
    for card, c in cards.items():
        out.append((f"queries/grouped_card={card}", 0.0,
                    f"{c['rows_per_s'] / 1e6:.1f}Mrows/s,"
                    f"{c['groups_per_s']:.0f}groups/s,{c['strategy']}"))

    rle, _ = _rle_vs_fallback()
    out.append(("queries/grouped_rle_vs_fallback", rle["rle_pregrouped_us"],
                f"{rle['speedup']}x_vs_fallback,"
                f"{rle['rle_launches_per_query']:g}launch/q"))

    append_trajectory(BENCH_PATH, {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_shards": n_dev,
        "rows": table.num_rows,
        "rows_per_shard": st.rows_per_shard,
        "scan_agg_gbps": round(gbps, 4),
        "model_gbps": round(mc["model_gbps"], 1),
        "attained_fraction": mc["attained_fraction"],
        "provision_100ms_chips": adv.design.compute_chips,
        "sla_vs_load": {str(k): v for k, v in sla.items()},
        "grouped": {
            "sharded_us_per_query": round(us_g, 1),
            "sharded_rows_per_s": round(g_rows_per_s, 1),
            "sharded_groups": len(res_g.aggregates["groups"]),
            "cardinality": {str(k): v for k, v in cards.items()},
            **rle,
        },
        # flat engine: the digest carries snapshot scalars + launch
        # counts (no tier ledger), still diffable by the explainer
        "obs": obs_digest(eng),
    })
    return out
