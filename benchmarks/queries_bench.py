"""Query-engine benchmarks: sharded scan GB/s + SLA attainment vs load.

Shards a synthetic table across every available device (CI forces 8 host
devices via XLA_FLAGS), times the sharded scan+aggregate path, compares
attained throughput against the analytical model's roofline
(QueryEngine.model_check), then sweeps offered load: batches of deadline-
carrying queries at 0.5x/1x/2x the engine's measured capacity, recording
attainment and rejections. Appends to BENCH_queries.json at the repo root —
a trajectory future PRs diff to catch sharding/dispatch regressions.

Interpret-mode numbers on CPU: the GB/s is not TPU-representative, but the
sharded-vs-oracle parity and the attainment-vs-load shape are.
"""
from __future__ import annotations

import os
import sys
import time
from pathlib import Path

if "jax" not in sys.modules:          # must precede the first jax import
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax

from benchmarks.common import append_trajectory, timed
from repro.db import Table
from repro.launch.mesh import make_mesh
from repro.query import Pred, Query, QueryEngine, ShardedTable

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_queries.json"


def _attainment_vs_load(st, measured_gbps: float, loads=(0.5, 1.0, 2.0),
                        n_queries: int = 12) -> dict:
    """Submit batches whose deadlines assume `load` x the engine's measured
    capacity: load <= 1 should mostly meet, load > 1 must shed/miss."""
    q = Query(Pred("a", "lt", 64), aggregates=("b",))
    out = {}
    for load in loads:
        eng = QueryEngine(st, est_gbps=measured_gbps)
        service = eng.bytes_scanned(q) / (measured_gbps * 1e9)
        t0 = eng.clock()
        for i in range(n_queries):
            # back-to-back arrivals; deadline i assumes the engine drains
            # (i+1) queries at load x capacity
            eng.submit(q, deadline=t0 + (i + 1) * service / load)
        eng.run()
        s = eng.summary()
        out[load] = {"sla_attainment": s["sla_attainment"],
                     "served": s["served"], "rejected": s["rejected"],
                     "latency_p99_s": s["latency_p99_s"]}
    return out


def rows():
    out = []
    n_dev = len(jax.devices())
    if n_dev == 1:
        # a prior module already imported jax, so the 8-device override
        # could not apply; shard counts in this record are not comparable
        # with CI's 8-shard rows
        print("queries_bench: jax already initialized, running 1-shard",
              file=sys.stderr)
    mesh = make_mesh((n_dev,), ("data",))
    table = Table.synthetic("bench", 1 << 21, {"a": 8, "b": 8, "c": 16},
                            seed=0)
    st = ShardedTable.shard(table, mesh)
    q = Query(Pred("a", "lt", 64), aggregates=("b",))

    # compile the execution into st's jit cache with a throwaway engine so
    # eng's cumulative totals (model_check/provision below) measure hot
    # scans, not trace+compile
    warm = QueryEngine(st, mode="auto")
    warm.submit(q)
    warm.run()

    eng = QueryEngine(st, mode="auto")

    def once():
        eng.submit(q)
        return eng.run()[-1]

    res, us = timed(once, repeat=3)
    gbps = res.bytes_scanned / (us / 1e6) / 1e9
    out.append((f"queries/sharded_scan_agg_{n_dev}shards", us,
                f"{gbps:.3f}GBps,sel={res.selectivity:.3f}"))

    mc = eng.model_check()
    out.append(("queries/model_vs_measured", 0.0,
                f"{mc['attained_fraction']:.2e}of_{mc['system']}"))
    adv = eng.provision(sla_s=0.100)
    out.append(("queries/provision_100ms_sla", 0.0,
                f"{adv.design.compute_chips}chips_measured_calibrated"))

    sla = _attainment_vs_load(st, max(gbps, 1e-6))
    for load, s in sla.items():
        out.append((f"queries/sla_attainment/load={load:g}", 0.0,
                    f"{s['sla_attainment']:.2f}att,{s['rejected']}rej"))

    append_trajectory(BENCH_PATH, {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_shards": n_dev,
        "rows": table.num_rows,
        "rows_per_shard": st.rows_per_shard,
        "scan_agg_gbps": round(gbps, 4),
        "model_gbps": round(mc["model_gbps"], 1),
        "attained_fraction": mc["attained_fraction"],
        "provision_100ms_chips": adv.design.compute_chips,
        "sla_vs_load": {str(k): v for k, v in sla.items()},
    })
    return out
