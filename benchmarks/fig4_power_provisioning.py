"""Paper Fig. 4: power-provisioned clusters at 1 MW / 250 kW / 50 kW —
response time + memory capacity."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_power)
from repro.core.systems import TiB

WL = Workload(16 * TiB, 0.20)
BUDGETS = (1e6, 250e3, 50e3)


def rows():
    out = []
    for budget in BUDGETS:
        for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
            d, us = timed(provision_power, s, WL, budget)
            out.append((
                f"fig4/{int(budget/1e3)}kW/{s.name}", us,
                f"rt={d.response_time*1e3:.1f}ms;"
                f"capacity={d.memory_capacity/TiB:.0f}TiB;"
                f"cores_per_chip={d.cores_per_chip};power={d.power/1e3:.1f}kW"))
    return out
