"""Parameterized BENCH_*.json append checks for CI smoke steps.

Every benchmark module appends one record per run to its trajectory file;
the CI smoke steps used to each carry a copy-pasted inline Python block
asserting the append happened and the record is sane. This script is that
check, once, parameterized by bench name:

    python benchmarks/check_append.py tier energy store

Each check asserts (a) the trajectory exists and is a non-empty list and
(b) the latest record carries the bench's invariants — the same
assertions the inline blocks made, plus the new store contract.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _load(name: str) -> tuple[list, dict]:
    path = ROOT / f"BENCH_{name}.json"
    assert path.exists(), f"{path.name} missing: the {name} bench did " \
        f"not append a record"
    hist = json.loads(path.read_text())
    assert isinstance(hist, list) and hist, \
        f"{path.name} holds no records"
    rec = hist[-1]
    # the obs digest is additive — old rows without one still load — but
    # when present it must carry the diffable schema the explainer reads
    obs = rec.get("obs")
    if obs is not None:
        assert isinstance(obs.get("v"), int), obs
        assert isinstance(obs.get("snapshot"), dict), obs
        assert isinstance(obs.get("categories"), dict), obs
        assert isinstance(obs.get("queries"), int), obs
    return hist, rec


def check_kernels() -> str:
    hist, rec = _load("kernels")
    assert rec["tuned_gbps"] > 0 and rec["speedup"] > 0, rec
    return (f"{len(hist)} record(s), last: {rec['op']} "
            f"{rec['default_gbps']}->{rec['tuned_gbps']} GB/s "
            f"({rec['speedup']}x)")


def check_queries() -> str:
    hist, rec = _load("queries")
    assert rec["scan_agg_gbps"] > 0 and rec["n_shards"] >= 1, rec
    assert rec["sla_vs_load"], rec
    g = rec["grouped"]
    assert g["sharded_rows_per_s"] > 0 and g["sharded_groups"] > 0, g
    cards = g["cardinality"]
    assert len(cards) >= 3, cards
    strategies = {c["strategy"] for c in cards.values()}
    assert strategies == {"dense", "fallback"}, \
        f"cardinality sweep should cross the dense cutoff: {cards}"
    assert g["rle_pregrouped_us"] < g["hash_fallback_us"], \
        (f"fused RLE run-accumulation did not beat the hash fallback on a "
         f"sorted low-cardinality key: {g['rle_pregrouped_us']} vs "
         f"{g['hash_fallback_us']} us")
    assert g["rle_launches_per_query"] == 1, \
        f"count-only RLE rollup should be ONE batched launch: {g}"
    assert g["fallback_launches_during_rle"] == 0, \
        f"RLE path fell back to the host sort/hash: {g}"
    return (f"{len(hist)} record(s), last: {rec['n_shards']} shards, "
            f"{rec['scan_agg_gbps']} GB/s, grouped rle "
            f"{g['speedup']}x vs fallback")


def check_tier() -> str:
    hist, rec = _load("tier")
    assert set(rec["policies"]) == {"static", "cache", "memcache"}, rec
    return f"{len(hist)} record(s), last: " + str(
        {p: v[str(1.1)] for p, v in rec["policies"].items()})


def check_energy() -> str:
    hist, rec = _load("energy")
    capped = rec["replay"]["capped"]
    assert capped["budget_utilization"] <= 1 + 1e-9, capped
    assert any(rec["surface"]["winners"].values()), rec["surface"]
    return f"{len(hist)} record(s), capped replay: {capped}"


def check_store() -> str:
    hist, rec = _load("store")
    assert rec["ratio"] > 1.0, rec
    tr = rec["trace"]
    assert tr["physical_bytes"] <= 0.5 * tr["logical_bytes"], \
        f"compressed trace streams more than half the logical bytes: {tr}"
    tier = rec["tier"]
    assert tier["encoded_hit_rate"] > tier["plain_hit_rate"], \
        f"compression did not improve the fast-tier hit rate: {tier}"
    surf = rec["surface"]
    assert surf["verdict_ratio1_10ms"] == "die-stacked", surf
    assert surf["crossover_ratio_10ms"] is not None, surf
    la = rec["launches"]
    assert la["per_query"] < la["n_chunks"], \
        (f"batched execution should launch fewer kernels per query than "
         f"the table has chunks: {la}")
    assert rec["encoded_us_per_query"] <= rec["plain_us_per_query"], \
        (f"warm encoded replay slower than plain: "
         f"{rec['encoded_us_per_query']} vs {rec['plain_us_per_query']} us")
    ov = rec["overlap"]
    pipelined = [p["pipelined_gbps"] for p in ov["points"]]
    assert pipelined == sorted(pipelined), \
        f"blended GB/s should rise with the fast fraction: {ov['points']}"
    for p in ov["points"]:
        assert p["pipelined_s"] <= p["sync_s"] * (1 + 1e-9), \
            f"prefetch overlap made the replay slower: {p}"
        assert p["prefetch_reserved_bytes"] <= p["fast_capacity_bytes"], \
            f"staging buffer exceeds the fast tier: {p}"
        assert p["staged_chunks"] > 0, f"pipeline never staged a chunk: {p}"
    return (f"{len(hist)} record(s), ratio={rec['ratio']}, "
            f"hit {tier['plain_hit_rate']}->{tier['encoded_hit_rate']}, "
            f"launches/q={la['per_query']}(chunks={la['n_chunks']}), "
            f"overlap {ov['points'][0]['sync_gbps']}->"
            f"{ov['points'][-1]['pipelined_gbps']} GB/s, "
            f"crossover@10ms={surf['crossover_ratio_10ms']}")


def check_resilience() -> str:
    hist, rec = _load("resilience")
    sweep = rec["sweep"]
    recovered = [k for k in next(iter(sweep.values())) if k != "norecover"]
    assert recovered, rec
    for rate, per in sweep.items():
        base = per["norecover"]["attainment"]
        if float(rate) == 0.0:
            # a fault-free chaos run is the plain tiered path: recovery
            # machinery idle, attainment identical
            assert all(per[p]["attainment"] == base for p in recovered), per
            continue
        for p in recovered:
            assert per[p]["attainment"] > base, \
                (f"recovery policy {p!r} did not beat the no-recovery "
                 f"baseline at fault rate {rate}: {per}")
            assert per[p]["degraded"] == 0, per
            assert per[p]["mttr_ms"] is not None, per
            assert per[p]["recovery_bytes"] > 0, per
    worst = max((r for r in sweep if float(r) > 0), key=float)
    per = sweep[worst]
    return (f"{len(hist)} record(s), rate={worst}: "
            + ", ".join(f"{p}={per[p]['attainment']}"
                        for p in ["norecover"] + recovered))


CHECKS = {
    "kernels": check_kernels,
    "queries": check_queries,
    "tier": check_tier,
    "energy": check_energy,
    "store": check_store,
    "resilience": check_resilience,
}


def main(argv=None) -> None:
    names = (argv if argv is not None else sys.argv[1:]) or []
    unknown = [n for n in names if n not in CHECKS]
    if not names or unknown:
        raise SystemExit(f"usage: check_append.py <bench>... ; benches: "
                         f"{sorted(CHECKS)}"
                         + (f" (unknown: {unknown})" if unknown else ""))
    for n in names:
        print(f"BENCH_{n}.json: {CHECKS[n]()}")


if __name__ == "__main__":
    main()
