"""Tiered placement walkthrough: a skewed trace beats STATIC under 10 ms.

The paper's die-stacked tier is bandwidth-rich but capacity-poor; here a
table gets only 25% of its bytes in the fast tier and the placement engine
(repro.tier) decides which column chunks live there. A zipfian multi-
tenant trace then shows the Bakhshalipour trichotomy live: STATIC pinning
(memory-style) wastes the fast tier on cold columns it picked blind, while
MEMCACHE's frequency-aware admission follows the heat and meets a 10 ms
per-query SLA far more often — same queries, bit-identical answers, only
placement differs.

Scale note: this demo table is a miniature (a few hundred KiB), so the
tier rates are scaled down with it — the fast tier runs at 16 MB/s so that
the 10 ms SLA sits exactly where the paper's question lives: between the
all-fast service time and the 2.5x slower (Table 1 bandwidth ratio)
capacity-only service time. The fractions, ratios, and policies are the
real thing; only the absolute bytes are shrunk to keep the walkthrough
instant.

Run: PYTHONPATH=src python examples/tiered_store.py
"""
import numpy as np

from repro.core.advisor import advise_tier_split
from repro.db import Table
from repro.query import physical
from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                        replay_trace, zipf_hit_curve)

SLA_S = 0.010
FAST_GBPS = 0.016        # demo-scaled die-stacked rate (see module note)
N_COLS, N_ROWS = 16, 32768
SKEW = 1.2


def main():
    table = Table.synthetic(
        "events", N_ROWS, {f"c{i:02d}": 8 for i in range(N_COLS)}, seed=0)
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=FAST_GBPS)
    trace = make_trace(table, TraceSpec(n_queries=300, skew=SKEW, seed=11))
    print(f"table: {N_COLS} columns x {N_ROWS} rows = "
          f"{table.nbytes / 1024:.0f} KiB; fast tier holds 25% at "
          f"{tiers.fast.gbps * 1e3:.0f} MB/s, capacity tier at "
          f"{tiers.capacity.gbps * 1e3:.0f} MB/s (Table 1 ratio 2.5x)")
    print(f"trace: {len(trace)} queries, zipf({SKEW}) column popularity, "
          f"{SLA_S * 1e3:.0f} ms SLA\n")

    results = {}
    print(f"{'policy':<10} {'hit rate':>8} {'blended':>10} "
          f"{'SLA attainment':>15} {'energy':>10}")
    for policy in (Policy.STATIC, Policy.CACHE, Policy.MEMCACHE):
        pe, eng, att = replay_trace(table, trace, tiers, policy,
                                    sla_s=SLA_S, chunk_rows=1024)
        s = eng.summary()["tier"]
        results[policy] = att
        print(f"{policy.value:<10} {pe.hit_rate:>8.2f} "
              f"{s['blended_gbps'] * 1e3:>7.1f}MB/s {att:>15.2f} "
              f"{s['energy_j'] * 1e6:>8.1f}uJ")

    assert results[Policy.MEMCACHE] > results[Policy.STATIC], \
        "frequency-aware placement should beat blind static pinning"

    bytes_typ = np.mean([
        physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                  table.columns) for tq in trace])
    adv = advise_tier_split(
        table.nbytes, float(bytes_typ), SLA_S,
        hit_curve=zipf_hit_curve(N_COLS, SKEW),
        fast_gbps=tiers.fast.gbps, capacity_gbps=tiers.capacity.gbps)
    best = adv["best"]
    print(f"\nadvise_tier_split: meet {SLA_S * 1e3:.0f} ms with the hottest "
          f"{best['fast_fraction']:.0%} of the table in the fast tier "
          f"(blended {best['blended_gbps'] * 1e3:.1f} MB/s, within the "
          f"datasheet Eq. 4 roofline: {adv['fast_within_roofline']})")


if __name__ == "__main__":
    main()
