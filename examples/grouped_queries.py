"""Grouped queries walkthrough: GROUP BY and joins over compressed data.

The paper's "big data workloads" are not single-column scans — they are
grouped aggregation and joins. This walkthrough runs both through the
`kernels/group_aggregate` family and shows where the compressed store
changes the execution strategy, not just the byte count:

- an RLE run over a sorted low-cardinality group key is *pre-grouped*:
  a run of length n contributes n to one group's count in registers —
  no scatter, ONE batched kernel launch for the whole table (the launch
  counters prove it);
- a FOR frame bounds the key range, so a dense int32 accumulator plane
  replaces the hash table while the domain stays under
  `DENSE_MAX_GROUPS`;
- past the cutoff, chunks take the host sort/hash fallback — the
  strategy cliff the decision surface's grouped-mix axis prices.

Every path lands in one exact host-partial algebra, so results are
bit-identical to a numpy oracle whichever strategy ran.

Run: PYTHONPATH=src:. python examples/grouped_queries.py
"""
import time

import numpy as np

from repro.db.columnar import BitPackedColumn, Table
from repro.energy.tco import decision_surface
from repro.kernels import dispatch
from repro.query import GroupBy, HashJoin, Pred, QueryEngine, relational
from repro.store import EncodedTable
from repro.store.exec import execute_grouped_encoded

N_ROWS, CHUNK_ROWS = 1 << 17, 4096


def main():
    rng = np.random.default_rng(0)
    t = Table("facts")
    t.add(BitPackedColumn.from_values(          # sorted low-card -> RLE
        "region", np.sort(rng.integers(0, 12, N_ROWS)), 8))
    t.add(BitPackedColumn.from_values(          # clustered -> FOR
        "day", 40 + rng.integers(0, 8, N_ROWS), 8))
    t.add(BitPackedColumn.from_values(          # uniform value column
        "sales", rng.integers(0, 120, N_ROWS), 8))
    store = EncodedTable.from_table(t, chunk_rows=CHUNK_ROWS)

    # --- GROUP BY through the engine, bit-exact vs the numpy oracle ----
    q = GroupBy("region", ("sales",), where=Pred("day", "lt", 45))
    eng = QueryEngine(store)
    eng.submit(q)
    (res,) = eng.run()
    assert res.aggregates == relational.execute_grouped_oracle(q, t)
    print(f"GROUP BY region: {len(res.aggregates['groups'])} groups over "
          f"{res.count} selected rows (physical {res.bytes_scanned} B of "
          f"{res.logical_bytes} B logical)")
    top = max(res.aggregates["groups"].items(),
              key=lambda kv: kv[1]["sums"]["sales"])
    print(f"  busiest region {top[0]}: count={top[1]['count']} "
          f"sum(sales)={top[1]['sums']['sales']}\n")

    # --- the RLE pre-grouped path: one launch, no scatter --------------
    hist = GroupBy("region")                    # count-only histogram
    execute_grouped_encoded(hist, store, mode="xla_ref")       # warm
    before = dict(dispatch.launch_counts())
    t0 = time.perf_counter()
    got = execute_grouped_encoded(hist, store, mode="xla_ref")
    rle_s = time.perf_counter() - t0
    launches = {k: v - before.get(k, 0)
                for k, v in dispatch.launch_counts().items()
                if v != before.get(k, 0)}
    print(f"count-only histogram on the RLE key: launches={launches} "
          f"({store.n_chunks} chunks) in {rle_s * 1e3:.1f} ms")

    # force the sort/hash fallback on the same bytes (the strategy knob)
    from repro.kernels.group_aggregate import ops as gops
    saved = relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS
    try:
        relational.DENSE_MAX_GROUPS = gops.DENSE_MAX_GROUPS = 0
        t0 = time.perf_counter()
        fb = execute_grouped_encoded(hist, store, mode="xla_ref")
        fb_s = time.perf_counter() - t0
    finally:
        relational.DENSE_MAX_GROUPS, gops.DENSE_MAX_GROUPS = saved
    assert fb == got
    print(f"same query, forced sort/hash fallback: {fb_s * 1e3:.1f} ms "
          f"-> pre-grouped RLE is {fb_s / rle_s:.1f}x faster\n")

    # --- hash join: build side broadcast, probe keys clipped -----------
    dim = Table("dim_region")
    dim.add(BitPackedColumn.from_values(
        "region", np.array([0, 3, 7, 11]), 8))
    j = HashJoin(dim, "region", "region", aggs=("sales",))
    jres = execute_grouped_encoded(j, store)
    assert jres == relational.execute_grouped_oracle(j, t)
    print(f"join vs 4-row dim table: groups={sorted(jres['groups'])} "
          f"({jres['count']} rows matched)")

    # --- the grouped-mix axis of the decision surface ------------------
    surf = decision_surface(
        16 * (1 << 40), 1 << 30, grouped_mixes=(0.0, 0.5),
        grouped_bytes_per_query=3 * (1 << 30))
    for mix in (0.0, 0.5):
        cells = [c for c in surf["cells"] if c["grouped_mix"] == mix
                 and c["winner"] is not None]
        wins = {}
        for c in cells:
            wins[c["winner"]] = wins.get(c["winner"], 0) + 1
        print(f"decision surface @ grouped_mix={mix}: winners {wins}")


if __name__ == "__main__":
    main()
