"""SLO burn-rate monitoring on a chaos replay: deterministic alerts.

The observability-analysis walkthrough (PR 10): replay a seeded fault
trace with an `SLOMonitor` (and a `Tracer`) attached, and

1. prove determinism — two full rebuild-and-replay runs of the same
   seeded chaos workload emit **byte-identical** SLO alert streams
   (`alerts_json()`): the monitor samples only at VirtualClock cadence
   ticks whose timestamps are computed, never accumulated;
2. print the alert timeline — which tenants' error budgets burned, when
   each multi-window rule fired and resolved, at which burn rates;
3. attribute the misses — `repro.obs.critical_path.verify` reconciles
   every query's path against the span totals and the EnergyMeter
   ledger, then reports which span category owned the SLA-miss time
   ("capacity reads X%, recovery Y%, throttle Z%");
4. close the loop — `repro.core.advisor.whatif_fast_fraction` converts
   that attribution into the estimated gain from a bigger fast tier,
   cross-checked against the advise_tier_split decision surface.

Run:  PYTHONPATH=src python examples/slo_monitor.py
"""
from __future__ import annotations

from repro.core.advisor import whatif_fast_fraction
from repro.db import Table
from repro.obs import SLOMonitor, Tracer, verify
from repro.query import physical
from repro.resilience import (ChaosHarness, ChunkGuard, FaultSpec,
                              RetryPolicy)
from repro.store import EncodedTable
from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                        replay_trace, zipf_hit_curve)

N_COLS, N_ROWS, CHUNK_ROWS = 8, 8192, 512
FAST_FRACTION = 0.25
SPEC = FaultSpec(seed=42, stall_rate=0.1, corrupt_rate=0.05)
TARGET = 0.90             # 90% attainment SLO -> 10% error budget


def monitored_run():
    """One fault-injected replay with monitoring + tracing on; rebuilt
    from scratch so injected corruption never leaks between runs (the
    same discipline as examples/chaos_replay.py / trace_query.py)."""
    table = Table.synthetic(
        "events", N_ROWS, {f"c{i:02d}": 8 for i in range(N_COLS)}, seed=0)
    encoded = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    tiers = paper_tiers(table.nbytes * FAST_FRACTION, fast_gbps=0.016)
    qtrace = make_trace(table, TraceSpec(n_queries=120, skew=1.2, seed=11))
    clean_s = (encoded.nbytes
               / sum(len(c.chunks) for c in encoded.columns.values())
               / tiers.fast.bandwidth)
    chaos = ChaosHarness(SPEC, guard=ChunkGuard(encoded),
                         retry=RetryPolicy(timeout_s=2.0 * clean_s,
                                           backoff_s=0.5 * clean_s,
                                           max_retries=2))
    chaos.inject_corruption()
    bytes_typ = sum(
        physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                  encoded.columns)
        for tq in qtrace) / len(qtrace)
    sla_s = 2.5 * bytes_typ / tiers.fast.bandwidth
    tracer = Tracer()
    monitor = SLOMonitor(target=TARGET, cadence_s=sla_s / 2)
    pe, eng, att = replay_trace(
        encoded, qtrace, tiers, Policy.CACHE, sla_s=sla_s,
        chunk_rows=CHUNK_ROWS, chaos=chaos,
        prefetch_bytes=table.nbytes // 16, tracer=tracer, monitor=monitor)
    # flush the burn windows past the last completion so every rule gets
    # its resolve tick (still modeled time — one deterministic horizon)
    monitor.tick(eng.clock() + monitor.max_window_s)
    return monitor, tracer, pe, eng, att, bytes_typ, sla_s, table


def main():
    monitor, tracer, pe, eng, att, bytes_typ, sla_s, table = \
        monitored_run()
    alerts = monitor.alerts_json()

    # 1. determinism: a second full rebuild emits the same alert bytes
    monitor2 = monitored_run()[0]
    assert monitor2.alerts_json() == alerts, \
        "seeded chaos replay produced a different SLO alert stream"
    print(f"replay x2 -> byte-identical alert stream "
          f"({len(alerts)} bytes, {len(monitor.alerts)} alerts, "
          f"{monitor.summary()['ticks']} ticks, attainment={att:.2f})")

    # 2. the alert timeline: deterministic virtual timestamps
    for a in monitor.alerts[:12]:
        print(f"  t={a.t * 1e3:9.3f}ms {a.kind:<7s} {a.rule:<9s} "
              f"tenant={a.tenant} burn_long={a.burn_long:.2f} "
              f"burn_short={a.burn_short:.2f} "
              f"budget_left={a.budget_remaining:+.2f}")
    if len(monitor.alerts) > 12:
        print(f"  ... {len(monitor.alerts) - 12} more alerts")
    for tenant, budget in monitor.summary()["tenants"].items():
        print(f"  tenant {tenant}: {budget['errors']}/{budget['events']} "
              f"errors, budget remaining {budget['remaining_fraction']:+.2f}")

    # 3. critical-path attribution, reconciled against the audit
    attr = verify(tracer, pe.meter)    # raises ConservationError on leak
    print(f"\n{attr.render()}")

    # 4. what buying more fast tier would do about it
    wi = whatif_fast_fraction(
        attr, db_bytes=table.nbytes, bytes_per_query=bytes_typ,
        sla_s=sla_s, current_fraction=FAST_FRACTION,
        hit_curve=zipf_hit_curve(N_COLS, 1.2),
        fast_gbps=pe.tiers.fast.gbps, capacity_gbps=pe.tiers.capacity.gbps)
    best = wi["best"]
    cur = wi["current"]
    print(f"\nwhat-if (cross-checked vs advise_tier_split): "
          f"current fraction {cur['fast_fraction']:.2f} -> "
          f"response {cur['response_s'] * 1e3:.3f}ms")
    if best is not None:
        print(f"  first SLA-meeting fraction: {best['fast_fraction']:.2f} "
              f"(est response {best['est_response_s'] * 1e3:.3f}ms, "
              f"gain {best['est_gain_s'] * 1e3:+.3f}ms/query)")
    else:
        biggest = wi["rows"][-1]
        print(f"  no fraction meets the SLA; even f="
              f"{biggest['fast_fraction']:.2f} estimates "
              f"{biggest['est_response_s'] * 1e3:.3f}ms — the misses are "
              f"not read-rate-bound (see attribution above)")


if __name__ == "__main__":
    main()
