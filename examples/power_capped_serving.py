"""Power-capped serving walkthrough: the paper's 50x power verdict, live.

The die-stacked tier is fast but hot — the paper's conclusion is that its
power (up to 50x higher) is what decides "when to use" it. This demo runs
the same zipfian multi-tenant trace three ways over a tiered table:

1. *uncapped*: the energy meter bills every query its per-tier byte
   joules plus compute watts over modeled busy time — the demand power;
2. *capped at 70%*: a PowerCap governor guarantees no sliding window ever
   averages above budget, by stretching service (race-to-idle derating)
   and feeding the derated estimate into EDF admission — queries that
   cannot meet their deadline at the throttled rate are rejected, never
   silently run over budget. Attainment drops; the watt contract holds;
3. *$/query*: advise_cost names the cheapest architecture for this SLA
   and power envelope, then re-prices it at the metered J/query.

Scale note: like examples/tiered_store.py this is a miniature (table and
rates scaled down together) so the walkthrough is instant; fractions,
ratios, and the governor's guarantee are the real thing.

Run: PYTHONPATH=src python examples/power_capped_serving.py
"""
from repro.core.advisor import advise_cost
from repro.core.systems import TiB
from repro.db import Table
from repro.energy import PowerCap, chip_compute_watts
from repro.core.systems import DIE_STACKED
from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                        replay_trace)

SLA_S = 0.010
FAST_GBPS = 0.016        # demo-scaled die-stacked rate
N_COLS, N_ROWS = 16, 32768
SKEW = 1.2
CAP_FRACTION = 0.7


def main():
    table = Table.synthetic(
        "events", N_ROWS, {f"c{i:02d}": 8 for i in range(N_COLS)}, seed=0)
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=FAST_GBPS)
    trace = make_trace(table, TraceSpec(n_queries=300, skew=SKEW, seed=11))
    compute_w = chip_compute_watts(DIE_STACKED) * 1e-6   # demo-scaled
    print(f"table: {table.nbytes / 1024:.0f} KiB, fast tier 25% at "
          f"{tiers.fast.gbps * 1e3:.0f} MB/s; {len(trace)} queries, "
          f"zipf({SKEW}), {SLA_S * 1e3:.0f} ms SLA\n")

    pe, eng, att = replay_trace(table, trace, tiers, Policy.MEMCACHE,
                                sla_s=SLA_S, chunk_rows=1024,
                                compute_w=compute_w)
    e = eng.summary()["energy"]
    demand_w = e["total_j"] / eng.seconds_total
    print(f"uncapped:   attainment {att:.2f}, demand {demand_w * 1e6:.1f} uW, "
          f"{e['j_per_query'] * 1e6:.2f} uJ/query "
          f"(memory {e['memory_j'] / e['total_j']:.0%}, "
          f"compute {e['compute_j'] / e['total_j']:.0%})")

    cap = PowerCap(budget_w=CAP_FRACTION * demand_w, window_s=20 * SLA_S)
    _, ceng, catt = replay_trace(table, trace, tiers, Policy.MEMCACHE,
                                 sla_s=SLA_S, chunk_rows=1024,
                                 compute_w=compute_w, power_cap=cap)
    rep = cap.report(now=ceng.clock())
    print(f"capped 70%: attainment {catt:.2f}, peak window "
          f"{rep['max_window_w'] * 1e6:.1f} uW <= budget "
          f"{cap.budget_w * 1e6:.1f} uW "
          f"(utilization {rep['budget_utilization']:.2f}, "
          f"{rep['throttled_queries']} throttled, "
          f"{ceng.summary()['rejected']} rejected)")
    assert rep["max_window_w"] <= cap.budget_w * (1 + 1e-9)

    bill = ceng.summary()["energy"]["by_tenant"]
    print("\nper-tenant bill (uJ):",
          {t: round(v["total_j"] * 1e6, 2) for t, v in sorted(bill.items())})

    # the full-scale question the miniature stands in for
    cell = advise_cost(16 * TiB, 0.2 * 16 * TiB, SLA_S, 1e6, skew=SKEW)
    verdict = (f"winner={cell['winner']} at "
               f"${cell['usd_per_query']:.4f}/query"
               if cell["winner"] else "nothing feasible at this budget")
    print(f"\nadvise_cost @ 16 TiB, {SLA_S * 1e3:.0f} ms, 1 MW: {verdict}")
    for c in cell["candidates"]:
        print(f"  {c['name']:<12} power={c['power_w'] / 1e3:7.1f} kW  "
              f"${c['usd_per_query']:.4f}/q  feasible={c['feasible']}")


if __name__ == "__main__":
    main()
