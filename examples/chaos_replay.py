"""Chaos replay walkthrough: the same seeded fault trace, twice, to the bit.

A chaos run here is a pure function of (workload, FaultSpec, RetryPolicy):
every stall, bit-flip, and retry decision is drawn order-independently
from the spec's seed and all time is modeled on serve.sla.VirtualClock —
no wall-clock sleeps, no racy nondeterminism. That is what makes fault
drills debuggable: a failure seen once can be replayed exactly, and a fix
can be verified against the *same* fault trace rather than a new roll of
the dice.

The walkthrough corrupts chunk payloads and stalls fast-tier reads over a
skewed trace, replays the whole thing twice from the same seed, and
asserts the two runs agree bit-for-bit: same attainment, same retry /
repair / failover counts, same recovery joules, same answers. A third run
with recovery disabled shows what the machinery buys — typed-degraded
queries and ridden-out stalls drop attainment, but never a silent wrong
answer.

Run: PYTHONPATH=src python examples/chaos_replay.py
"""
from repro.db import Table
from repro.query import physical
from repro.resilience import ChaosHarness, ChunkGuard, FaultSpec, RetryPolicy
from repro.store import EncodedTable
from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                        replay_trace)

N_COLS, N_ROWS, CHUNK_ROWS = 8, 8192, 512
SPEC = FaultSpec(seed=42, stall_rate=0.1, corrupt_rate=0.05)
SLA_SLACK = 2.5


def chaos_run(recover: bool):
    """One full fault-injected replay; rebuilt from scratch so injected
    corruption never leaks between runs — determinism comes from seeds,
    not shared state."""
    table = Table.synthetic(
        "events", N_ROWS, {f"c{i:02d}": 8 for i in range(N_COLS)}, seed=0)
    encoded = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=0.016)
    trace = make_trace(table, TraceSpec(n_queries=120, skew=1.2, seed=11))
    clean_s = (encoded.nbytes
               / sum(len(c.chunks) for c in encoded.columns.values())
               / tiers.fast.bandwidth)
    chaos = ChaosHarness(SPEC, guard=ChunkGuard(encoded), recover=recover,
                         retry=RetryPolicy(timeout_s=2.0 * clean_s,
                                           backoff_s=0.5 * clean_s,
                                           max_retries=2))
    chaos.inject_corruption()
    bytes_typ = sum(
        physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                  encoded.columns)
        for tq in trace) / len(trace)
    sla_s = SLA_SLACK * bytes_typ / tiers.fast.bandwidth
    pe, eng, att = replay_trace(encoded, trace, tiers, Policy.CACHE,
                                sla_s=sla_s, chunk_rows=CHUNK_ROWS,
                                chaos=chaos)
    answers = [(r.qid, r.degraded, tuple(sorted(
        (k, tuple(sorted(v.items()))) for k, v in r.aggregates.items())))
        for r in eng.results]
    return {"attainment": att, "summary": chaos.summary(),
            "recovery_j": pe.meter.recovery_j, "answers": answers}


def main():
    first = chaos_run(recover=True)
    second = chaos_run(recover=True)
    assert first == second, "seeded chaos replay diverged between runs"
    s = first["summary"]
    print(f"fault spec: {s['spec']}")
    print(f"replay x2 -> identical verdicts: attainment="
          f"{first['attainment']:.2f}, stalls={s['stalls']}, "
          f"retries={s['retries']}, failovers={s['failovers']}, "
          f"repairs={s['repairs']}, "
          f"recovery={first['recovery_j'] * 1e6:.2f}uJ, "
          f"mttr={s['mttr_s'] * 1e3:.3f}ms")

    degraded = chaos_run(recover=False)
    d = degraded["summary"]
    print(f"recovery off  -> attainment={degraded['attainment']:.2f}, "
          f"degraded_queries={d['degraded_queries']} "
          f"(typed errors, never silent partial sums)")
    assert first["attainment"] > degraded["attainment"], \
        "recovery should buy attainment under the same faults"
    assert d["degraded_queries"] > 0 and s["degraded_queries"] == 0
    print("\nsame seed, same faults, same verdict — chaos drills here are "
          "replayable evidence, not flaky noise")


if __name__ == "__main__":
    main()
