"""Streaming long-context prefill: the `long_500k` story, runnable on CPU.

Sub-quadratic archs (mamba2, recurrentgemma, mixtral-SWA) process
arbitrarily long contexts as a stream of fixed-size segments with O(1)
carried state — the bandwidth-capacity argument in its purest form: the
memory a chip must hold (and re-read per token) is *constant* in context
length, while full-attention archs grow linearly.

This driver streams a long synthetic context through a reduced mamba2 in
segments, verifying the segmented pass is numerically identical to the
monolithic pass, then prints the per-token decode state sizes for the full
configs (what the long_500k dry-run cells shard).

  PYTHONPATH=src python examples/long_context_stream.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import traffic
from repro.models import lm
from repro.models.common import dtype_of

ARCH = "mamba2-1.3b"
SEGMENT = 128
TOTAL = 1024

cfg = get_config(ARCH).reduced(dtype="float32")
params, _ = lm.init(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, TOTAL), 0,
                            cfg.vocab_size)

# monolithic pass
mono, _, _ = lm.prefill(params, cfg, tokens, caches=None)

# streaming pass: state handoff between segments
caches, _ = lm.init_caches(cfg, 1, SEGMENT, dtype_of(cfg.dtype))
outs = []
for s0 in range(0, TOTAL, SEGMENT):
    seg = tokens[:, s0:s0 + SEGMENT]
    positions = jnp.arange(s0, s0 + SEGMENT, dtype=jnp.int32)[None]
    logits, caches, _ = lm.apply(params, cfg, seg, positions, caches=caches)
    outs.append(logits)
stream = jnp.concatenate(outs, axis=1)

err = float(jnp.max(jnp.abs(mono - stream)))
print(f"{ARCH}: streamed {TOTAL} tokens in {TOTAL//SEGMENT} segments of "
      f"{SEGMENT}; max |logit diff| vs monolithic = {err:.2e}")
assert err < 1e-3, err

print("\nper-row decode state at 524,288-token context (full configs):")
rows = {}
for arch in ("mamba2-1.3b", "recurrentgemma-2b", "mixtral-8x22b",
             "llama3-405b"):
    c = get_config(arch)
    state = traffic._state_bytes_per_row(c)
    kv = traffic._kv_bytes_per_row(c, 524288)
    rows[arch] = (state + kv) / 1e9
    note = "constant in context" if c.subquadratic else "grows with context"
    print(f"  {arch:22s} {rows[arch]:10.3f} GB/row   ({note})")
print(f"\n-> the long_500k dry-run cells run only for the sub-quadratic "
      f"archs; llama3-405b would need {rows['llama3-405b']:.0f} GB of KV "
      f"per row ({rows['llama3-405b']/rows['mamba2-1.3b']:.0f}x mamba2's "
      f"constant state).")
