"""Walk the paper end-to-end: reproduce every figure's headline numbers,
then the beyond-paper TPU rooflines from the dry-run artifacts.

  PYTHONPATH=src python examples/bandwidth_model_walkthrough.py
"""
import json
from pathlib import Path

from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        power_crossover_sla, provision_capacity,
                        provision_performance, provision_power)
from repro.core.systems import TiB

WL = Workload(16 * TiB, 0.20)

print("— Fig. 1: bandwidth/capacity ratios —")
for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
    print(f"  {s.name:12s} {s.bandwidth_capacity_ratio:8.4f}/s "
          f"(reads 20% of its memory in "
          f"{0.2/s.bandwidth_capacity_ratio*1e3:7.1f} ms)")

print("— Fig. 3 / Table 2: 10 ms SLA —")
for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
    d = provision_performance(s, WL, 0.010)
    print(f"  {s.name:12s} chips={d.compute_chips:5d} blades={d.blades:5d} "
          f"power={d.power/1e3:7.1f}kW overprov=x{d.overprovision_factor:5.1f}")

print("— Fig. 4: 1 MW budget —")
for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
    d = provision_power(s, WL, 1e6)
    print(f"  {s.name:12s} rt={d.response_time*1e3:6.1f}ms "
          f"capacity={d.memory_capacity/TiB:7.0f}TiB")

print("— Fig. 5/6: capacity-provisioned 16 TiB —")
for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
    d = provision_capacity(s, WL)
    print(f"  {s.name:12s} rt={d.response_time*1e3:7.1f}ms "
          f"power={d.power/1e3:7.1f}kW energy={d.energy_per_query:6.0f}J")

t = power_crossover_sla(TRADITIONAL, DIE_STACKED, WL)
print(f"— §5.1 crossover: die-stacked is power-cheaper below "
      f"{t*1e3:.0f} ms SLA (paper: ~60 ms) —")

art = Path(__file__).resolve().parents[1] / "artifacts/dryrun/single"
if art.exists():
    print("\n— beyond-paper: TPU roofline (single pod, from dry-run) —")
    for p in sorted(art.glob("*__train_4k.json")):
        c = json.loads(p.read_text())
        if c.get("status") != "ok" or "roofline" not in c:
            continue
        r, u = c["roofline"], c["utilization"]
        print(f"  {c['arch']:22s} dom={r['dominant']:10s} "
              f"mfu={u['roofline_mfu']:.3f} "
              f"(compute {r['compute_s']*1e3:8.1f}ms / mem "
              f"{r['memory_s']*1e3:7.1f}ms / coll "
              f"{r['collective_s']*1e3:8.1f}ms)")
