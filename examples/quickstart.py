"""Quickstart: the three layers of the framework in two minutes.

  PYTHONPATH=src python examples/quickstart.py

1. the paper's analytical model (when is die-stacked memory worth it?),
2. the paper's workload (bit-packed scan+aggregate through Pallas kernels),
3. the modern workload (train a tiny assigned-architecture LM).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        provision_capacity, provision_performance)
from repro.core.systems import TiB
from repro.db import Predicate, Table, scan_aggregate_query
from repro.models import lm
from repro.train import optim, step as step_lib

print("=" * 70)
print("1. The paper's model: 16 TiB in-memory analytics, 20% per query")
print("=" * 70)
wl = Workload(db_size=16 * TiB, percent_accessed=0.20)
for system in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
    d = provision_capacity(system, wl)
    print(f"  {system.name:12s} response={d.response_time*1e3:8.1f}ms  "
          f"power={d.power/1e3:7.1f}kW  chips={d.compute_chips}")
d10 = provision_performance(DIE_STACKED, wl, sla=0.010)
print(f"  -> 10ms SLA: die-stacked needs {d10.compute_chips} stacks, "
      f"{d10.power/1e3:.0f} kW, overprovision x{d10.overprovision_factor:.1f}")

print()
print("=" * 70)
print("2. The paper's workload: scan+aggregate on bit-packed columns")
print("=" * 70)
table = Table.synthetic("sales", 1 << 18, {"price": 16, "region": 8})
result = scan_aggregate_query(
    table, [Predicate("region", "lt", 32)], agg_column="price")
print(f"  rows={table.num_rows:,} bytes={table.nbytes/1e6:.1f}MB")
print(f"  SELECT sum(price) WHERE region < 32 -> sum={int(result['sum']):,} "
      f"count={int(result['count']):,} "
      f"selectivity={float(result['selectivity']):.3f}")

print()
print("=" * 70)
print("3. The modern workload: train a reduced assigned arch (mamba2)")
print("=" * 70)
cfg = get_config("mamba2-1.3b").reduced(dtype="float32")
opt_cfg = optim.AdamWConfig(lr=3e-3, warmup_steps=2, decay_steps=50)
state, _ = step_lib.init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
step = jax.jit(step_lib.make_train_step(cfg, opt_cfg))
key = jax.random.PRNGKey(1)
batch = {
    "inputs": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
    "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
}
for i in range(5):
    state, metrics = step(state, batch)
    print(f"  step {i+1}  loss={float(metrics['loss']):.4f}")
print("done.")
