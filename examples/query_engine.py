"""Sharded SLA-aware query engine, end to end.

  PYTHONPATH=src python examples/query_engine.py

Builds a bit-packed analytic table, shards it across every available device
(run with XLA_FLAGS=--xla_force_host_platform_device_count=8 for a mesh on
CPU), executes AND/OR/mixed-width plans under deadlines through the EDF
scheduler, then closes the paper's loop: measured scan throughput vs the
analytical model's roofline, and a cluster provisioned from *attained*
(not datasheet) throughput.
"""
import jax

from repro.db import Table
from repro.launch.mesh import make_mesh
from repro.query import Pred, Query, QueryEngine, ShardedTable

print("=" * 70)
print("1. A sharded in-memory analytic table")
print("=" * 70)
table = Table.synthetic("sales", 1 << 20,
                        {"price": 16, "region": 8, "qty": 8}, seed=0)
n_dev = len(jax.devices())
mesh = make_mesh((n_dev,), ("data",))
st = ShardedTable.shard(table, mesh)
print(f"  rows={table.num_rows:,}  packed={table.nbytes/1e6:.1f}MB  "
      f"shards={st.n_shards}  rows/shard={st.rows_per_shard:,}")

print()
print("=" * 70)
print("2. Deadline-batched queries (logical plans -> dispatch kernels)")
print("=" * 70)
engine = QueryEngine(st, mode="auto", est_gbps=0.5)
queries = {
    "cheap & west": Query(Pred("price", "lt", 5000)
                          & Pred("region", "lt", 32),
                          aggregates=("price",)),
    "bulk | luxury": Query(Pred("qty", "ge", 100)
                           | Pred("price", "ge", 30000),
                           aggregates=("price", "qty")),
    "fused single-pred": Query(Pred("qty", "lt", 64), aggregates=("qty",)),
}
t0 = engine.clock()
for name, q in queries.items():
    engine.submit(q, deadline=t0 + 30.0)
for name, res in zip(queries, engine.run()):
    price = res.aggregates[res.query.aggregates[0]]
    print(f"  {name:18s} count={res.count:8,}  sel={res.selectivity:.3f}  "
          f"sum={price['sum']:12,}  lat={res.latency_s*1e3:7.1f}ms  "
          f"met={res.met}")
s = engine.summary()
print(f"  -> attainment={s['sla_attainment']:.2f}  "
      f"p99={s['latency_p99_s']*1e3:.1f}ms  "
      f"scan={s['measured_gbps']:.3f} GB/s")

print()
print("=" * 70)
print("3. Model vs measured (the paper's loop, closed)")
print("=" * 70)
mc = engine.model_check()
print(f"  model roofline ({mc['system']}, {mc['chips']} chips): "
      f"{mc['model_gbps']:.0f} GB/s")
print(f"  measured: {mc['measured_gbps']:.3f} GB/s  "
      f"(x{mc['attained_fraction']:.2e} of model — interpret mode on CPU)")
for sla_ms in (10, 100, 1000):
    adv = engine.provision(sla_s=sla_ms / 1e3)
    d = adv.design
    print(f"  provision @ {sla_ms:5d}ms SLA from measured rate: "
          f"{d.compute_chips:6d} chips  {d.power/1e3:8.1f} kW")
