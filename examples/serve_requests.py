"""End-to-end serving driver (the paper-appropriate e2e: response-time SLAs).

Serves a small model with batched requests through the continuous-batching
engine, then asks the advisor what a production cluster for this workload
would look like under the paper's three provisioning regimes.

  PYTHONPATH=src python examples/serve_requests.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import advisor
from repro.models import lm
from repro.serve.engine import Request, ServeEngine

ARCH = "internlm2-1.8b"

cfg = get_config(ARCH).reduced()
params, _ = lm.init(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(cfg, params, batch_slots=4, max_len=128)

rng = np.random.default_rng(0)
requests = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                    max_new_tokens=12) for i in range(10)]

t0 = time.time()
done = engine.run(requests)
wall = time.time() - t0
toks = sum(len(r.generated) for r in done)
print(f"served {len(done)} requests / {toks} tokens in {wall:.2f}s "
      f"({toks/wall:.1f} tok/s on CPU, reduced model)")
for r in done[:3]:
    print(f"  request {r.rid}: prompt={list(r.prompt)[:4]}... "
          f"generated={r.generated}")

print()
print(f"advisor: production cluster for full-scale {ARCH} decode "
      f"(batch=128, 32k ctx):")
full = get_config(ARCH)
for sla in (0.005, 0.020, 0.100):
    a = advisor.advise_decode_sla(full, batch=128, seq_len=32768, sla_s=sla)
    d = a.design
    print(f"  SLA {sla*1e3:5.0f}ms -> {d.compute_chips:5d} chips  "
          f"{d.power/1e3:7.1f} kW  rt={d.response_time*1e3:.2f}ms  "
          f"overprov=x{d.overprovision_factor:.1f}")

print()
print("when to use the TPU (vs DDR5 host cluster), llama3-405b decode:")
for row in advisor.when_to_use_tpu(get_config("llama3-405b"), 128, 32768):
    print(f"  SLA {row['sla_ms']:5.0f}ms  tpu={row['tpu_power_kw']:8.1f}kW "
          f"host={row['host_power_kw']:8.1f}kW  "
          f"tpu_wins={row['tpu_wins_power']}")
