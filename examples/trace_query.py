"""Traced chaos replay: attribute every modeled second, byte, and joule.

The observability walkthrough (PR 9): replay a seeded fault trace with a
`repro.obs.Tracer` attached, and

1. prove determinism — the exported Chrome trace JSON is byte-identical
   across two full rebuild-and-replay runs (spans live on the
   VirtualClock, never the wall clock);
2. run the conservation audit — for every query, span-attributed bytes
   and joules equal the EnergyMeter's kind="query"/"recovery"/"prefetch"
   ledger lines exactly;
3. print the plain-text waterfall of the most fault-afflicted query —
   stalls, retries, repairs, and prefetch streams on one timeline;
4. optionally (`--out trace.json`) write the Perfetto-loadable trace:
   open ui.perfetto.dev > "Open trace file" and browse per-tenant lanes.

Run:  PYTHONPATH=src python examples/trace_query.py [--out trace.json]
"""
from __future__ import annotations

import sys

from repro.db import Table
from repro.obs import (Tracer, check, chrome_trace_json, unified_snapshot,
                       waterfall_query)
from repro.query import physical
from repro.resilience import (ChaosHarness, ChunkGuard, FaultSpec,
                              RetryPolicy)
from repro.store import EncodedTable
from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                        replay_trace)

N_COLS, N_ROWS, CHUNK_ROWS = 8, 8192, 512
SPEC = FaultSpec(seed=42, stall_rate=0.1, corrupt_rate=0.05)


def traced_run():
    """One fault-injected replay with tracing on; rebuilt from scratch so
    injected corruption never leaks between runs (the same discipline as
    examples/chaos_replay.py)."""
    table = Table.synthetic(
        "events", N_ROWS, {f"c{i:02d}": 8 for i in range(N_COLS)}, seed=0)
    encoded = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=0.016)
    qtrace = make_trace(table, TraceSpec(n_queries=120, skew=1.2, seed=11))
    clean_s = (encoded.nbytes
               / sum(len(c.chunks) for c in encoded.columns.values())
               / tiers.fast.bandwidth)
    chaos = ChaosHarness(SPEC, guard=ChunkGuard(encoded),
                         retry=RetryPolicy(timeout_s=2.0 * clean_s,
                                           backoff_s=0.5 * clean_s,
                                           max_retries=2))
    chaos.inject_corruption()
    bytes_typ = sum(
        physical.referenced_bytes(tq.query.plan(), tq.query.aggregates,
                                  encoded.columns)
        for tq in qtrace) / len(qtrace)
    tracer = Tracer()
    pe, eng, att = replay_trace(
        encoded, qtrace, tiers, Policy.CACHE,
        sla_s=2.5 * bytes_typ / tiers.fast.bandwidth,
        chunk_rows=CHUNK_ROWS, chaos=chaos,
        prefetch_bytes=table.nbytes // 16, tracer=tracer)
    return tracer, pe, eng, att


def main():
    out_path = None
    if "--out" in sys.argv:
        out_path = sys.argv[sys.argv.index("--out") + 1]

    tracer, pe, eng, att = traced_run()
    exported = chrome_trace_json(tracer)

    # 1. determinism: a second full rebuild exports the same bytes
    tracer2, _, _, _ = traced_run()
    assert chrome_trace_json(tracer2) == exported, \
        "seeded traced replay diverged between runs"
    s = tracer.summary()
    print(f"replay x2 -> byte-identical trace JSON "
          f"({len(exported)} bytes, {s['queries']} queries, "
          f"{s['spans']} spans)")
    print(f"span kinds: {s['span_kinds']}")

    # 2. conservation: every byte/joule on exactly one ledger line
    report = check(tracer, pe.meter)   # raises ConservationError on leak
    print(f"conservation audit: {len(report.queries)} queries OK — "
          f"span bytes == bytes_scanned + recovery + prefetch lines, "
          f"span joules == EnergyMeter lines (bitwise)")

    # 3. the waterfall of the most fault-afflicted query
    noisy = max(tracer.queries,
                key=lambda qt: sum(n for k, n in qt.span_kinds().items()
                                   if k in ("retry", "failover", "repair",
                                            "stall", "prefetch_stall")))
    print(f"\nmost fault-afflicted query (attainment={att:.2f}):")
    print(waterfall_query(noisy, width=56))

    # one unified snapshot instead of five stats() dialects
    snap = unified_snapshot(eng)
    keys = ["tier.hit_rate", "tier.recovery_bytes",
            "prefetch.streamed_bytes", "prefetch.wasted_bytes",
            "energy.recovery_j", "sla.attainment"]
    print("\nunified snapshot:",
          {k: (round(v, 4) if isinstance(v, float) else v)
           for k, v in snap.items() if k in keys})

    if out_path:
        with open(out_path, "w") as f:
            f.write(exported)
        print(f"\nwrote {out_path} — open in ui.perfetto.dev "
              f"(Open trace file) or chrome://tracing")


if __name__ == "__main__":
    main()
