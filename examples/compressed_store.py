"""Compressed store walkthrough: buying bandwidth in software.

The paper prices one way out of the bandwidth wall — die-stacked DRAM.
This walkthrough runs the other way: `repro.store` encodes a table's
bit-packed columns chunk-by-chunk (RLE for sorted/low-cardinality
columns, frame-of-reference delta packing for clustered ones, plain
where nothing wins) and the query engine scans the *compressed* bytes
directly — RLE runs through the fused `scan_compressed` kernel, FOR
planes through the ordinary BitWeaving kernels at the narrower delta
width. Answers are bit-identical to the plain engine; what changes is
every byte count downstream of the scan:

- `bytes_scanned` becomes physical (compressed) traffic, with
  `logical_bytes` beside it — effective GB/s multiplies by the ratio;
- tier placement holds 1/ratio more of the table in the same fast-tier
  bytes, so hit rates rise at fixed capacity;
- the decision surface grows a compression axis: at the 10 ms SLA,
  `compression_crossover_ratio` names the ratio at which a compressed
  traditional system beats the die-stacked baseline.

Run: PYTHONPATH=src:. python examples/compressed_store.py
"""
import numpy as np

from benchmarks.store_bench import compressible_table
from repro.core.systems import TiB
from repro.energy.tco import (cheapest_architecture,
                              compression_crossover_ratio)
from repro.query import Pred, Query, QueryEngine
from repro.store import EncodedTable
from repro.tier import Policy, TraceSpec, make_trace, paper_tiers, \
    replay_trace

N_COLS, N_ROWS, CHUNK_ROWS = 16, 32768, 2048
SKEW = 1.1
PAPER_DB = 16 * TiB


def main():
    table = compressible_table(N_COLS, N_ROWS, seed=0)
    encoded = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    s = encoded.stats()
    print(f"table: {N_COLS} columns x {N_ROWS} rows, "
          f"{s['logical_bytes'] / 1024:.0f} KiB plain -> "
          f"{s['physical_bytes'] / 1024:.0f} KiB compressed "
          f"({s['ratio']:.2f}x)")
    mix = {}
    for col in encoded.columns.values():
        for k, v in col.encodings().items():
            mix[k] = mix.get(k, 0) + v
    print(f"chunk encodings: {mix}\n")

    # bit-exact parity, compressed vs plain, on a few shapes
    for q in (Query(Pred("c00", "lt", 4), aggregates=("c00",)),   # RLE fused
              Query(Pred("c02", "ge", 44), aggregates=("c01",)),  # FOR x FOR
              Query(Pred("c03", "lt", 0), aggregates=("c00",))):  # empty
        e_plain, e_comp = QueryEngine(table), QueryEngine(encoded)
        e_plain.submit(q)
        e_comp.submit(q)
        want, got = e_plain.run()[0], e_comp.run()[0]
        assert got.aggregates == want.aggregates, (q, got, want)
        print(f"parity OK  {str(q.where):<42} "
              f"physical {got.bytes_scanned:>7,} B of "
              f"{got.logical_bytes:>7,} B logical")

    # same trace, same absolute fast-tier bytes: the hit rate rises
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=8.0)
    trace = make_trace(table, TraceSpec(n_queries=150, skew=SKEW, seed=7))
    sla_s = 2.0 * (table.nbytes / N_COLS * 2) / tiers.fast.bandwidth
    pe_p, eng_p, att_p = replay_trace(table, trace, tiers, Policy.CACHE,
                                      sla_s=sla_s, chunk_rows=CHUNK_ROWS)
    pe_e, eng_e, att_e = replay_trace(encoded, trace, tiers, Policy.CACHE,
                                      sla_s=sla_s, chunk_rows=CHUNK_ROWS)
    se = eng_e.summary()
    print(f"\nzipf({SKEW}) trace, fast tier = 25% of the *plain* table:")
    print(f"  plain    hit {pe_p.hit_rate:.2f}  attainment {att_p:.2f}")
    print(f"  encoded  hit {pe_e.hit_rate:.2f}  attainment {att_e:.2f}  "
          f"(physical {se['measured_gbps']:.2f} GB/s -> effective "
          f"{se['effective_gbps']:.2f} GB/s)")
    assert pe_e.hit_rate > pe_p.hit_rate

    # the compression axis of the paper's verdict
    cell = cheapest_architecture(PAPER_DB, 0.2 * PAPER_DB, 0.010, 1e6)
    x = compression_crossover_ratio(PAPER_DB, 0.2 * PAPER_DB, 0.010, 1e6)
    print(f"\n16 TiB / 10 ms / 1 MW: winner uncompressed = "
          f"{cell['winner']}; a traditional system takes over at "
          f"{x:.2f}x compression"
          + (f" — this table's {s['ratio']:.2f}x "
             f"{'clears' if s['ratio'] >= x else 'does not clear'} it"
             if x else ""))


if __name__ == "__main__":
    main()
