"""Checkpoint store: versioned, atomic, async, elastic.

Fault-tolerance contract (DESIGN.md §4):
- atomic publish: writes go to step_K.tmp/, fsync'd, then renamed — a
  crash mid-write never corrupts the latest checkpoint;
- versioned: keep_last N steps retained, `latest` resolves dynamically;
- elastic restore: leaves are stored as full logical arrays (per-host
  shards gathered on save) and re-sharded on load onto *any* mesh, so a
  512-chip job restarts on 256 chips (or vice versa) without conversion;
- async: save() can snapshot host-side and write in a background thread,
  overlapping the next train step (async_save=True);
- self-describing: a manifest.json records the tree structure, shapes,
  dtypes and user metadata (data step, mesh, code version).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

_SEP = "."


def _flatten(tree, prefix=""):
    """Flatten to {path: leaf} with deterministic key order."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(skeleton, flat):
    """Rebuild `skeleton`'s structure with arrays from `flat`."""
    def rec(node, prefix=""):
        if isinstance(node, dict):
            return {k: rec(v, f"{prefix}{k}{_SEP}") for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(rec(v, f"{prefix}{i}{_SEP}")
                         for i, v in enumerate(node))
        if isinstance(node, list):
            return [rec(v, f"{prefix}{i}{_SEP}") for i, v in enumerate(node)]
        if node is None:
            return None
        return flat[prefix[:-1]]
    return rec(skeleton)


class CheckpointManager:
    def __init__(self, directory, keep_last: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, metadata: dict | None = None):
        """Snapshot to host memory, then write (optionally in background)."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host
        meta = {
            "step": int(step),
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "user": metadata or {},
        }
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, meta: dict):
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **host)
        (tmp / "manifest.json").write_text(json.dumps(meta))
        with open(tmp / "manifest.json") as f:   # durability barrier
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                         # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step_{step:010d}" / "manifest.json").read_text())

    def restore(self, skeleton, step: int | None = None, mesh=None,
                shardings=None):
        """Rebuild `skeleton`'s structure; if `shardings` (a matching pytree
        of NamedShardings, possibly on a *different* mesh than at save time)
        is given, leaves are device_put with those shardings — this is the
        elastic-rescale path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        zf = np.load(self.dir / f"step_{step:010d}" / "arrays.npz")
        flat = {k: zf[k] for k in zf.files}
        tree = _unflatten_into(skeleton, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda x, ref: jax.numpy.asarray(
                    x, getattr(ref, "dtype", None)), tree, skeleton)
        return tree, self.metadata(step)
