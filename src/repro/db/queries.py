"""Legacy scan/aggregate entry points over the bit-packed store.

The seed's ad-hoc single-device functions grew into the repro.query engine
(logical Pred/And/Or plans -> kernel-dispatch physical operators, row-wise
sharding, SLA-batched execution); these wrappers keep the original call
signatures and route through that same execution path, so there is exactly
one way a scan runs. Kernel selection is a dispatch `mode=`
(KernelMode.PALLAS | XLA_REF | AUTO) — the `use_kernel=` booleans are gone.
"""
from __future__ import annotations

from repro.db.columnar import Table
from repro.query import physical
from repro.query.plan import Predicate, normalize


def scan_query(table: Table, predicates, mode=None):
    """Predicate tree (or legacy list = conjunction) -> packed selection
    mask in the delimiter-bit layout of the leftmost predicate's column.
    Mixed column widths are repacked automatically; padding rows never
    match."""
    plan = normalize(predicates)
    physical.bind_check(plan, (), table.columns)
    mask, _ = physical.eval_mask(plan, physical.table_slices(table), mode)
    return mask


def scan_aggregate_query(table: Table, predicates, agg_column: str,
                         mode=None) -> dict:
    """SELECT agg(agg_column) WHERE <predicates> — the paper's query.
    Returns exact host ints (sum/count/min/max) + selectivity."""
    plan = normalize(predicates)
    physical.bind_check(plan, (agg_column,), table.columns)
    out = physical.finalize_aggs(physical.execute(
        plan, (agg_column,), physical.table_slices(table), mode=mode))
    res = out[agg_column]
    res["selectivity"] = res["count"] / max(table.num_rows, 1)
    return res


def bytes_scanned(table: Table, predicates, agg_column: str) -> int:
    """Bytes a query streams from memory — the model's `percent accessed`
    numerator for this workload."""
    plan = normalize(predicates)
    physical.bind_check(plan, (agg_column,), table.columns)
    return physical.referenced_bytes(plan, (agg_column,), table.columns)
