"""Scan + aggregate query plans over the bit-packed store.

WideTable's observation (Li & Patel, VLDB'14): most analytic queries reduce
to conjunctive predicate scans followed by aggregates. A query here is a
list of Predicates ANDed together (masks combined word-wise) feeding a
fused masked aggregate — exactly the operator mix the paper's `core_perf`
models, now running through the Pallas kernels.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.db.columnar import Table
from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter.ref import OPS


@dataclass(frozen=True)
class Predicate:
    column: str
    op: str          # lt | le | gt | ge | eq | ne
    constant: int

    def __post_init__(self):
        assert self.op in OPS, self.op


def scan_query(table: Table, predicates: list[Predicate],
               use_kernel: bool = True):
    """Conjunctive scan -> packed selection mask (delimiter-bit layout of
    the first predicate's column)."""
    assert predicates, "need at least one predicate"
    bits = {table.columns[p.column].code_bits for p in predicates}
    assert len(bits) == 1, "conjunction across widths: repack first"
    mask = None
    for p in predicates:
        col = table.columns[p.column]
        m = scan_ops.scan_filter(col.words, p.constant, p.op, col.code_bits,
                                 use_kernel=use_kernel)
        mask = m if mask is None else (mask & m)
    return mask


def scan_aggregate_query(table: Table, predicates: list[Predicate],
                         agg_column: str, use_kernel: bool = True) -> dict:
    """SELECT agg(agg_column) WHERE AND(predicates) — the paper's query."""
    mask = scan_query(table, predicates, use_kernel=use_kernel)
    col = table.columns[agg_column]
    out = agg_ops.aggregate(col.words, mask, col.code_bits,
                            use_kernel=use_kernel)
    out["selectivity"] = (jnp.float32(out["count"])
                          / jnp.float32(table.num_rows))
    return out


def bytes_scanned(table: Table, predicates: list[Predicate],
                  agg_column: str) -> int:
    """Bytes a query streams from memory — the model's `percent accessed`
    numerator for this workload."""
    cols = {p.column for p in predicates} | {agg_column}
    return sum(table.columns[c].nbytes for c in cols)
