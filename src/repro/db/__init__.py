"""In-memory analytic DB substrate (the paper's workload)."""
from repro.db.columnar import BitPackedColumn, Table
from repro.db.queries import Predicate, scan_aggregate_query

__all__ = ["BitPackedColumn", "Table", "Predicate", "scan_aggregate_query"]
