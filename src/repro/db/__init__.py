"""In-memory analytic DB substrate (the paper's workload).

Storage lives here (bit-packed columns, tables); execution lives in
repro.query (plans, sharding, the SLA-aware engine).
"""
from repro.db.columnar import BitPackedColumn, Table
from repro.db.queries import Predicate, scan_aggregate_query

__all__ = ["BitPackedColumn", "Table", "Predicate", "scan_aggregate_query"]
