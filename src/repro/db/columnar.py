"""Bit-packed in-memory column store (WideTable/BitWeaving-style).

The paper's workload is scans over an in-memory analytic database; this is
that database. Columns hold dictionary-encoded codes bit-packed into int32
words (delimiter MSB per field kept 0 — see kernels/scan_filter), sharded
row-wise across devices for cluster-scale scans.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.kernels.scan_filter import ref as packref


@dataclass
class BitPackedColumn:
    name: str
    code_bits: int
    num_rows: int
    words: jnp.ndarray                 # (n_words,) uint32
    dictionary: np.ndarray | None = None   # code -> value (optional)
    _valid: jnp.ndarray | None = field(default=None, repr=False,
                                       compare=False)

    @classmethod
    def from_values(cls, name: str, values, code_bits: int,
                    dictionary=None) -> "BitPackedColumn":
        values = np.asarray(values)
        if code_bits not in (2, 4, 8, 16):
            raise ValueError(
                f"column {name!r}: code_bits={code_bits} unsupported; must "
                f"be 2, 4, 8, or 16 (fields divide the 32-bit word, and "
                f"exact aggregation needs payloads < 2^16)")
        vmax = (1 << (code_bits - 1)) - 1
        if values.min(initial=0) < 0:
            raise ValueError(
                f"column {name!r}: min code {int(values.min())} is "
                f"negative; dictionary codes are unsigned indices")
        if values.max(initial=0) > vmax:
            raise ValueError(
                f"column {name!r}: max code {int(values.max())} exceeds "
                f"the {code_bits}-bit payload max {vmax} (the delimiter "
                f"MSB must stay 0); widen code_bits or re-encode the "
                f"dictionary")
        words = packref.pack(values, code_bits)
        return cls(name, code_bits, len(values), jnp.asarray(words),
                   None if dictionary is None else np.asarray(dictionary))

    @property
    def valid_words(self) -> jnp.ndarray:
        """Packed delimiter-bit mask set only for real rows: cancels the
        pack()-to-a-word-multiple tail padding during query evaluation
        (cached — reused by every query touching this column)."""
        if self._valid is None:
            total = int(self.words.size) * self.codes_per_word
            self._valid = jnp.asarray(packref.pack_mask(
                np.arange(total) < self.num_rows, self.code_bits))
        return self._valid

    @property
    def codes_per_word(self) -> int:
        return 32 // self.code_bits

    @property
    def nbytes(self) -> int:
        return int(self.words.size) * 4

    def decode(self) -> np.ndarray:
        vals = np.asarray(packref.unpack(self.words, self.code_bits))
        vals = vals[:self.num_rows]
        if self.dictionary is not None:
            return self.dictionary[vals]
        return vals


@dataclass
class Table:
    name: str
    columns: dict[str, BitPackedColumn] = field(default_factory=dict)

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).num_rows if self.columns else 0

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns.values())

    def add(self, col: BitPackedColumn) -> "Table":
        if self.columns and col.num_rows != self.num_rows:
            raise ValueError(
                f"column {col.name!r} has {col.num_rows} rows but table "
                f"{self.name!r} has {self.num_rows}; all columns of a "
                f"table share one row count")
        self.columns[col.name] = col
        return self

    @classmethod
    def synthetic(cls, name: str, num_rows: int, spec: dict[str, int],
                  seed: int = 0) -> "Table":
        """spec: column name -> code_bits; values uniform in payload range."""
        rng = np.random.default_rng(seed)
        t = cls(name)
        for cname, bits in spec.items():
            vmax = (1 << (bits - 1)) - 1
            vals = rng.integers(0, vmax + 1, num_rows)
            t.add(BitPackedColumn.from_values(cname, vals, bits))
        return t
