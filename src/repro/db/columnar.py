"""Bit-packed in-memory column store (WideTable/BitWeaving-style).

The paper's workload is scans over an in-memory analytic database; this is
that database. Columns hold dictionary-encoded codes bit-packed into int32
words (delimiter MSB per field kept 0 — see kernels/scan_filter), sharded
row-wise across devices for cluster-scale scans.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.kernels.scan_filter import ref as packref


@dataclass
class BitPackedColumn:
    name: str
    code_bits: int
    num_rows: int
    words: jnp.ndarray                 # (n_words,) uint32
    dictionary: np.ndarray | None = None   # code -> value (optional)

    @classmethod
    def from_values(cls, name: str, values, code_bits: int,
                    dictionary=None) -> "BitPackedColumn":
        values = np.asarray(values)
        vmax = (1 << (code_bits - 1)) - 1
        if values.max(initial=0) > vmax:
            raise ValueError(f"codes exceed {code_bits}-bit payload")
        words = packref.pack(values, code_bits)
        return cls(name, code_bits, len(values), jnp.asarray(words),
                   None if dictionary is None else np.asarray(dictionary))

    @property
    def codes_per_word(self) -> int:
        return 32 // self.code_bits

    @property
    def nbytes(self) -> int:
        return int(self.words.size) * 4

    def decode(self) -> np.ndarray:
        vals = np.asarray(packref.unpack(self.words, self.code_bits))
        vals = vals[:self.num_rows]
        if self.dictionary is not None:
            return self.dictionary[vals]
        return vals


@dataclass
class Table:
    name: str
    columns: dict[str, BitPackedColumn] = field(default_factory=dict)

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).num_rows if self.columns else 0

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns.values())

    def add(self, col: BitPackedColumn) -> "Table":
        if self.columns and col.num_rows != self.num_rows:
            raise ValueError("row count mismatch")
        self.columns[col.name] = col
        return self

    @classmethod
    def synthetic(cls, name: str, num_rows: int, spec: dict[str, int],
                  seed: int = 0) -> "Table":
        """spec: column name -> code_bits; values uniform in payload range."""
        rng = np.random.default_rng(seed)
        t = cls(name)
        for cname, bits in spec.items():
            vmax = (1 << (bits - 1)) - 1
            vals = rng.integers(0, vmax + 1, num_rows)
            t.add(BitPackedColumn.from_values(cname, vals, bits))
        return t
