"""Per-query energy metering: the joules ledger behind the paper's verdict.

The paper's conclusion is not that die-stacking is fast — it is that
die-stacked *power* is up to 50x higher, so the decision depends on SLA,
power, and cost jointly. The tier subsystem used to keep one scalar
(`PlacementEngine.energy_j_total`); this module replaces it with a ledger
that charges every query:

- *memory* joules from the bytes it streamed per tier (fast vs capacity,
  each at its tier's `energy_per_byte` — the same Table-1 derivation as
  `TierPair.energy_j`), and
- *compute* joules from the compute chip's power times the *modeled busy
  time* on the `serve.sla.VirtualClock` (the paper's Eq. 7 compute term,
  per query instead of per cluster).

Every charge carries the query id and tenant, so the meter answers the
questions a production bill needs: joules per query, watts per tenant,
fast-vs-capacity-vs-compute breakdown — and its window'd form feeds the
`PowerCap` governor (repro.energy.caps) and the $/query TCO model
(repro.energy.tco).

Compute energy is charged at *busy* (natural) service time: a power-capped
query that gets throttled stretches its wall time, but the chip
races-to-idle — the work (and its joules) does not grow with the wait.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

if TYPE_CHECKING:   # annotation-only: tier.placement imports this module
    from repro.tier.tiers import TierPair


def chip_compute_watts(system, cores: int | None = None) -> float:
    """Eq. 7's per-chip compute power from a Table-1 `SystemSpec`:
    enabled cores x W/core (default: the cores that saturate the chip's
    bandwidth — the paper's scan regime)."""
    n = system.saturating_cores if cores is None else cores
    if not 1 <= n <= system.max_chip_cores:
        raise ValueError(f"cores={n} outside [1, {system.max_chip_cores}] "
                         f"for {system.name!r}")
    return n * system.core_power


@dataclass
class EnergyCharge:
    """One query's line on the bill: bytes moved, joules per component."""

    qid: int | None
    tenant: int | None
    fast_bytes: int
    capacity_bytes: int
    fast_j: float
    capacity_j: float
    compute_j: float = 0.0
    busy_s: float = 0.0          # modeled busy time the compute term used
    kind: str = "query"          # "query" | "recovery" (retry/repair
    #                              bytes) | "prefetch" (overlap traffic:
    #                              staged fast re-reads + cancelled waste)

    @property
    def memory_j(self) -> float:
        return self.fast_j + self.capacity_j

    @property
    def total_j(self) -> float:
        return self.memory_j + self.compute_j

    def as_dict(self) -> dict:
        return {
            "qid": self.qid, "tenant": self.tenant,
            "fast_bytes": self.fast_bytes,
            "capacity_bytes": self.capacity_bytes,
            "fast_j": self.fast_j, "capacity_j": self.capacity_j,
            "compute_j": self.compute_j, "total_j": self.total_j,
            "busy_s": self.busy_s, "kind": self.kind,
        }


@dataclass
class EnergyMeter:
    """The joules ledger for one placement domain.

    `tiers` prices the memory term; `compute_w` is the per-chip compute
    power (0.0 keeps the meter bit-compatible with the old memory-only
    scalar — see `memory_j`, which is exactly what
    `PlacementEngine.energy_j_total` used to accumulate).
    """

    tiers: TierPair
    compute_w: float = 0.0
    charges: list[EnergyCharge] = field(default_factory=list)

    def __post_init__(self):
        if not math.isfinite(self.compute_w) or self.compute_w < 0:
            raise ValueError(f"compute_w={self.compute_w} must be a finite "
                             f"non-negative power in watts")

    # --- charging ---------------------------------------------------------
    def charge(self, fast_bytes: int, capacity_bytes: int, *,
               qid: int | None = None, tenant: int | None = None,
               kind: str = "query") -> EnergyCharge:
        """Open a query's charge with its memory term (bytes validated,
        per-tier pricing single-sourced in TierPair.energy_components);
        the compute term lands via charge_compute once the modeled
        service time is known. `kind` separates nominal query lines from
        "recovery" lines (retry/failover/repair traffic) so fault
        overhead is auditable on the bill."""
        fast_j, capacity_j = self.tiers.energy_components(fast_bytes,
                                                          capacity_bytes)
        ch = EnergyCharge(
            qid=qid, tenant=tenant,
            fast_bytes=int(fast_bytes), capacity_bytes=int(capacity_bytes),
            fast_j=fast_j, capacity_j=capacity_j, kind=str(kind))
        self.charges.append(ch)
        return ch

    def charge_compute(self, ch: EnergyCharge, busy_s: float,
                       chips: int = 1) -> EnergyCharge:
        """Add the compute term: compute_w x chips x modeled busy seconds."""
        if not math.isfinite(busy_s) or busy_s < 0:
            raise ValueError(f"busy_s={busy_s} must be finite and "
                             f"non-negative")
        ch.compute_j += self.compute_w * chips * busy_s
        ch.busy_s += busy_s
        return ch

    # --- totals -----------------------------------------------------------
    @property
    def fast_j(self) -> float:
        return sum(c.fast_j for c in self.charges)

    @property
    def capacity_j(self) -> float:
        return sum(c.capacity_j for c in self.charges)

    @property
    def compute_j(self) -> float:
        return sum(c.compute_j for c in self.charges)

    @property
    def memory_j(self) -> float:
        """The old `PlacementEngine.energy_j_total` scalar: per-tier byte
        energy only. Kept as an exact sum of the ledger's memory lines so
        the tier module's `stats()["energy_j"]` stays bit-compatible."""
        return sum(c.memory_j for c in self.charges)

    @property
    def total_j(self) -> float:
        return sum(c.total_j for c in self.charges)

    def by_tenant(self) -> dict:
        """tenant -> {queries, fast_j, capacity_j, compute_j, total_j}."""
        out: dict = {}
        for c in self.charges:
            t = out.setdefault(c.tenant, {
                "queries": 0, "fast_j": 0.0, "capacity_j": 0.0,
                "compute_j": 0.0, "total_j": 0.0})
            # recovery lines bill joules to the tenant without counting
            # as queries — j_per_query stays joules per *served* query
            t["queries"] += 1 if c.kind == "query" else 0
            t["fast_j"] += c.fast_j
            t["capacity_j"] += c.capacity_j
            t["compute_j"] += c.compute_j
            t["total_j"] += c.total_j
        return out

    @property
    def recovery_j(self) -> float:
        """Joules on kind="recovery" lines — what the faults cost."""
        return sum(c.total_j for c in self.charges if c.kind == "recovery")

    @property
    def prefetch_j(self) -> float:
        """Joules on kind="prefetch" lines — what the overlap cost (staged
        fast-buffer re-reads plus streamed-then-cancelled waste; the
        nominal capacity stream stays on the query line, charged once)."""
        return sum(c.total_j for c in self.charges if c.kind == "prefetch")

    def summary(self) -> dict:
        n = sum(1 for c in self.charges if c.kind == "query")
        return {
            "queries": n,
            "recovery_j": self.recovery_j,
            "prefetch_j": self.prefetch_j,
            "fast_j": self.fast_j,
            "capacity_j": self.capacity_j,
            "compute_j": self.compute_j,
            "memory_j": self.memory_j,
            "total_j": self.total_j,
            "j_per_query": self.total_j / n if n else 0.0,
            "compute_w": self.compute_w,
            "by_tenant": self.by_tenant(),
        }
