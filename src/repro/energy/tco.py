"""Cost-effectiveness: $/query and the paper's decision surface.

The paper's bottom line (§7) is neither "die-stacking is fast" nor
"die-stacking is power-hungry" — it is that the *cheapest* architecture
depends on the SLA, the power envelope, and the workload jointly. This
module makes that verdict executable:

- `CostSheet`: capex assumptions ($/GiB per memory technology, $/chip,
  $/blade) plus opex ($/kWh) and a depreciation horizon. The defaults are
  Table-1-era list prices; every number is an input, not a constant.
- `usd_per_query`: amortized capex per served query (the cluster serves
  queries back-to-back at its response time) plus metered energy opex
  (J/query x $/kWh) — the measured path takes the EnergyMeter's joules and
  the engine's attained latency instead of datasheet derivations.
- `cheapest_architecture` / `decision_surface`: sweep SLA x skew x power
  budget, provision each candidate (the paper's Table-1 systems via
  provision_performance, plus a two-tier die-stacked-over-DDR node priced
  from the tier model), drop the power-infeasible ones, and name the
  cheapest $/query per cell — Figures 4/6/7 as one queryable surface.
  With `fast_gbps` from the autotune cache (tier.tiers.measured_fast_gbps)
  the tiered candidate runs at *measured* blended rates instead of
  datasheet numbers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.model import ClusterDesign, Workload
from repro.core.provisioning import provision_performance
from repro.core.systems import (BIG_MEMORY, DIE_STACKED, GiB, TRADITIONAL,
                                SystemSpec)

_YEAR_S = 365.25 * 86400.0


@dataclass(frozen=True)
class CostSheet:
    """Capex/opex assumptions. `mem_usd_per_gib` maps Table-1 system names
    (prefix-matched, so density/power variants inherit their base price)
    to $/GiB of deployed memory."""

    mem_usd_per_gib: tuple[tuple[str, float], ...] = (
        ("traditional", 10.0),     # commodity DDR4 DIMMs
        ("big-memory", 25.0),      # buffer-on-board appliance memory
        ("die-stacked", 40.0),     # HBM stacks, on-package integration
        ("ddr5-host", 12.0),
        ("tpu-v5e", 40.0),
    )
    chip_usd: float = 2000.0
    blade_usd: float = 1000.0
    usd_per_kwh: float = 0.10
    amortize_s: float = 3.0 * _YEAR_S     # depreciation horizon

    def __post_init__(self):
        for field_name in ("chip_usd", "blade_usd", "usd_per_kwh",
                           "amortize_s"):
            v = getattr(self, field_name)
            if not math.isfinite(v) or v < 0:
                raise ValueError(f"{field_name}={v} must be finite and "
                                 f"non-negative")
        if self.amortize_s <= 0:
            raise ValueError(f"amortize_s={self.amortize_s} must be "
                             f"positive")

    def mem_usd(self, system_name: str) -> float:
        for prefix, usd in self.mem_usd_per_gib:
            if system_name.startswith(prefix):
                return usd
        raise ValueError(
            f"no $/GiB price for system {system_name!r}; add it to "
            f"CostSheet.mem_usd_per_gib (have "
            f"{[p for p, _ in self.mem_usd_per_gib]})")


DEFAULT_COSTS = CostSheet()

#: Systems (by Table-1 name prefix) whose bandwidth already comes from
#: stacked/on-package memory: on the compression axis of the decision
#: surface they keep the datasheet workload — compression competes with
#: their hardware, it does not stack onto it. Callers passing custom
#: bandwidth-rich specs through `systems=` must list them here (via
#: `cheapest_architecture(bandwidth_rich_prefixes=...)`), or they are
#: treated as capacity-optimized and priced compressed.
BANDWIDTH_RICH_PREFIXES = ("die-stacked", "tpu")


def capex_usd(design: ClusterDesign, sheet: CostSheet = DEFAULT_COSTS
              ) -> float:
    """Cluster purchase price: deployed memory + chips + blades."""
    return (design.memory_capacity / GiB * sheet.mem_usd(design.system.name)
            + design.compute_chips * sheet.chip_usd
            + design.blades * sheet.blade_usd)


def usd_per_query(capex: float, response_time_s: float, energy_j: float,
                  sheet: CostSheet = DEFAULT_COSTS) -> float:
    """Amortized capex + energy opex for one query.

    The cluster serves back-to-back queries over the depreciation horizon
    (amortize_s / response_time queries), so each carries
    capex * rt / amortize_s of depreciation, plus its joules at $/kWh.
    """
    for name, v in (("capex", capex), ("response_time_s", response_time_s),
                    ("energy_j", energy_j)):
        if not math.isfinite(v) or v < 0:
            raise ValueError(f"{name}={v} must be finite and non-negative")
    if response_time_s == 0:
        raise ValueError("response_time_s=0: a query that takes no time "
                         "amortizes no capex; pass the attained latency")
    return (capex * response_time_s / sheet.amortize_s
            + energy_j / 3.6e6 * sheet.usd_per_kwh)


# --- candidates ------------------------------------------------------------

def evaluate_system(system: SystemSpec, workload: Workload, sla_s: float,
                    sheet: CostSheet = DEFAULT_COSTS) -> dict:
    """One Table-1 architecture, performance-provisioned for the SLA."""
    d = provision_performance(system, workload, sla_s)
    capex = capex_usd(d, sheet)
    return {
        "name": system.name,
        "chips": d.compute_chips,
        "cores_per_chip": d.cores_per_chip,
        "response_time_s": d.response_time,
        "power_w": d.power,
        "capex_usd": capex,
        "energy_per_query_j": d.energy_per_query,
        "usd_per_query": usd_per_query(capex, d.response_time,
                                       d.energy_per_query, sheet),
        "overprovision_x": d.overprovision_factor,
        "meets_sla": d.response_time <= sla_s * (1 + 1e-9),
    }


def evaluate_tiered(db_bytes: float, bytes_per_query: float, sla_s: float,
                    skew: float, sheet: CostSheet = DEFAULT_COSTS, *,
                    fast_gbps: float | None = None,
                    n_hot_items: int = 64,
                    fast_system: SystemSpec = DIE_STACKED,
                    capacity_system: SystemSpec = TRADITIONAL
                    ) -> dict | None:
    """The two-tier node (die-stacked over DDR) as a cost candidate.

    Searches the fast-tier fraction with core.advisor.advise_tier_split
    against the analytic zipf hit curve at `skew`, then prices each
    feasible fraction: the whole database in capacity-tier DRAM, the fast
    fraction duplicated into die-stacked stacks, chips sized by the
    blended rate. Returns the cheapest feasible fraction's candidate, or
    None when no fraction meets the SLA. With `fast_gbps` (the measured
    autotune rate) both tiers move to the measured scale — the capacity
    tier derated by the Table-1 bandwidth ratio — instead of Eq. 4
    datasheet rates.
    """
    from repro.core.advisor import advise_tier_split
    from repro.tier.tiers import table1_bandwidth_ratio
    from repro.tier.trace import zipf_hit_curve

    if fast_gbps is not None:
        fast = fast_gbps
        cap = fast / table1_bandwidth_ratio(fast_system, capacity_system)
    else:
        fast = fast_system.chip_peak_perf / 1e9       # Eq. 4, not raw BW
        cap = capacity_system.chip_peak_perf / 1e9
    adv = advise_tier_split(
        db_bytes, bytes_per_query, sla_s,
        hit_curve=zipf_hit_curve(n_hot_items, skew),
        fast_gbps=fast, capacity_gbps=cap, fast_system=fast_system)

    best = None
    for row in adv["rows"]:
        if not row["within_roofline"]:
            # a blended rate above the datasheet Eq. 4 roofline means the
            # measured fast rate is mis-measured (advise_tier_split's
            # cross-check); pricing it would let a broken tune-cache
            # entry win the surface at an unattainable operating point.
            # The roofline also bounds per-chip rate by max cores x
            # core_perf, so the cores derivation below cannot truncate.
            continue
        chips = row["chips_for_sla"]
        rate = row["blended_gbps"] * 1e9 * chips / adv["chips"]
        rt = bytes_per_query / rate
        if rt > sla_s * (1 + 1e-9):
            continue
        f = row["fast_fraction"]
        # capacity tier holds the database; fast tier caches f of it
        mem_w = (db_bytes * capacity_system.module_power
                 / capacity_system.module_capacity
                 + f * db_bytes * fast_system.module_power
                 / fast_system.module_capacity)
        per_chip = rate / chips
        cores = max(1, min(fast_system.max_chip_cores,
                           math.ceil(per_chip / fast_system.core_perf)))
        blades = math.ceil(chips / fast_system.blade_chips)
        power = (mem_w + chips * cores * fast_system.core_power
                 + blades * fast_system.blade_overhead)
        capex = (db_bytes / GiB * sheet.mem_usd(capacity_system.name)
                 + f * db_bytes / GiB * sheet.mem_usd(fast_system.name)
                 + chips * sheet.chip_usd + blades * sheet.blade_usd)
        energy_j = power * rt
        cand = {
            "name": "tiered",
            "fast_fraction": f,
            "hit_rate": row["hit_rate"],
            "chips": chips,
            "cores_per_chip": cores,
            "response_time_s": rt,
            "power_w": power,
            "capex_usd": capex,
            "energy_per_query_j": energy_j,
            "usd_per_query": usd_per_query(capex, rt, energy_j, sheet),
            "blended_gbps": rate / 1e9,
            "measured_rates": fast_gbps is not None,
            "meets_sla": True,
        }
        if best is None or cand["usd_per_query"] < best["usd_per_query"]:
            best = cand
    return best


# --- the decision surface --------------------------------------------------

def cheapest_architecture(db_bytes: float, bytes_per_query: float,
                          sla_s: float, power_budget_w: float, *,
                          skew: float | None = None,
                          sheet: CostSheet = DEFAULT_COSTS,
                          systems: tuple[SystemSpec, ...] = (
                              TRADITIONAL, BIG_MEMORY, DIE_STACKED),
                          fast_gbps: float | None = None,
                          n_hot_items: int = 64,
                          compression_ratio: float = 1.0,
                          grouped_mix: float = 0.0,
                          grouped_bytes_per_query: float | None = None,
                          bandwidth_rich_prefixes: tuple[str, ...] =
                          BANDWIDTH_RICH_PREFIXES) -> dict:
    """One cell of the decision surface: every candidate provisioned for
    `sla_s`, power-infeasible ones excluded, cheapest $/query named.

    `skew=None` skips the tiered candidate (the pure Table-1 comparison);
    with a skew the two-tier node competes at the zipf hit curve's blended
    rate.

    `compression_ratio` r is the repro.store logical/physical ratio, and
    it frames compression as the *software substitute for die-stacked
    bandwidth*: capacity-optimized candidates (traditional, big-memory)
    scan-over-compressed and so stream and store 1/r of the bytes, while
    the bandwidth-rich candidates — those whose name matches a
    `bandwidth_rich_prefixes` prefix (default: die-stacked and TPU-class
    specs), plus the two-tier node — stay at the datasheet workload: they
    already bought their bandwidth in hardware. Compressing every
    candidate equally would leave the verdict scale-invariant; the
    interesting question is exactly whether a compressed traditional
    system now meets the SLA (and beats the $/query) that used to
    require HBM. Custom bandwidth-rich specs passed via `systems=` must
    be named in `bandwidth_rich_prefixes` or they are priced compressed.

    `grouped_mix` m blends in the relational slice of the workload:
    GroupBy/HashJoin queries touch key + value columns instead of a
    scan's predicate + aggregate set, so they stream
    `grouped_bytes_per_query` physical bytes (measure it with
    engine.bytes_scanned on a grouped trace; defaults to
    bytes_per_query). Every candidate is priced at the blended
    (1-m)*scan + m*grouped bytes — the axis that answers whether a
    rollup-heavy workload moves the die-stacking verdict.
    """
    if db_bytes <= 0 or bytes_per_query <= 0:
        raise ValueError(f"db_bytes={db_bytes} and bytes_per_query="
                         f"{bytes_per_query} must be positive")
    if not (0.0 <= grouped_mix <= 1.0):
        raise ValueError(f"grouped_mix={grouped_mix} must be a fraction "
                         f"in [0, 1] (the grouped share of the stream)")
    if grouped_bytes_per_query is not None and \
            grouped_bytes_per_query <= 0:
        raise ValueError(f"grouped_bytes_per_query="
                         f"{grouped_bytes_per_query} must be positive")
    if grouped_mix > 0.0:
        gb = (bytes_per_query if grouped_bytes_per_query is None
              else grouped_bytes_per_query)
        bytes_per_query = (1.0 - grouped_mix) * bytes_per_query \
            + grouped_mix * gb
    if not math.isfinite(sla_s) or sla_s <= 0:
        raise ValueError(f"sla_s={sla_s} must be a finite positive time")
    if not math.isfinite(power_budget_w) or power_budget_w <= 0:
        raise ValueError(f"power_budget_w={power_budget_w} must be a "
                         f"finite positive power")
    if not math.isfinite(compression_ratio) or compression_ratio < 1.0:
        raise ValueError(
            f"compression_ratio={compression_ratio} must be a finite "
            f"ratio >= 1.0 (logical/physical; the store's selector never "
            f"produces expansion)")
    wl = Workload(db_size=db_bytes,
                  percent_accessed=min(bytes_per_query / db_bytes, 1.0))
    wl_c = Workload(db_size=db_bytes / compression_ratio,
                    percent_accessed=wl.percent_accessed)
    cands = []
    for s in systems:
        compressed = not s.name.startswith(tuple(bandwidth_rich_prefixes))
        c = evaluate_system(s, wl_c if compressed else wl, sla_s, sheet)
        c["compressed"] = compressed and compression_ratio > 1.0
        cands.append(c)
    if skew is not None:
        t = evaluate_tiered(db_bytes, bytes_per_query, sla_s, skew, sheet,
                            fast_gbps=fast_gbps, n_hot_items=n_hot_items)
        if t is not None:
            t["compressed"] = False
            cands.append(t)
    for c in cands:
        c["within_power"] = c["power_w"] <= power_budget_w * (1 + 1e-9)
        c["feasible"] = c["meets_sla"] and c["within_power"]
    feasible = [c for c in cands if c["feasible"]]
    winner = min(feasible, key=lambda c: c["usd_per_query"], default=None)
    return {
        "sla_s": sla_s,
        "skew": skew,
        "power_budget_w": power_budget_w,
        "compression_ratio": compression_ratio,
        "grouped_mix": grouped_mix,
        "winner": winner and winner["name"],
        "usd_per_query": winner and winner["usd_per_query"],
        "candidates": cands,
    }


def decision_surface(db_bytes: float, bytes_per_query: float, *,
                     slas: tuple = (0.010, 0.060, 0.250, 1.0),
                     skews: tuple = (None, 0.6, 1.1),
                     power_budgets_w: tuple = (50e3, 250e3, 1e6),
                     sheet: CostSheet = DEFAULT_COSTS,
                     fast_gbps: float | None = None,
                     n_hot_items: int = 64,
                     compression_ratios: tuple = (1.0,),
                     grouped_mixes: tuple = (0.0,),
                     grouped_bytes_per_query: float | None = None) -> dict:
    """The paper's "when to use" question as a queryable grid: for every
    (SLA, skew, power budget, compression ratio, grouped mix) cell, the
    cheapest feasible architecture.

    Cells where nothing is feasible report winner=None — the honest
    answer the closed-form figures cannot give. The default budgets are
    the paper's Fig. 4 operating points (50 kW / 250 kW / 1 MW); the
    default ratio axis is the uncompressed store and the default grouped
    axis the pure-scan stream (one cell per old cell, so the surface is
    backward-compatible). Passing the measured repro.store ratio
    alongside 1.0 shows which cells compression flips; passing grouped
    mixes with the measured `grouped_bytes_per_query` shows which cells a
    rollup/join-heavy stream flips.
    """
    cells = [
        cheapest_architecture(db_bytes, bytes_per_query, sla, budget,
                              skew=skew, sheet=sheet, fast_gbps=fast_gbps,
                              n_hot_items=n_hot_items,
                              compression_ratio=ratio, grouped_mix=mix,
                              grouped_bytes_per_query=grouped_bytes_per_query)
        for sla in slas for skew in skews for budget in power_budgets_w
        for ratio in compression_ratios for mix in grouped_mixes
    ]
    return {
        "db_bytes": db_bytes,
        "bytes_per_query": bytes_per_query,
        "slas": list(slas),
        "skews": list(skews),
        "power_budgets_w": list(power_budgets_w),
        "compression_ratios": list(compression_ratios),
        "grouped_mixes": list(grouped_mixes),
        "grouped_bytes_per_query": grouped_bytes_per_query,
        "fast_gbps": fast_gbps,
        "cells": cells,
    }


def compression_crossover_ratio(db_bytes: float, bytes_per_query: float,
                                sla_s: float, power_budget_w: float, *,
                                skew: float | None = None,
                                sheet: CostSheet = DEFAULT_COSTS,
                                fast_gbps: float | None = None,
                                n_hot_items: int = 64,
                                max_ratio: float = 64.0,
                                tol: float = 0.01) -> float | None:
    """The headline number compression adds to the paper's verdict: the
    smallest logical/physical ratio at which the *traditional*
    (capacity-optimized, bandwidth-poor) system becomes the cheapest
    feasible architecture for this (SLA, power) cell — i.e. how much the
    store must compress before die-stacking stops paying.

    Returns 1.0 when traditional already wins uncompressed, None when it
    still does not win at `max_ratio`. Bisects to `tol` assuming the win
    region is upward-closed in the ratio (shrinking bytes only ever helps
    the bandwidth-poor candidate)."""

    def traditional_wins(ratio: float) -> bool:
        cell = cheapest_architecture(
            db_bytes, bytes_per_query, sla_s, power_budget_w, skew=skew,
            sheet=sheet, fast_gbps=fast_gbps, n_hot_items=n_hot_items,
            compression_ratio=ratio)
        return (cell["winner"] is not None
                and cell["winner"].startswith("traditional"))

    if traditional_wins(1.0):
        return 1.0
    if not traditional_wins(max_ratio):
        return None
    lo, hi = 1.0, max_ratio
    while hi - lo > tol:
        mid = (lo + hi) / 2
        if traditional_wins(mid):
            hi = mid
        else:
            lo = mid
    return hi
