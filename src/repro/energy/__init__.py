"""Energy & cost-effectiveness engine: the paper's other two axes.

PR 2/3 made the *performance* axis executable (sharded SLA queries, tiered
placement); this package adds power and cost, so the paper's "when to use
die-stacked memory" question becomes a three-axis decision:

- `meter`:  EnergyMeter — a per-query/per-tenant joules ledger charging
            bytes-moved-per-tier plus compute-power x modeled busy time;
            replaces the tier module's single energy scalar.
- `caps`:   PowerCap — a sliding-window watt governor that derates
            effective bandwidth (stretches modeled service) so no window
            ever averages above budget, and feeds the derated estimate
            back into EDF admission.
- `tco`:    CostSheet / usd_per_query / decision_surface — capex + metered
            opex per query, and the SLA x skew x power-budget grid naming
            the cheapest architecture per cell.
"""
from repro.energy.caps import PowerCap
from repro.energy.meter import EnergyCharge, EnergyMeter, chip_compute_watts
from repro.energy.tco import (CostSheet, DEFAULT_COSTS, capex_usd,
                              cheapest_architecture,
                              compression_crossover_ratio,
                              decision_surface, evaluate_system,
                              evaluate_tiered, usd_per_query)

__all__ = [
    "EnergyMeter", "EnergyCharge", "chip_compute_watts",
    "PowerCap",
    "CostSheet", "DEFAULT_COSTS", "capex_usd", "usd_per_query",
    "evaluate_system", "evaluate_tiered", "cheapest_architecture",
    "decision_surface", "compression_crossover_ratio",
]
