"""Power-capped execution: a sliding-window watt budget as a governor.

A rack's power envelope is a contract over *every* window, not an average
over the whole day — a 30 s burst at 3x the budget trips the breaker even
if the daily mean is fine. `PowerCap` enforces that contract on the tiered
query path:

- every executed query is a ledger segment `(t0, t1, joules)` with uniform
  power over its wall time (times come from `serve.sla.VirtualClock`, so
  the guarantee is deterministic and testable);
- before a query runs, the governor *stretches* its wall service time just
  enough that no window of length `window_s` — past, present, or straddling
  — averages above `budget_w`. Stretching is a bandwidth derate: the
  effective tier rate drops, the chip races-to-idle (compute joules are
  charged at busy time, see repro.energy.meter), and the query simply
  finishes later;
- the same stretched estimate feeds EDF admission (`repro.query.engine`):
  a query whose power-derated service time cannot meet its deadline is
  rejected at submit, never silently run over-budget.

`max_window_watts()` is an exact check, not a sampling one: with piecewise-
constant power the sliding-window average is piecewise-linear in the window
position, so its maximum is attained with a window edge on a segment
boundary — checking those finitely many candidates bounds every window.
The governor only ever inspects segments still inside one window of the
new query's start (older ones cannot overlap any affected window), so its
cost tracks the window's occupancy, not the full history.
"""
from __future__ import annotations

import math

import numpy as np

_TOL = 1e-12     # relative slack for float-equality at the budget boundary


def _max_window_watts(t0s: np.ndarray, t1s: np.ndarray, js: np.ndarray,
                      window_s: float) -> float:
    """Exact sup of window-average power over ALL windows of `window_s`
    for uniform-power segments. Candidate window ends: every boundary and
    every boundary plus one window length (covering windows that *start*
    on a boundary) — the extrema of a piecewise-linear function."""
    if len(t0s) == 0:
        return 0.0
    ends = np.unique(np.concatenate(
        [t0s, t1s, t0s + window_s, t1s + window_s]))
    dur = t1s - t0s
    dens = np.where(dur > 0, js / np.where(dur > 0, dur, 1.0), 0.0)
    best = 0.0
    # overlap of every (window, segment) pair; windows are (e - L, e].
    # Batched so a long history costs O(batch x n) memory, not O(n^2)
    for i in range(0, len(ends), 1024):
        e = ends[i:i + 1024, None]
        ov = (np.minimum(t1s[None, :], e)
              - np.maximum(t0s[None, :], e - window_s))
        watts = (np.clip(ov, 0.0, None) * dens[None, :]).sum(axis=1)
        best = max(best, float(watts.max()))
    return best / window_s


class PowerCap:
    """Sliding-window watt budget over a ledger of executed queries."""

    def __init__(self, budget_w: float, window_s: float):
        if not math.isfinite(budget_w) or budget_w <= 0:
            raise ValueError(f"budget_w={budget_w} must be a finite "
                             f"positive power in watts")
        if not math.isfinite(window_s) or window_s <= 0:
            raise ValueError(f"window_s={window_s} must be a finite "
                             f"positive duration in seconds")
        self.budget_w = float(budget_w)
        self.window_s = float(window_s)
        # full history, append-only in time order (the engine is serial)
        self._t0: list[float] = []
        self._t1: list[float] = []
        self._j: list[float] = []
        self._gc = 0             # first segment still inside the window
        self.throttled_queries = 0
        self.throttle_s_total = 0.0

    # --- the ledger -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._j)

    @property
    def total_j(self) -> float:
        return float(sum(self._j))

    def record(self, t0: float, t1: float, joules: float,
               natural_s: float | None = None) -> None:
        """Append one executed query's (uniform-power) segment. With
        `natural_s` (the un-throttled service time) the cap also keeps
        the throttle statistics its report() publishes — callers that
        stretch service via throttled_service_s should pass it."""
        if not (math.isfinite(t0) and math.isfinite(t1)) or t1 < t0:
            raise ValueError(f"segment [{t0}, {t1}] is not a forward "
                             f"time interval")
        if not math.isfinite(joules) or joules < 0:
            raise ValueError(f"joules={joules} must be finite and "
                             f"non-negative")
        if joules > 0 and t1 == t0:
            raise ValueError(f"{joules} J over a zero-length segment is "
                             f"infinite power; stretch the service time")
        if self._t0 and t0 < self._t0[-1]:
            raise ValueError(
                f"segment start {t0} precedes the previous segment's "
                f"start {self._t0[-1]}; the ledger is time-ordered "
                f"(queries execute serially on one clock)")
        self._t0.append(float(t0))
        self._t1.append(float(t1))
        self._j.append(float(joules))
        if natural_s is not None and t1 - t0 > natural_s:
            self.throttled_queries += 1
            self.throttle_s_total += (t1 - t0) - natural_s

    def _active(self, t_min: float) -> tuple:
        """Segments that can still overlap a window touching times past
        `t_min`; the pointer only moves forward (time is monotone)."""
        while self._gc < len(self._t1) and self._t1[self._gc] <= t_min:
            self._gc += 1
        sl = slice(self._gc, None)
        return (np.asarray(self._t0[sl]), np.asarray(self._t1[sl]),
                np.asarray(self._j[sl]))

    def window_j(self, t_end: float) -> float:
        """Energy inside the window ending at `t_end`."""
        a = t_end - self.window_s
        j = 0.0
        for t0, t1, e in zip(self._t0, self._t1, self._j):
            dur = t1 - t0
            ov = min(t1, t_end) - max(t0, a)
            if dur > 0 and ov > 0:
                j += e * ov / dur
        return j

    def watts(self, t_end: float) -> float:
        """Window-average power of the window ending at `t_end`."""
        return self.window_j(t_end) / self.window_s

    def max_window_watts(self) -> float:
        """Exact supremum over all windows, whole recorded history."""
        return _max_window_watts(np.asarray(self._t0),
                                 np.asarray(self._t1),
                                 np.asarray(self._j), self.window_s)

    # --- the governor -----------------------------------------------------
    def throttled_service_s(self, now: float, joules: float,
                            natural_s: float) -> float:
        """Minimal wall service >= `natural_s` such that executing
        `joules` over (now, now + s) keeps every window at or under
        budget. Pure query — does not record; callers record() the
        segment once the query actually runs."""
        if not math.isfinite(natural_s) or natural_s < 0:
            raise ValueError(f"natural_s={natural_s} must be finite and "
                             f"non-negative")
        if not math.isfinite(joules) or joules < 0:
            raise ValueError(f"joules={joules} must be finite and "
                             f"non-negative")
        if joules == 0.0:
            return natural_s
        t0s, t1s, js = self._active(now - self.window_s)
        limit = self.budget_w * (1.0 + _TOL)

        def ok(s: float) -> bool:
            if not now + s > now:
                # s underflowed below ulp(now): the trial segment would
                # collapse to zero length, its joules vanishing from the
                # window check (and record() would rightly refuse it)
                return False
            return _max_window_watts(
                np.append(t0s, now), np.append(t1s, now + s),
                np.append(js, joules), self.window_s) <= limit

        # a zero-length segment has infinite power; seed lo with any
        # strictly positive floor so the bisection interval is real
        lo = max(natural_s, 1e-300)
        if ok(lo):
            return lo
        # the query alone needs joules / budget_w seconds; past-ledger
        # congestion can push further — double until feasible
        hi = max(lo, self.window_s, joules / self.budget_w)
        for _ in range(200):
            if ok(hi):
                break
            hi *= 2.0
        else:  # pragma: no cover - ledger invariant keeps this unreachable
            raise RuntimeError(
                f"power cap {self.budget_w} W cannot be met for a "
                f"{joules} J query; the recorded ledger already saturates "
                f"the budget")
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if ok(mid):
                hi = mid
            else:
                lo = mid
        return hi        # the feasible endpoint, verified by ok()

    # --- reporting --------------------------------------------------------
    def report(self, now: float | None = None) -> dict:
        peak = self.max_window_watts()
        return {
            "budget_w": self.budget_w,
            "window_s": self.window_s,
            "segments": len(self),
            "total_j": self.total_j,
            "max_window_w": peak,
            "budget_utilization": peak / self.budget_w,
            "current_w": self.watts(now) if now is not None else None,
            "throttled_queries": self.throttled_queries,
            "throttle_s_total": self.throttle_s_total,
        }
