"""Fixed-cadence ring-buffer time series on the VirtualClock.

Samples are pushed only at the SLOMonitor's cadence ticks — never on the
wall clock — so a series is a pure function of (workload, seed) and two
same-seed chaos replays produce identical buffers. The ring keeps the
most recent `capacity` samples; burn-rate windows are bounded, so old
samples age out without unbounded growth.

Timestamps are *computed*, not accumulated: tick i lives at
`i * cadence_s` (one multiplication), so timestamps are bitwise
reproducible regardless of how many pushes happened — the determinism
the SLO alert stream inherits.
"""
from __future__ import annotations


class RingSeries:
    """A bounded (t, value) series with time-window queries.

    Push order must be non-decreasing in t (the monitor's cadence
    guarantees it); lookups assume that order.
    """

    __slots__ = ("name", "capacity", "_t", "_v")

    def __init__(self, name: str, *, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.name = name
        self.capacity = int(capacity)
        self._t: list[float] = []
        self._v: list[float] = []

    def __len__(self) -> int:
        return len(self._t)

    def push(self, t: float, value: float) -> None:
        if self._t and t < self._t[-1]:
            raise ValueError(
                f"series {self.name!r}: push at t={t!r} before "
                f"last sample t={self._t[-1]!r}")
        self._t.append(float(t))
        self._v.append(float(value))
        if len(self._t) > self.capacity:
            del self._t[0]
            del self._v[0]

    @property
    def last(self) -> float | None:
        return self._v[-1] if self._v else None

    @property
    def last_t(self) -> float | None:
        return self._t[-1] if self._t else None

    def at_or_before(self, t: float) -> float | None:
        """Latest value with sample time <= t (None before first sample
        still in the ring). Linear from the tail: burn windows look back
        a bounded number of ticks."""
        for i in range(len(self._t) - 1, -1, -1):
            if self._t[i] <= t:
                return self._v[i]
        return None

    def window(self, t_lo: float, t_hi: float) -> list:
        """Samples with t_lo < t <= t_hi, oldest first."""
        return [(t, v) for t, v in zip(self._t, self._v)
                if t_lo < t <= t_hi]

    def window_mean(self, t_lo: float, t_hi: float) -> float:
        """Mean over (t_lo, t_hi]; 0.0 when the window is empty (the
        same empty-series convention as metrics.Histogram.mean)."""
        w = self.window(t_lo, t_hi)
        if not w:
            return 0.0
        return sum(v for _, v in w) / len(w)

    def as_dict(self) -> dict:
        return {"name": self.name, "n": len(self._t),
                "t": list(self._t), "v": list(self._v)}
