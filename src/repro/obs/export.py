"""Trace export: Chrome trace events (Perfetto) + a plain-text waterfall.

`chrome_trace` emits the Trace Event Format (the JSON Perfetto and
chrome://tracing load): one process lane per tenant, one thread lane per
span kind, complete ("X") events in microseconds of *modeled* time.
`chrome_trace_json` serializes with sorted keys and fixed separators, so
two runs from the same seed produce byte-identical files — the
determinism contract tests/test_obs.py pins down.

`waterfall` renders the same spans as aligned ASCII timelines for humans
without a browser (examples/trace_query.py prints one per chaos query).
"""
from __future__ import annotations

import json
import math

# stable thread-lane order: the execution story top to bottom
_LANES = ("admission", "read", "prefetch_read", "prefetch_cancel",
          "prefetch_stall", "stall", "retry", "failover", "repair",
          "shard_failover", "launch", "launch_batch", "compute",
          "throttle")


def _lane(kind: str) -> int:
    try:
        return _LANES.index(kind) + 1
    except ValueError:
        return len(_LANES) + 1


def _us(t: float) -> float:
    return round(t * 1e6, 6)


def _args(sp) -> dict:
    args = {"bytes": sp.nbytes, "joules": sp.joules}
    if sp.tier is not None:
        args["tier"] = sp.tier
    if sp.ledger is not None:
        args["ledger"] = sp.ledger
    for k, v in sp.attrs.items():
        args[k] = list(v) if isinstance(v, tuple) else v
    return args


def _name(sp) -> str:
    cid = sp.attrs.get("cid")
    if cid is not None:
        return f"{sp.kind} {cid[0]}/{cid[1]}"
    fam = sp.attrs.get("family")
    if fam is not None:
        return f"{sp.kind} {fam}"
    return sp.kind


def chrome_trace(tracer) -> dict:
    """The trace as a Trace-Event-Format object (load in Perfetto via
    `ui.perfetto.dev` > Open trace file, or chrome://tracing)."""
    events: list[dict] = []
    tenants = sorted({qt.tenant for qt in tracer.queries})
    for tenant in tenants:
        events.append({"ph": "M", "pid": tenant, "tid": 0,
                       "name": "process_name",
                       "args": {"name": f"tenant {tenant}"}})
        for i, lane in enumerate(_LANES):
            events.append({"ph": "M", "pid": tenant, "tid": i + 1,
                           "name": "thread_name", "args": {"name": lane}})
        events.append({"ph": "M", "pid": tenant, "tid": 0,
                       "name": "thread_name", "args": {"name": "query"}})
    for qt in tracer.queries:
        if qt.t_start is None or qt.t_end is None:
            continue
        events.append({
            "ph": "X", "pid": qt.tenant, "tid": 0, "cat": "query",
            "name": f"q{qt.qid}", "ts": _us(qt.t_start),
            "dur": _us(qt.t_end - qt.t_start),
            "args": {"qid": qt.qid, "bytes": qt.bytes_expected,
                     "met": qt.met, "degraded": qt.degraded,
                     "error": qt.error,
                     "deadline": (None if math.isinf(qt.deadline)
                                  else _us(qt.deadline))}})
        for sp in qt.spans:
            events.append({
                "ph": "X", "pid": qt.tenant, "tid": _lane(sp.kind),
                "cat": sp.kind, "name": _name(sp), "ts": _us(sp.t0),
                "dur": _us(sp.dur_s), "args": _args(sp)})
    # schema invariant the export tests pin: within every (pid, tid)
    # lane the X events are ts-monotone, so viewers never reorder them.
    # Metadata (M) keeps its emission order ahead of all X events.
    events.sort(key=lambda e: (e["ph"] != "M", e.get("pid", 0),
                               e.get("tid", 0), e.get("ts", 0.0),
                               e.get("dur", 0.0), e.get("name", "")))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def chrome_trace_json(tracer) -> str:
    """Deterministic serialization: same seed -> byte-identical string."""
    return json.dumps(chrome_trace(tracer), sort_keys=True,
                      separators=(",", ":"))


# --------------------------------------------------------------------------
# plain-text waterfall
# --------------------------------------------------------------------------

def _fmt_bytes(n: int) -> str:
    if n >= 1 << 20:
        return f"{n / (1 << 20):.1f}MiB"
    if n >= 1 << 10:
        return f"{n / (1 << 10):.1f}KiB"
    return f"{n}B"


def _fmt_s(t: float) -> str:
    if t >= 1.0:
        return f"{t:.3f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.3f}ms"
    return f"{t * 1e6:.1f}us"


def waterfall_query(qt, *, width: int = 48) -> str:
    """One query's spans as aligned bars over [t_start, t_end]."""
    if qt.t_start is None or qt.t_end is None:
        return f"q{qt.qid}: not served"
    t0, t1 = qt.t_start, qt.t_end
    span = max(t1 - t0, 1e-12)
    head = (f"q{qt.qid} tenant={qt.tenant} "
            f"[{_fmt_s(t0)} .. {_fmt_s(t1)}] "
            f"{_fmt_bytes(qt.bytes_expected)} "
            f"{'met' if qt.met else 'MISSED'}")
    if qt.degraded:
        head += f" DEGRADED({qt.error})"
    lines = [head]
    for sp in qt.spans:
        lo = max(0.0, min(1.0, (sp.t0 - t0) / span))
        hi = max(lo, min(1.0, (sp.t1 - t0) / span))
        a = int(lo * width)
        b = max(int(math.ceil(hi * width)), a + 1)
        bar = " " * a + "#" * (b - a) + " " * (width - b)
        label = _name(sp)
        detail = _fmt_s(sp.dur_s)
        if sp.nbytes:
            detail += f" {_fmt_bytes(sp.nbytes)}"
            if sp.tier:
                detail += f" {sp.tier}"
            if sp.ledger and sp.ledger != "query":
                detail += f" [{sp.ledger}]"
        lines.append(f"  {label:<28s}|{bar}| {detail}")
    return "\n".join(lines)


def waterfall(tracer, *, width: int = 48,
              max_queries: int | None = None) -> str:
    """Every traced query's waterfall, service order."""
    qs = tracer.queries
    if max_queries is not None:
        qs = qs[:max_queries]
    out = [waterfall_query(qt, width=width) for qt in qs]
    if max_queries is not None and len(tracer.queries) > max_queries:
        out.append(f"... {len(tracer.queries) - max_queries} more queries")
    return "\n".join(out)
