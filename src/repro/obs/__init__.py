"""Observability: deterministic query tracing, scoped metrics, audit.

- `trace`   per-query span trees on the VirtualClock (Tracer/NullTracer)
- `metrics` scoped counter/gauge/histogram registry + unified snapshot
- `audit`   conservation checker: span bytes/joules == ledger lines
- `export`  Chrome-trace-event JSON (Perfetto) + plain-text waterfall
"""
from repro.obs.audit import AuditReport, ConservationError, audit, check
from repro.obs.export import (chrome_trace, chrome_trace_json, waterfall,
                              waterfall_query)
from repro.obs.metrics import (MetricsRegistry, default_registry, scoped,
                               unified_snapshot)
from repro.obs.trace import (NULL_TRACE, NullTracer, QueryTrace, Span,
                             Tracer)

__all__ = [
    "AuditReport", "ConservationError", "audit", "check",
    "chrome_trace", "chrome_trace_json", "waterfall", "waterfall_query",
    "MetricsRegistry", "default_registry", "scoped", "unified_snapshot",
    "NULL_TRACE", "NullTracer", "QueryTrace", "Span", "Tracer",
]
