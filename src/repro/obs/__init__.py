"""Observability: deterministic query tracing, scoped metrics, analysis.

- `trace`         per-query span trees on the VirtualClock
                  (Tracer/NullTracer)
- `metrics`       scoped counter/gauge/histogram registry + unified
                  snapshot
- `audit`         conservation checker: span bytes/joules == ledger lines
- `export`        Chrome-trace-event JSON (Perfetto) + plain-text
                  waterfall
- `critical_path` per-query critical-path extraction + bottleneck
                  attribution, reconciled against the audit
- `timeseries`    fixed-cadence ring-buffer series on the VirtualClock
- `slo`           multi-window multi-burn-rate SLO alerting (per-tenant
                  error budgets, deterministic virtual timestamps)
- `diff`          trace-diff regression explanation (per-category,
                  per-shape wall-time attribution between two runs)
"""
from repro.obs.audit import AuditReport, ConservationError, audit, check
from repro.obs.critical_path import (CriticalPath, Segment, attribute,
                                     critical_path, verify)
from repro.obs.diff import (DiffReport, DiffRow, diff_digests, diff_traces,
                            digest, trace_category_seconds)
from repro.obs.export import (chrome_trace, chrome_trace_json, waterfall,
                              waterfall_query)
from repro.obs.metrics import (MetricsRegistry, default_registry, scoped,
                               unified_snapshot)
from repro.obs.slo import Alert, BurnRateRule, SLOMonitor, default_rules
from repro.obs.timeseries import RingSeries
from repro.obs.trace import (NULL_TRACE, NullTracer, QueryTrace, Span,
                             Tracer)

__all__ = [
    "AuditReport", "ConservationError", "audit", "check",
    "CriticalPath", "Segment", "attribute", "critical_path", "verify",
    "DiffReport", "DiffRow", "diff_digests", "diff_traces", "digest",
    "trace_category_seconds",
    "chrome_trace", "chrome_trace_json", "waterfall", "waterfall_query",
    "MetricsRegistry", "default_registry", "scoped", "unified_snapshot",
    "Alert", "BurnRateRule", "SLOMonitor", "default_rules",
    "RingSeries",
    "NULL_TRACE", "NullTracer", "QueryTrace", "Span", "Tracer",
]
