"""Critical-path extraction: which stage owned each modeled second.

PR 9's span trees account for every byte and joule; this module answers
the *time* question: for one query, decompose the closed interval
[submitted_at, t_end] into contiguous segments, each owned by exactly one
span — the critical path. Under the synchronous layout that is trivially
the read sequence; under `PrefetchPipeline` overlap it is the max branch
per stage window (`max(scan_k, stream_{k+1})`), **never** the sum — a
capacity stream that finished under a longer scan contributes zero path
time (its bytes are attributed off-path), while a stream that outlasted
the scan owns the window as `stream_wait`.

The path is *reconstructed from span geometry*, not re-derived from the
pipeline plan: the same window model `obs.trace.layout_pipeline` stamped
onto the spans is read back off them, so a layout bug surfaces as a
closure failure here instead of being reproduced twice.

Categories (`Segment.category`):

- ``queue``          admission wait, submit -> dispatch
- ``fast_read``      a fast-tier scan on the path (nominal hit reads and
                     staged chunks' fast-buffer re-reads)
- ``capacity_read``  a capacity-tier read on the path (sync misses,
                     stall-degraded streams)
- ``stream_wait``    a stage window bound by the *next* chunk's capacity
                     stream — the overlap's residual exposure
- ``recovery``       chaos extras: stall rides, retries, failovers,
                     repairs, shard failovers
- ``throttle``       power-cap stretch beyond busy time

Invariants (`critical_path` records violations in `problems`; `verify`
raises):

1. *closure* — segments tile [submitted_at, t_end] contiguously; window
   boundaries are exact (shared floats from one layout pass), the final
   endpoint matches t_end to 1e-9 relative (service_s sums bytes before
   dividing, the layout cursor divides per chunk — same value, different
   float association);
2. *byte conservation* — on-path bytes + off-path bytes equal the span
   tree's `bytes_by_ledger()` **exactly** (int compare) per
   (ledger kind, tier): every byte is either on the path or attributed
   to a hidden branch, never dropped or double-counted;
3. via `verify`, the whole trace still reconciles against the
   EnergyMeter ledger through `obs.audit.check` — path attribution and
   the conservation audit are one story.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.audit import ConservationError, check

# span kinds the chaos harness lays out sequentially after the reads
_RECOVERY_KINDS = ("stall", "retry", "failover", "repair",
                   "shard_failover")

CATEGORIES = ("queue", "fast_read", "capacity_read", "stream_wait",
              "recovery", "throttle")

_REL_TOL = 1e-9


@dataclass(frozen=True)
class Segment:
    """One owned, contiguous interval of a query's critical path."""

    category: str
    kind: str                # the owning span's kind
    t0: float
    dur_s: float
    nbytes: int = 0
    tier: str | None = None
    ledger: str | None = None

    @property
    def t1(self) -> float:
        return self.t0 + self.dur_s


@dataclass
class CriticalPath:
    """One query's path decomposition + its reconciliation evidence."""

    qid: int
    tenant: int
    shape: str
    met: bool | None
    degraded: bool
    t0: float                # submitted_at
    t1: float                # t_end
    segments: list = field(default_factory=list)
    on_path_bytes: dict = field(default_factory=dict)
    off_path_bytes: dict = field(default_factory=dict)
    problems: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.problems

    @property
    def total_s(self) -> float:
        return self.t1 - self.t0

    def seconds_by_category(self) -> dict:
        out: dict = {}
        for seg in self.segments:
            out[seg.category] = out.get(seg.category, 0.0) + seg.dur_s
        return out


def _tol(path_end: float) -> float:
    return _REL_TOL * max(abs(path_end), 1.0)


def _category(sp) -> str:
    if sp.kind in _RECOVERY_KINDS:
        return "recovery"
    if sp.kind == "throttle":
        return "throttle"
    if sp.kind == "admission":
        return "queue"
    return "fast_read" if sp.tier == "fast" else "capacity_read"


def _seg(sp, category: str | None = None, *, t0=None, dur=None) -> Segment:
    return Segment(category=category or _category(sp), kind=sp.kind,
                   t0=sp.t0 if t0 is None else t0,
                   dur_s=sp.dur_s if dur is None else dur,
                   nbytes=sp.nbytes, tier=sp.tier, ledger=sp.ledger)


def _add_bytes(acc: dict, sp) -> None:
    if sp.ledger is None or sp.nbytes == 0:
        return
    key = (sp.ledger, sp.tier)
    acc[key] = acc.get(key, 0) + sp.nbytes


def critical_path(qt) -> CriticalPath:
    """Extract one traced query's critical path from its span tree.

    Works on both layouts from the geometry alone: scan-side spans (the
    `prefetch_read` re-reads plus every `read` not marked staged) define
    the stage windows; a window whose scan reaches the next window's
    start is scan-bound, otherwise it is owned by the next stage's
    capacity stream (the staged `read` span that ends there). Recovery
    and throttle spans are sequential by construction. Never raises —
    violations land in `.problems` (see `verify`).
    """
    cp = CriticalPath(qid=qt.qid, tenant=qt.tenant,
                      shape=getattr(qt, "shape", "scan"), met=qt.met,
                      degraded=qt.degraded, t0=qt.submitted_at,
                      t1=qt.t_end if qt.t_end is not None else
                      qt.submitted_at)
    if qt.t_start is None or qt.t_end is None:
        cp.problems.append("query was never served (no t_start/t_end)")
        return cp

    on_path: list = []       # owning spans, for the byte split
    # --- queue: the admission span, [submitted_at, t_start] ---------------
    for sp in qt.spans:
        if sp.kind == "admission":
            cp.segments.append(_seg(sp, "queue"))
            on_path.append(sp)
            break
    else:
        cp.problems.append("no admission span")

    # --- stage windows from the scan-side spans ---------------------------
    scan_side = sorted(
        (sp for sp in qt.spans
         if sp.kind == "prefetch_read"
         or (sp.kind == "read" and not sp.attrs.get("staged"))),
        key=lambda sp: sp.t0)
    staged = {sp.attrs["cid"]: sp for sp in qt.reads
              if sp.attrs.get("staged")}
    for k, sp in enumerate(scan_side):
        if k + 1 < len(scan_side):
            w_end = scan_side[k + 1].t0
        else:
            w_end = sp.t1
        if sp.t1 >= w_end:       # scan-bound window (exact: shared floats)
            cp.segments.append(_seg(sp, t0=sp.t0, dur=w_end - sp.t0))
            on_path.append(sp)
        else:                    # the next stage's stream owns the window
            nxt_cid = scan_side[k + 1].attrs.get("cid")
            stream = staged.get(nxt_cid)
            if stream is None:
                cp.problems.append(
                    f"window [{sp.t0:.6g}, {w_end:.6g}] outlasts its scan "
                    f"but no staged stream for cid={nxt_cid!r} ends there")
                continue
            cp.segments.append(_seg(stream, "stream_wait",
                                    t0=sp.t0, dur=w_end - sp.t0))
            on_path.append(stream)

    # --- recovery + throttle: sequential spans past the reads -------------
    for sp in qt.spans:
        if sp.kind in _RECOVERY_KINDS or sp.kind == "throttle":
            cp.segments.append(_seg(sp))
            on_path.append(sp)

    # --- closure: segments tile [submitted_at, t_end] ---------------------
    cp.segments.sort(key=lambda s: (s.t0, s.t1))
    tol = _tol(cp.t1)
    cursor = qt.submitted_at
    for seg in cp.segments:
        if abs(seg.t0 - cursor) > tol:
            cp.problems.append(
                f"gap/overlap at {seg.category}/{seg.kind}: segment "
                f"starts {seg.t0!r}, path cursor {cursor!r}")
        cursor = seg.t1
    if abs(cursor - qt.t_end) > tol:
        cp.problems.append(
            f"path closes at {cursor!r}, query t_end {qt.t_end!r} "
            f"(diff {cursor - qt.t_end:.3g} s > tol {tol:.3g})")

    # --- byte conservation: on-path + off-path == bytes_by_ledger ---------
    owner_ids = {id(sp) for sp in on_path}
    for sp in qt.spans:
        _add_bytes(cp.on_path_bytes if id(sp) in owner_ids
                   else cp.off_path_bytes, sp)
    want = qt.bytes_by_ledger()
    got = dict(cp.on_path_bytes)
    for key, n in cp.off_path_bytes.items():
        got[key] = got.get(key, 0) + n
    if got != want:
        cp.problems.append(
            f"path bytes (on+off) {got} != span tree bytes {want}")
    return cp


@dataclass
class Attribution:
    """Bottleneck attribution aggregated across a traced replay."""

    queries: int
    missed: int
    seconds: dict            # category -> total path seconds
    miss_seconds: dict       # category -> path seconds of SLA-missed qs
    shape_seconds: dict      # (shape, category) -> total path seconds
    paths: list
    problems: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.problems

    @property
    def total_s(self) -> float:
        return sum(self.seconds.values())

    @property
    def miss_total_s(self) -> float:
        return sum(self.miss_seconds.values())

    def fractions(self, *, missed_only: bool = False) -> dict:
        src = self.miss_seconds if missed_only else self.seconds
        total = sum(src.values())
        if total <= 0:
            return {k: 0.0 for k in src}
        return {k: v / total for k, v in sorted(src.items())}

    def render(self) -> str:
        lines = [f"critical-path attribution: {self.queries} queries "
                 f"({self.missed} SLA-missed), "
                 f"{self.total_s:.6g} s of path time"]
        order = sorted(self.seconds, key=self.seconds.get, reverse=True)
        fr_all = self.fractions()
        fr_miss = self.fractions(missed_only=True)
        for cat in order:
            lines.append(
                f"  {cat:<14s} {self.seconds[cat]:>12.6g} s "
                f"({fr_all.get(cat, 0.0):6.1%} of all, "
                f"{fr_miss.get(cat, 0.0):6.1%} of SLA-miss time)")
        for p in self.problems:
            lines.append(f"  ! {p}")
        return "\n".join(lines)


def attribute(tracer) -> Attribution:
    """Aggregate per-category path seconds over every traced query —
    the "capacity reads account for X% of SLA-miss time" number."""
    seconds: dict = {}
    miss_seconds: dict = {}
    shape_seconds: dict = {}
    paths = []
    problems: list = []
    missed = 0
    for qt in tracer.queries:
        cp = critical_path(qt)
        paths.append(cp)
        problems.extend(f"qid={cp.qid}: {p}" for p in cp.problems)
        is_miss = cp.met is False
        missed += is_miss
        for cat, s in cp.seconds_by_category().items():
            seconds[cat] = seconds.get(cat, 0.0) + s
            shape_seconds[(cp.shape, cat)] = \
                shape_seconds.get((cp.shape, cat), 0.0) + s
            if is_miss:
                miss_seconds[cat] = miss_seconds.get(cat, 0.0) + s
    return Attribution(queries=len(paths), missed=missed, seconds=seconds,
                       miss_seconds=miss_seconds,
                       shape_seconds=shape_seconds, paths=paths,
                       problems=problems)


def verify(tracer, meter) -> Attribution:
    """The full reconciliation: the conservation audit (span bytes/joules
    == EnergyMeter lines, exact) AND every query's critical path closing
    over [submitted_at, t_end] with exact byte attribution. Raises
    ConservationError on any violation; returns the Attribution."""
    check(tracer, meter)
    attr = attribute(tracer)
    if not attr.ok:
        raise ConservationError(
            "critical-path reconciliation failed:\n  "
            + "\n  ".join(attr.problems))
    return attr
