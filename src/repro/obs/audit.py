"""Conservation audit: every traced byte and joule equals a ledger line.

The ROADMAP's standing accounting contract — every byte charged once,
never twice, on exactly one ledger kind — used to be a review convention
policed by hand-written property tests per subsystem. With the tracer in
place it becomes a machine-checked invariant over the *whole* execution
path: for each traced query

- span bytes by ledger kind must equal the EnergyMeter lines of that
  kind for that qid, **exactly** (int compare):
  kind="query"    == the nominal on_access split,
  kind="recovery" == the chaos harness's single recovery line,
  kind="prefetch" == staged re-reads + cancelled-stream waste;
- the kind="query" span bytes must also equal the engine's
  `bytes_scanned` for the query (`QueryTrace.bytes_expected`);
- memory joules per kind, recomputed from the span byte sums through the
  same `TierPair.energy_components` the meter prices with, must be
  *bitwise* equal to the lines' joules (same function, same ints in —
  float equality is exact, not approximate);
- compute joules, recomputed as `compute_w * chips * busy_s` from the
  compute span (the same expression `EnergyMeter.charge_compute`
  evaluates), must be bitwise equal to the lines' compute term.

A double charge (PRs 6-7's bug class), a dropped span, or a byte landing
on the wrong kind all surface as an exact mismatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field

_KINDS = ("query", "recovery", "prefetch")


class ConservationError(ValueError):
    """The span-attributed bytes/joules and the energy ledger disagree."""


@dataclass
class QueryAudit:
    """One query's reconciliation: span sums vs ledger lines."""

    qid: int
    span_bytes: dict        # kind -> (fast_bytes, capacity_bytes)
    ledger_bytes: dict      # kind -> (fast_bytes, capacity_bytes)
    problems: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.problems


@dataclass
class AuditReport:
    queries: list
    problems: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.problems and all(q.ok for q in self.queries)

    def render(self) -> str:
        lines = [f"conservation audit: {len(self.queries)} queries, "
                 f"{'OK' if self.ok else 'FAILED'}"]
        for p in self.problems:
            lines.append(f"  ! {p}")
        for q in self.queries:
            for p in q.problems:
                lines.append(f"  ! qid={q.qid}: {p}")
        return "\n".join(lines)


def _span_sums(qt) -> dict:
    """kind -> [fast_bytes, capacity_bytes] over the query's spans."""
    sums = {k: [0, 0] for k in _KINDS}
    for sp in qt.spans:
        if sp.ledger is None or sp.nbytes == 0:
            continue
        if sp.ledger not in sums:
            raise ConservationError(
                f"qid={qt.qid}: span kind={sp.kind!r} carries unknown "
                f"ledger {sp.ledger!r} (must be one of {_KINDS})")
        if sp.tier not in ("fast", "capacity"):
            raise ConservationError(
                f"qid={qt.qid}: span kind={sp.kind!r} carries bytes but "
                f"tier={sp.tier!r} (must be 'fast' or 'capacity')")
        sums[sp.ledger][0 if sp.tier == "fast" else 1] += sp.nbytes
    return sums


def audit_query(qt, meter) -> QueryAudit:
    """Reconcile one traced query against the meter's lines for its qid."""
    spans = _span_sums(qt)
    lines = [c for c in meter.charges if c.qid == qt.qid]
    ledger = {k: [0, 0] for k in _KINDS}
    ledger_j = {k: [0.0, 0.0] for k in _KINDS}
    compute_lines_j = 0.0
    for c in lines:
        if c.kind not in ledger:
            ledger[c.kind] = [0, 0]
            ledger_j[c.kind] = [0.0, 0.0]
        ledger[c.kind][0] += c.fast_bytes
        ledger[c.kind][1] += c.capacity_bytes
        ledger_j[c.kind][0] += c.fast_j
        ledger_j[c.kind][1] += c.capacity_j
        compute_lines_j += c.compute_j
    problems: list[str] = []
    # --- bytes: exact int equality per ledger kind and tier ---------------
    for kind in sorted(set(spans) | set(ledger)):
        s = tuple(spans.get(kind, (0, 0)))
        led = tuple(ledger.get(kind, (0, 0)))
        if s != led:
            problems.append(
                f"kind={kind!r} bytes (fast, capacity): spans attribute "
                f"{s}, ledger charged {led}")
    # --- the engine's bytes_scanned is the query-kind span total ----------
    nominal = sum(spans["query"])
    if nominal != qt.bytes_expected:
        problems.append(
            f"query-kind span bytes {nominal} != bytes_scanned "
            f"{qt.bytes_expected}")
    # --- memory joules: recompute from span byte sums, bitwise ------------
    n_by_kind: dict = {}
    for c in lines:
        n_by_kind[c.kind] = n_by_kind.get(c.kind, 0) + 1
    for kind, (fb, cb) in spans.items():
        want_f, want_c = meter.tiers.energy_components(fb, cb)
        got_f, got_c = ledger_j.get(kind, (0.0, 0.0))
        if n_by_kind.get(kind, 0) > 1:
            # several lines of one kind (not produced by the current
            # engine paths, but legal): summation order differs, so
            # equality is near-exact rather than bitwise
            close = (abs(want_f - got_f) <= 1e-9 * max(abs(want_f), 1.0)
                     and abs(want_c - got_c)
                     <= 1e-9 * max(abs(want_c), 1.0))
            if not close:
                problems.append(
                    f"kind={kind!r} joules: spans imply "
                    f"({want_f}, {want_c}), ledger holds "
                    f"({got_f}, {got_c})")
        elif (want_f, want_c) != (got_f, got_c):
            problems.append(
                f"kind={kind!r} joules: spans imply ({want_f}, {want_c}), "
                f"ledger holds ({got_f}, {got_c})")
    # --- compute joules: the charge_compute expression, bitwise -----------
    want_compute = meter.compute_w * qt.chips * qt.busy_s
    if want_compute != compute_lines_j:
        problems.append(
            f"compute joules: compute_w*chips*busy_s = {want_compute} "
            f"(chips={qt.chips}, busy_s={qt.busy_s}), ledger holds "
            f"{compute_lines_j}")
    return QueryAudit(qid=qt.qid, span_bytes={k: tuple(v)
                                              for k, v in spans.items()},
                      ledger_bytes={k: tuple(v)
                                    for k, v in ledger.items()},
                      problems=problems)


def audit(tracer, meter) -> AuditReport:
    """Reconcile every traced query; also flags ledger lines whose qid
    was never traced (bytes charged outside any traced query — with a
    tracer attached from the start, that is itself a leak)."""
    traced = {qt.qid for qt in tracer.queries}
    report = AuditReport(queries=[audit_query(qt, meter)
                                  for qt in tracer.queries])
    stray = sorted({c.qid for c in meter.charges
                    if c.qid is not None and c.qid not in traced})
    if stray:
        report.problems.append(
            f"ledger lines charged to untraced qids {stray}")
    return report


def check(tracer, meter) -> AuditReport:
    """`audit`, raising ConservationError on any mismatch."""
    report = audit(tracer, meter)
    if not report.ok:
        raise ConservationError(report.render())
    return report
