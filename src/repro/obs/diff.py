"""Trace-diff: explain a performance delta by span category per shape.

Two runs of the same workload rarely differ uniformly — a regression
lives somewhere: capacity reads grew, the prefetch overlap stopped
hiding streams, recovery time doubled. This module turns "the headline
dropped 30%" into "capacity_read seconds per query grew 41% on grouped
queries":

- `digest(engine, tracer=None)` — a JSON-safe per-run summary: a pruned
  `unified_snapshot` plus per-(shape, category) critical-path seconds.
  With a `Tracer` the categories are the exact per-query critical paths
  (`obs.critical_path`); without one they are derived from the byte
  ledgers at tier rates (coarser, marked ``exact: false``). BENCH_*.json
  trajectory rows carry this digest under ``rec["obs"]``.
- `diff_digests(base, new)` / `diff_traces(a, b)` — attribute the
  per-query wall-time delta across categories, normalized per query so
  rows with different query counts still compare.
- `benchmarks/check_regress.py` uses the result to *name* the dominant
  regressing category when its gate trips, instead of just failing.

Category keys serialize as ``"<shape>/<category>"`` (JSON objects need
string keys); shapes are the engine's "scan" | "grouped" | "join", or
"all" for derived digests that cannot split by shape.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.critical_path import CATEGORIES, attribute
from repro.obs.metrics import unified_snapshot

DIGEST_VERSION = 1

# snapshot scalars worth carrying into a trajectory row: enough to
# explain a delta, small enough to live in JSON forever
_SNAPSHOT_KEYS = (
    "engine.queries", "engine.bytes_scanned", "engine.logical_bytes",
    "engine.seconds",
    "tier.policy", "tier.hit_rate", "tier.fast_bytes",
    "tier.capacity_bytes", "tier.recovery_bytes",
    "prefetch.streamed_bytes", "prefetch.wasted_bytes",
    "energy.total_j", "energy.recovery_j", "energy.prefetch_j",
    "sla.served", "sla.rejected", "sla.degraded", "sla.attainment",
)


def trace_category_seconds(tracer) -> dict:
    """Exact per-("<shape>/<category>") critical-path seconds across a
    traced run (string keys, JSON-ready)."""
    attr = attribute(tracer)
    return {f"{shape}/{cat}": s
            for (shape, cat), s in sorted(attr.shape_seconds.items())}


def _derived_categories(engine) -> dict:
    """No-tracer fallback: byte ledgers priced at tier rates. Coarse on
    purpose — it cannot split by shape or see overlap, but it moves when
    the same ledgers move, which is what a regression explainer needs."""
    pe = engine.tiered
    if pe is None:
        # a flat engine measures wall time; there is no modeled ledger
        # to attribute, so the digest diffs on snapshot scalars alone
        return {}
    chips = engine.n_shards
    fast_bw = pe.tiers.fast.bandwidth * chips
    cap_bw = pe.tiers.capacity.bandwidth * chips
    out = {
        "all/fast_read": pe.fast_bytes_total / fast_bw,
        "all/capacity_read": pe.capacity_bytes_total / cap_bw,
    }
    if pe.recovery_bytes_total:
        # recovery bytes already sit inside the fast/capacity totals;
        # surface them as their own signal too (overlapping views, not
        # a partition — digests are diffed per key, never summed)
        out["all/recovery"] = pe.recovery_bytes_total / cap_bw
    if pe.prefetch_streamed_bytes_total:
        out["all/stream_wait"] = (pe.prefetch_streamed_bytes_total
                                  / cap_bw)
    if engine.power_cap is not None:
        out["all/throttle"] = engine.power_cap.throttle_s_total
    return {k: v for k, v in sorted(out.items())}


def digest(engine, tracer=None) -> dict:
    """The per-run summary a BENCH trajectory row carries (JSON-safe)."""
    snap = unified_snapshot(engine)
    kept = {k: snap[k] for k in _SNAPSHOT_KEYS if k in snap}
    for k in sorted(snap):
        if k.startswith("launches."):
            kept[k] = snap[k]
    if tracer is not None and len(tracer.queries):
        attr = attribute(tracer)
        cats = trace_category_seconds(tracer)
        exact = attr.ok
        queries = attr.queries
    else:
        cats = _derived_categories(engine)
        exact = False
        queries = len(engine.reports)
    return {"v": DIGEST_VERSION, "queries": queries, "exact": exact,
            "snapshot": kept, "categories": cats}


@dataclass(frozen=True)
class DiffRow:
    """One category's per-query seconds in both runs."""

    shape: str
    category: str
    base_s: float            # per-query seconds in the baseline run
    new_s: float             # per-query seconds in the new run
    delta_s: float           # new - base; positive = slower

    @property
    def key(self) -> str:
        return f"{self.shape}/{self.category}"

    @property
    def ratio(self) -> float:
        if self.base_s > 0:
            return self.new_s / self.base_s
        return float("inf") if self.new_s > 0 else 1.0


@dataclass
class DiffReport:
    """Attributed wall-time delta between two digests."""

    rows: list               # DiffRow, sorted most-regressing first
    base_queries: int
    new_queries: int
    base_total_s: float      # per-query category seconds, baseline
    new_total_s: float
    exact: bool              # both sides carried exact trace paths
    snapshot_deltas: dict = field(default_factory=dict)

    @property
    def delta_total_s(self) -> float:
        return self.new_total_s - self.base_total_s

    def dominant(self):
        """The top *regressing* row (largest positive per-query delta),
        or None when nothing got slower."""
        for row in self.rows:
            if row.delta_s > 0:
                return row
        return None

    def render(self) -> str:
        kind = "exact critical-path" if self.exact else "ledger-derived"
        lines = [f"trace diff ({kind} categories, per-query seconds): "
                 f"{self.base_total_s:.6g} -> {self.new_total_s:.6g} s "
                 f"({self.delta_total_s:+.3g} s)"]
        for row in self.rows:
            lines.append(
                f"  {row.key:<24s} {row.base_s:>12.6g} -> "
                f"{row.new_s:>12.6g} s  ({row.delta_s:+.3g} s, "
                f"x{row.ratio:.3g})")
        dom = self.dominant()
        if dom is not None:
            lines.append(f"  dominant regression: {dom.key} "
                         f"({dom.delta_s:+.3g} s/query)")
        else:
            lines.append("  no category regressed")
        for key, (b, n) in sorted(self.snapshot_deltas.items()):
            lines.append(f"  snapshot {key}: {b!r} -> {n!r}")
        return "\n".join(lines)


def diff_digests(base: dict, new: dict) -> DiffReport:
    """Attribute the per-query delta between two `digest()` dicts."""
    qb = max(int(base.get("queries", 0)), 1)
    qn = max(int(new.get("queries", 0)), 1)
    bc = base.get("categories", {})
    nc = new.get("categories", {})
    rows = []
    for key in sorted(set(bc) | set(nc)):
        shape, _, cat = key.partition("/")
        b = bc.get(key, 0.0) / qb
        n = nc.get(key, 0.0) / qn
        rows.append(DiffRow(shape=shape, category=cat, base_s=b,
                            new_s=n, delta_s=n - b))
    rows.sort(key=lambda r: (-r.delta_s, r.key))
    deltas = {}
    bs, ns = base.get("snapshot", {}), new.get("snapshot", {})
    for key in sorted(set(bs) | set(ns)):
        if bs.get(key) != ns.get(key):
            deltas[key] = (bs.get(key), ns.get(key))
    return DiffReport(
        rows=rows, base_queries=qb, new_queries=qn,
        base_total_s=sum(r.base_s for r in rows),
        new_total_s=sum(r.new_s for r in rows),
        exact=bool(base.get("exact")) and bool(new.get("exact")),
        snapshot_deltas=deltas)


def diff_traces(tracer_base, tracer_new) -> DiffReport:
    """Diff two traced runs directly (both sides exact)."""
    base = {"queries": len(tracer_base.queries), "exact": True,
            "categories": trace_category_seconds(tracer_base)}
    new = {"queries": len(tracer_new.queries), "exact": True,
           "categories": trace_category_seconds(tracer_new)}
    return diff_digests(base, new)


__all__ = ["CATEGORIES", "DIGEST_VERSION", "DiffRow", "DiffReport",
           "digest", "diff_digests", "diff_traces",
           "trace_category_seconds"]
