"""Scoped counter/gauge/histogram registry: one metrics namespace per scope.

`kernels.dispatch` used to keep launch counters in module-global state, so
two engines in one process polluted each other's counts and a test could
only assert launches by resetting the world. This module replaces that
with explicit `MetricsRegistry` scopes on a dynamic stack:

- the *default* registry sits at the bottom of the stack forever and
  accumulates everything — `dispatch.launch_counts()` & friends are shims
  over it, so every existing assert keeps its exact behavior;
- a `scoped(registry)` context pushes a second registry; increments land
  in **every** active scope, so an engine that wraps its execution in its
  own scope sees only its own launches while the global view still adds
  up.

The registry also names the canonical cross-subsystem byte keys:
`unified_snapshot(engine)` folds the per-subsystem `stats()` dicts
(placement, prefetch, energy, SLA) into one flat dotted-key namespace and
*cross-checks* the overlapping sources (e.g. the placement engine's
prefetch byte totals vs the pipeline's `stats()`), so a renamed or
double-counted key fails loudly instead of telling two stories.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Counter:
    """A monotonically increasing integer."""
    name: str
    value: int = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: inc({n}) — counters "
                             f"only go up; use a gauge for levels")
        self.value += n


@dataclass
class Gauge:
    """A level that can move both ways."""
    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        if not math.isfinite(v):
            raise ValueError(f"gauge {self.name!r}: set({v}) must be finite")
        self.value = float(v)


@dataclass
class Histogram:
    """Streaming summary of observed values (count/sum/min/max)."""
    name: str
    count: int = 0
    total: float = 0.0
    vmin: float = math.inf
    vmax: float = -math.inf

    def observe(self, v: float) -> None:
        if not math.isfinite(v):
            raise ValueError(f"histogram {self.name!r}: observe({v}) must "
                             f"be finite")
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def as_dict(self) -> dict:
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.vmin if self.count else None,
                "max": self.vmax if self.count else None}


_LAUNCH_PREFIX = "launches/"


@dataclass
class MetricsRegistry:
    """One named metrics scope. Get-or-create accessors, cheap snapshot."""

    name: str = "default"
    counters: dict = field(default_factory=dict)
    gauges: dict = field(default_factory=dict)
    histograms: dict = field(default_factory=dict)

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    # --- kernel-launch accounting (the dispatch shims' substrate) ---------
    def count_launch(self, family: str, n: int = 1) -> None:
        self.counter(_LAUNCH_PREFIX + family).inc(n)

    def launch_counts(self) -> dict[str, int]:
        """Per-family launch counts — the exact dict the old module-global
        `dispatch.launch_counts()` returned."""
        return {k[len(_LAUNCH_PREFIX):]: c.value
                for k, c in self.counters.items()
                if k.startswith(_LAUNCH_PREFIX) and c.value}

    def total_launches(self) -> int:
        return sum(self.launch_counts().values())

    def reset_launches(self) -> None:
        for k in [k for k in self.counters if k.startswith(_LAUNCH_PREFIX)]:
            del self.counters[k]

    def snapshot(self) -> dict:
        return {
            "scope": self.name,
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.as_dict()
                           for k, h in sorted(self.histograms.items())},
        }


# --------------------------------------------------------------------------
# the scope stack
# --------------------------------------------------------------------------

_DEFAULT = MetricsRegistry("default")
_STACK: list[MetricsRegistry] = [_DEFAULT]


def default_registry() -> MetricsRegistry:
    """The always-active bottom-of-stack scope (the old global state)."""
    return _DEFAULT


def active_scopes() -> tuple[MetricsRegistry, ...]:
    return tuple(_STACK)


@contextmanager
def scoped(registry: MetricsRegistry):
    """Push `registry` onto the scope stack: increments inside the block
    land in it *and* in every scope below (the default keeps the global
    view; the pushed scope isolates one engine's counts)."""
    _STACK.append(registry)
    try:
        yield registry
    finally:
        _STACK.remove(registry)


def count_launch(family: str, n: int = 1) -> None:
    """Record `n` kernel dispatches for `family` in every active scope."""
    for reg in _STACK:
        reg.count_launch(family, n)


def record_batch(family: str, width: int, n_chunks: int) -> None:
    """Record one *batched* launch covering `n_chunks` chunks at the
    unified payload width `width` — the width-group attribution the trace
    launch spans carry (counters `batch/<family>/w<width>` and
    `batch_chunks/<family>/w<width>` in every active scope)."""
    for reg in _STACK:
        reg.counter(f"batch/{family}/w{width}").inc(1)
        reg.counter(f"batch_chunks/{family}/w{width}").inc(n_chunks)


# --------------------------------------------------------------------------
# the unified snapshot (satellite: one canonical byte-key namespace)
# --------------------------------------------------------------------------

def unified_snapshot(engine) -> dict:
    """One flat dotted-key snapshot over every subsystem the engine
    carries — the canonical names the per-subsystem `stats()` dicts map
    into. Overlapping sources are cross-checked, not duplicated:

    - ``tier.recovery_bytes``     == PlacementEngine.recovery_bytes_total
                                  == PlacementEngine.stats()["recovery_bytes"]
    - ``prefetch.streamed_bytes`` == PlacementEngine
                                     .prefetch_streamed_bytes_total
                                  == PrefetchPipeline.stats()
                                     ["streamed_bytes"]
    - ``prefetch.wasted_bytes``   likewise for cancelled-stream waste

    A mismatch between the placement engine's totals and the pipeline's
    view raises ValueError — the byte accounting upstream broke.
    """
    out: dict = {
        "engine.queries": len(engine.results),
        "engine.bytes_scanned": int(engine.bytes_total),
        "engine.logical_bytes": int(engine.logical_bytes_total),
        "engine.seconds": engine.seconds_total,
    }
    for family, n in sorted(engine.metrics.launch_counts().items()):
        out[f"launches.{family}"] = n
    pe = engine.tiered
    if pe is not None:
        out["tier.policy"] = pe.policy.value
        out["tier.fast_bytes"] = int(pe.fast_bytes_total)
        out["tier.capacity_bytes"] = int(pe.capacity_bytes_total)
        out["tier.recovery_bytes"] = int(pe.recovery_bytes_total)
        out["tier.hit_rate"] = pe.hit_rate
        out["tier.chunk_hits"] = pe.hits_total
        out["tier.chunk_misses"] = pe.misses_total
        out["tier.demoted"] = pe.demoted
        out["prefetch.reserved_bytes"] = int(pe.prefetch_reserved_bytes)
        out["prefetch.streamed_bytes"] = \
            int(pe.prefetch_streamed_bytes_total)
        out["prefetch.wasted_bytes"] = int(pe.prefetch_wasted_bytes_total)
        m = pe.meter
        query_j = sum(c.total_j for c in m.charges if c.kind == "query")
        out["energy.query_j"] = query_j
        out["energy.recovery_j"] = m.recovery_j
        out["energy.prefetch_j"] = m.prefetch_j
        out["energy.memory_j"] = m.memory_j
        out["energy.compute_j"] = m.compute_j
        out["energy.total_j"] = m.total_j
        stats = pe.stats(engine.n_shards)
        if stats["recovery_bytes"] != out["tier.recovery_bytes"]:
            raise ValueError(
                f"PlacementEngine.stats()['recovery_bytes']="
                f"{stats['recovery_bytes']} disagrees with "
                f"recovery_bytes_total={out['tier.recovery_bytes']}")
    if engine.prefetch is not None:
        ps = engine.prefetch.stats()
        for snap_key, stats_key in (("prefetch.streamed_bytes",
                                     "streamed_bytes"),
                                    ("prefetch.wasted_bytes",
                                     "wasted_bytes")):
            if ps[stats_key] != out[snap_key]:
                raise ValueError(
                    f"PrefetchPipeline.stats()[{stats_key!r}]="
                    f"{ps[stats_key]} disagrees with {snap_key}="
                    f"{out[snap_key]}; the prefetch ledger and the "
                    f"placement totals must tell one story")
        out["prefetch.plans"] = ps["plans"]
        out["prefetch.staged_chunks"] = ps["staged_chunks"]
        out["prefetch.stalled_chunks"] = ps["stalled_chunks"]
        out["prefetch.cancelled_chunks"] = ps["cancelled_chunks"]
    rep = engine.reports
    out["sla.served"] = len(rep)
    out["sla.rejected"] = len(engine.queue.rejected)
    out["sla.degraded"] = sum(1 for r in rep if r.degraded)
    out["sla.attainment"] = (sum(1 for r in rep if r.met) / len(rep)
                             if rep else 1.0)
    return out
