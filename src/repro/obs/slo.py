"""Multi-window multi-burn-rate SLO alerting on the VirtualClock.

The SRE playbook's alerting structure, transplanted onto modeled time: a
tenant's error budget is `1 - target` of its events; the *burn rate* over
a window is `windowed_error_rate / (1 - target)` (burn 1.0 = spending the
budget exactly at the sustainable pace). A rule pairs a long window (is
the burn real?) with a short window (is it still happening?) and fires
only when BOTH exceed its threshold — the long window suppresses blips,
the short one makes alerts resolve promptly when the burn stops. Two
default rules (a fast/high-threshold pair for page-worthy burns and a
slow/low-threshold pair for budget leaks) are scaled off the monitor's
cadence, since our virtual runs last seconds, not weeks.

Determinism contract (pinned by tests/test_obs_analysis.py): the monitor
samples only at cadence ticks whose timestamps are *computed* as
`tick_index * cadence_s` — one multiplication, never float accumulation —
and every input is modeled (VirtualClock) time, so two same-seed chaos
replays emit byte-identical `alerts_json()` streams.

Error events: an SLA miss, a degraded (typed-failure) answer, or an
admission/shed rejection — the same definition `serve.sla.SLAReport.met`
and the rejected ledger use, so attainment here reconciles with
`summarize()`.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass

from repro.obs.timeseries import RingSeries


@dataclass(frozen=True)
class BurnRateRule:
    """One (long window, short window, threshold) alerting pair."""

    name: str
    long_s: float
    short_s: float
    threshold: float

    def __post_init__(self):
        if self.long_s <= 0 or self.short_s <= 0:
            raise ValueError(f"rule {self.name!r}: windows must be "
                             f"positive, got long={self.long_s} "
                             f"short={self.short_s}")
        if self.short_s > self.long_s:
            raise ValueError(f"rule {self.name!r}: short window "
                             f"{self.short_s} exceeds long window "
                             f"{self.long_s}")
        if self.threshold <= 0:
            raise ValueError(f"rule {self.name!r}: threshold must be "
                             f"positive, got {self.threshold}")


def default_rules(cadence_s: float) -> tuple:
    """The fast-page / slow-leak pair, scaled to the virtual cadence."""
    return (BurnRateRule("fast_burn", long_s=16 * cadence_s,
                         short_s=2 * cadence_s, threshold=4.0),
            BurnRateRule("slow_burn", long_s=64 * cadence_s,
                         short_s=8 * cadence_s, threshold=1.5))


@dataclass(frozen=True)
class Alert:
    """One deterministic alert transition at a virtual timestamp."""

    t: float
    kind: str                # "fire" | "resolve"
    rule: str
    tenant: int
    burn_long: float
    burn_short: float
    budget_remaining: float  # fraction of the whole-run budget left

    def as_dict(self) -> dict:
        return {"t": self.t, "kind": self.kind, "rule": self.rule,
                "tenant": self.tenant, "burn_long": self.burn_long,
                "burn_short": self.burn_short,
                "budget_remaining": self.budget_remaining}


class _TenantLedger:
    """Cumulative event/error counters + their sampled ring series."""

    __slots__ = ("events", "errors", "events_series", "errors_series")

    def __init__(self, tenant: int, capacity: int):
        self.events = 0
        self.errors = 0
        self.events_series = RingSeries(f"tenant{tenant}.events",
                                        capacity=capacity)
        self.errors_series = RingSeries(f"tenant{tenant}.errors",
                                        capacity=capacity)


class SLOMonitor:
    """Per-tenant burn-rate alerting fed by an engine's SLA stream.

    Wire it with `QueryEngine(monitor=...)` (or
    `replay_trace(monitor=...)`): the engine calls `observe` per served
    query, `observe_rejected` per admission/shed rejection, and `tick`
    after each service charge moves the VirtualClock. Standalone use
    follows the same three calls.
    """

    def __init__(self, *, target: float = 0.9, cadence_s: float = 0.01,
                 rules: tuple | None = None, capacity: int = 4096):
        if not (0.0 < target < 1.0):
            raise ValueError(f"target={target} must be in (0, 1): "
                             f"target 1.0 leaves a zero error budget and "
                             f"every error is an infinite burn")
        if not math.isfinite(cadence_s) or cadence_s <= 0:
            raise ValueError(f"cadence_s={cadence_s} must be a finite "
                             f"positive interval")
        self.target = float(target)
        self.cadence_s = float(cadence_s)
        self.rules = tuple(rules) if rules is not None \
            else default_rules(cadence_s)
        self.capacity = int(capacity)
        self.engine = None
        self.tenants: dict[int, _TenantLedger] = {}
        self.series: dict[str, RingSeries] = {}
        self.alerts: list[Alert] = []
        self._active: set = set()        # (rule.name, tenant) firing now
        self._next_tick = 0              # first not-yet-sampled tick index
        # widest lookback any rule needs, in ticks (for ring sizing docs)
        self.max_window_s = max((r.long_s for r in self.rules),
                                default=0.0)

    # --- wiring -----------------------------------------------------------
    def bind(self, engine) -> None:
        """Attach the engine whose gauges (blended rate, hit rate, watts,
        recovery/prefetch bytes) each tick samples. Requires tiered mode:
        gauges and tick timestamps live on the modeled clock."""
        if engine.tiered is None:
            raise ValueError(
                "SLOMonitor samples on the modeled (VirtualClock) "
                "timeline; pass tiered=repro.tier.PlacementEngine(...) "
                "to the engine as well")
        self.engine = engine

    def _ledger(self, tenant: int) -> _TenantLedger:
        led = self.tenants.get(tenant)
        if led is None:
            led = self.tenants[tenant] = _TenantLedger(tenant,
                                                       self.capacity)
        return led

    def _series(self, name: str) -> RingSeries:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = RingSeries(name,
                                               capacity=self.capacity)
        return s

    # --- event intake -----------------------------------------------------
    def observe(self, report, *, tenant: int = 0) -> None:
        """One served query's SLAReport: an event, and an error unless
        its deadline was met with a full answer."""
        led = self._ledger(tenant)
        led.events += 1
        led.errors += not report.met

    def observe_rejected(self, *, tenant: int = 0) -> None:
        """An admission or shed rejection: the promise was broken before
        service, which burns budget exactly like a miss."""
        led = self._ledger(tenant)
        led.events += 1
        led.errors += 1

    # --- sampling + rule evaluation ---------------------------------------
    def tick(self, t: float) -> list:
        """Sample every cadence boundary in (last sampled, t] and
        evaluate all rules at each; returns alerts emitted by this call.
        Tick i's timestamp is exactly `i * cadence_s`."""
        emitted: list[Alert] = []
        while self._next_tick * self.cadence_s <= t:
            ts = self._next_tick * self.cadence_s
            self._sample(ts)
            emitted.extend(self._evaluate(ts))
            self._next_tick += 1
        return emitted

    def _sample(self, ts: float) -> None:
        for led in self.tenants.values():
            led.events_series.push(ts, led.events)
            led.errors_series.push(ts, led.errors)
        eng = self.engine
        if eng is None:
            return
        pe = eng.tiered
        chips = eng.n_shards
        self._series("blended_gbps").push(
            ts, pe.blended_measured_bps(chips) / 1e9)
        self._series("hit_rate").push(ts, pe.hit_rate)
        self._series("recovery_bytes").push(ts, pe.recovery_bytes_total)
        self._series("prefetch_bytes").push(
            ts, pe.prefetch_streamed_bytes_total)
        if eng.power_cap is not None:
            self._series("watts").push(ts, eng.power_cap.watts(ts))
            self._series("cap_w").push(ts, eng.power_cap.budget_w)

    def _windowed_burn(self, led: _TenantLedger, ts: float,
                       window_s: float) -> float:
        """Burn rate over (ts - window, ts]: windowed error rate divided
        by the budget rate. Windows with no events burn 0.0."""
        ev1, er1 = led.events, led.errors
        ev0 = led.events_series.at_or_before(ts - window_s) or 0.0
        er0 = led.errors_series.at_or_before(ts - window_s) or 0.0
        events = ev1 - ev0
        if events <= 0:
            return 0.0
        return ((er1 - er0) / events) / (1.0 - self.target)

    def _evaluate(self, ts: float) -> list:
        emitted: list[Alert] = []
        for tenant in sorted(self.tenants):
            led = self.tenants[tenant]
            for rule in self.rules:
                burn_l = self._windowed_burn(led, ts, rule.long_s)
                burn_s = self._windowed_burn(led, ts, rule.short_s)
                key = (rule.name, tenant)
                firing = key in self._active
                if not firing and burn_l >= rule.threshold \
                        and burn_s >= rule.threshold:
                    self._active.add(key)
                    emitted.append(self._alert(ts, "fire", rule, tenant,
                                               burn_l, burn_s))
                elif firing and burn_s < rule.threshold:
                    self._active.discard(key)
                    emitted.append(self._alert(ts, "resolve", rule,
                                               tenant, burn_l, burn_s))
        self.alerts.extend(emitted)
        return emitted

    def _alert(self, ts, kind, rule, tenant, burn_l, burn_s) -> Alert:
        return Alert(t=ts, kind=kind, rule=rule.name, tenant=tenant,
                     burn_long=burn_l, burn_short=burn_s,
                     budget_remaining=self.error_budget(tenant)
                     ["remaining_fraction"])

    # --- reporting --------------------------------------------------------
    def error_budget(self, tenant: int = 0) -> dict:
        """Whole-run budget arithmetic: budget = (1 - target) * events;
        remaining_fraction < 0 means the tenant is over budget."""
        led = self.tenants.get(tenant)
        events = led.events if led is not None else 0
        errors = led.errors if led is not None else 0
        budget = (1.0 - self.target) * events
        return {
            "tenant": tenant,
            "events": events,
            "errors": errors,
            "budget_events": budget,
            "remaining_fraction": (1.0 - errors / budget) if budget > 0
            else 1.0,
        }

    def alerts_json(self) -> str:
        """The canonical alert stream: sorted keys, compact separators —
        the byte-identical-replay artifact."""
        return json.dumps([a.as_dict() for a in self.alerts],
                          sort_keys=True, separators=(",", ":"))

    def summary(self) -> dict:
        return {
            "target": self.target,
            "cadence_s": self.cadence_s,
            "rules": [{"name": r.name, "long_s": r.long_s,
                       "short_s": r.short_s, "threshold": r.threshold}
                      for r in self.rules],
            "ticks": self._next_tick,
            "alerts": len(self.alerts),
            "firing": sorted(self._active),
            "tenants": {t: self.error_budget(t)
                        for t in sorted(self.tenants)},
        }
