"""Per-query span trees on the VirtualClock: deterministic query tracing.

Every span is stamped in *modeled* time — the engine's VirtualClock,
never the wall clock — so a traced run is a pure function of (workload,
seed): replaying the same chaos trace twice exports byte-identical JSON
(tests/test_obs.py pins this down).

Span taxonomy (`Span.kind`):

- ``admission``       queue wait, submit -> dispatch
- ``read``            one chunk's nominal tier read (attrs: cid, hit,
                      inflight, staged); bytes on the kind="query" ledger
- ``prefetch_read``   a staged chunk's scan re-read from the fast staging
                      buffer (kind="prefetch" ledger, fast tier)
- ``prefetch_cancel`` a stream cancelled in flight: wasted capacity bytes
                      on the kind="prefetch" ledger
- ``prefetch_stall``  a stalled stream's wasted bytes — folded into the
                      query's single kind="recovery" line by the chaos
                      harness, so the span says ledger="recovery"
- ``stall``           a stalled fast read riding to completion (pure
                      extra seconds, no extra bytes)
- ``retry``           a re-issued fast read after timeout (recovery/fast)
- ``failover``        retry budget exhausted, capacity-tier re-read
                      (recovery/capacity)
- ``repair``          verify-on-read oracle re-read (recovery/capacity)
- ``shard_failover``  lost-shard degraded re-execution (recovery/capacity)
- ``launch``          kernel dispatches this query drove (attrs: family,
                      n), from the engine's scoped metrics delta
- ``launch_batch``    one batched launch group (attrs: family, width,
                      n, n_chunks) — the store executor's width groups
- ``compute``         the busy-time compute term (attrs: chips; joules =
                      compute_w * chips * busy_s, the charge_compute term)
- ``throttle``        power-cap stretch beyond busy time (race-to-idle:
                      no bytes, no joules)

Attribution contract: each span carries the `nbytes` and `joules` it
accounts for and the ledger `kind` those bytes were charged on
("query" | "recovery" | "prefetch"); `obs.audit` proves the span sums
equal the EnergyMeter's ledger lines exactly. Per-span joules are the
per-chunk share `nbytes * energy_per_byte`; the audit recomputes from
byte *sums* through the same `TierPair.energy_components`, so equality
with the ledger is bitwise, not approximate.

The disabled path allocates nothing: `NullTracer.begin_query` returns
the shared `NULL_TRACE` singleton whose methods are no-ops, and the
wire points skip span construction entirely when `trace is None`.
"""
from __future__ import annotations

import math


class Span:
    """One attributed interval (or instant, dur_s=0) of modeled time."""

    __slots__ = ("kind", "t0", "dur_s", "nbytes", "tier", "ledger",
                 "joules", "attrs")

    def __init__(self, kind: str, *, t0: float = 0.0, dur_s: float = 0.0,
                 nbytes: int = 0, tier: str | None = None,
                 ledger: str | None = None, joules: float = 0.0,
                 **attrs):
        self.kind = kind
        self.t0 = float(t0)
        self.dur_s = float(dur_s)
        self.nbytes = int(nbytes)
        self.tier = tier
        self.ledger = ledger
        self.joules = float(joules)
        self.attrs = attrs

    @property
    def t1(self) -> float:
        return self.t0 + self.dur_s

    def as_dict(self) -> dict:
        d = {"kind": self.kind, "t0": self.t0, "dur_s": self.dur_s,
             "nbytes": self.nbytes, "tier": self.tier,
             "ledger": self.ledger, "joules": self.joules}
        d.update(self.attrs)
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.kind!r}, t0={self.t0:.6g}, "
                f"dur={self.dur_s:.6g}, bytes={self.nbytes}, "
                f"ledger={self.ledger})")


class QueryTrace:
    """The span tree of one query (flat list + the root interval)."""

    enabled = True

    def __init__(self, qid: int, *, tenant: int = 0,
                 submitted_at: float = 0.0, deadline: float = math.inf,
                 bytes_expected: int = 0, shape: str = "scan"):
        self.qid = qid
        self.tenant = tenant
        self.submitted_at = submitted_at
        self.deadline = deadline
        self.bytes_expected = int(bytes_expected)
        self.shape = shape        # "scan" | "grouped" | "join" — the query
        #                           shape key trace-diff attribution uses
        self.spans: list[Span] = []
        self.reads: list[Span] = []   # the per-chunk "read" spans, in
        #                               on_access emission order
        self.t_start: float | None = None
        self.t_end: float | None = None
        self.busy_s = 0.0
        self.chips = 1
        self.met: bool | None = None
        self.degraded = False
        self.error: str | None = None

    # --- emission ---------------------------------------------------------
    def begin_run(self, t: float) -> None:
        self.t_start = float(t)
        self.add("admission", t0=self.submitted_at,
                 dur_s=max(t - self.submitted_at, 0.0))

    def add(self, kind: str, **kw) -> Span:
        sp = Span(kind, **kw)
        self.spans.append(sp)
        return sp

    def read(self, cid, nbytes: int, *, tier: str, hit: bool,
             inflight: bool = False, joules: float = 0.0) -> Span:
        """One chunk's nominal tier read. Emitted inside
        PlacementEngine.on_access — the traced hit/miss split is the
        charged one by construction, not a parallel re-derivation. The
        span's time window is filled in afterwards by layout_sync /
        layout_pipeline (on_access knows bytes and tiers, not the
        pipeline's stage windows)."""
        sp = self.add("read", nbytes=nbytes,
                      tier=tier, ledger="query", joules=joules,
                      cid=cid, hit=hit, inflight=inflight)
        self.reads.append(sp)
        return sp

    def compute(self, t0: float, busy_s: float, chips: int,
                joules: float) -> Span:
        self.busy_s = float(busy_s)
        self.chips = int(chips)
        return self.add("compute", t0=t0, dur_s=busy_s, joules=joules,
                        chips=chips)

    def close(self, t: float, *, met: bool, degraded: bool = False,
              error: str | None = None) -> None:
        self.t_end = float(t)
        self.met = bool(met)
        self.degraded = bool(degraded)
        self.error = error

    # --- attribution rollups (the audit's inputs) -------------------------
    def bytes_by_ledger(self) -> dict:
        """(ledger, tier) -> exact int byte sum over this query's spans."""
        out: dict = {}
        for sp in self.spans:
            if sp.ledger is None or sp.nbytes == 0:
                continue
            key = (sp.ledger, sp.tier)
            out[key] = out.get(key, 0) + sp.nbytes
        return out

    def joules_total(self) -> float:
        return sum(sp.joules for sp in self.spans)

    def span_kinds(self) -> dict:
        out: dict = {}
        for sp in self.spans:
            out[sp.kind] = out.get(sp.kind, 0) + 1
        return out


class _NullQueryTrace:
    """The disabled trace: every emission is a no-op, nothing allocates."""

    enabled = False
    spans: tuple = ()
    reads: tuple = ()

    def begin_run(self, t):
        pass

    def add(self, kind, **kw):
        return None

    def read(self, cid, nbytes, *, tier, hit, inflight=False, joules=0.0):
        return None

    def compute(self, t0, busy_s, chips, joules):
        return None

    def close(self, t, *, met, degraded=False, error=None):
        pass


NULL_TRACE = _NullQueryTrace()


class Tracer:
    """Collects one QueryTrace per served query, in service order."""

    enabled = True

    def __init__(self):
        self.queries: list[QueryTrace] = []

    def begin_query(self, qid: int, **kw) -> QueryTrace:
        qt = QueryTrace(qid, **kw)
        self.queries.append(qt)
        return qt

    def clear(self) -> None:
        self.queries.clear()

    def __len__(self) -> int:
        return len(self.queries)

    def summary(self) -> dict:
        kinds: dict = {}
        for qt in self.queries:
            for k, n in qt.span_kinds().items():
                kinds[k] = kinds.get(k, 0) + n
        return {"queries": len(self.queries),
                "spans": sum(len(qt.spans) for qt in self.queries),
                "span_kinds": kinds}


class NullTracer:
    """The allocation-free disabled tracer (the engine's default)."""

    enabled = False
    queries: tuple = ()

    def begin_query(self, qid: int, **kw):
        return NULL_TRACE

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


# --------------------------------------------------------------------------
# timeline layout: place the read spans the access path emitted
# --------------------------------------------------------------------------

def layout_sync(qt: QueryTrace, t0: float, tiers, chips: int) -> float:
    """Sequential tiered reads: each chunk at its tier's rate, in
    on_access emission order (the synchronous service model). Returns
    the cursor after the last read."""
    t = t0
    fast_bw = tiers.fast.bandwidth * chips
    cap_bw = tiers.capacity.bandwidth * chips
    for sp in qt.reads:
        sp.t0 = t
        sp.dur_s = sp.nbytes / (fast_bw if sp.tier == "fast" else cap_bw)
        t += sp.dur_s
    return t


def layout_pipeline(qt: QueryTrace, t0: float, plan, tiers,
                    chips: int) -> float:
    """Double-buffered reads: mirror PrefetchPipeline.plan's stage model
    (window k = max(scan_k, stream_{k+1})) onto the read spans, and emit
    the pipeline's own spans:

    - a live staged chunk's *read* span is its capacity stream, placed in
      the window it streamed under; its fast-buffer scan re-read becomes
      a ``prefetch_read`` span (kind="prefetch" ledger);
    - a cancelled stream adds ``prefetch_cancel`` (prefetch ledger);
    - a stalled stream adds ``prefetch_stall`` with ledger="recovery" —
      the chaos harness folds exactly those bytes into its single
      recovery line.

    Returns the cursor after the last stage window.
    """
    reads = {sp.attrs["cid"]: sp for sp in qt.reads}
    fast_e = tiers.fast.energy_per_byte
    cap_e = tiers.capacity.energy_per_byte
    stages = plan.stages
    if not stages:
        return layout_sync(qt, t0, tiers, chips)
    t = t0 + stages[0].stream_s          # pipeline fill (0 by scheduling:
    #                                      the first stage never streams)
    for k, st in enumerate(stages):
        nxt = stages[k + 1].stream_s if k + 1 < len(stages) else 0.0
        window = max(st.scan_s, nxt)
        sp = reads.get(st.cid)
        live = st.staged and not (st.stalled or st.cancelled)
        if live:
            if sp is not None:
                # the nominal capacity stream ran under the previous
                # window's scan, ending where this window begins
                sp.t0 = t - st.stream_s
                sp.dur_s = st.stream_s
                sp.attrs["staged"] = True
            qt.add("prefetch_read", t0=t, dur_s=st.scan_s,
                   nbytes=st.nbytes, tier="fast", ledger="prefetch",
                   joules=st.nbytes * fast_e, cid=st.cid)
        else:
            if sp is not None:
                sp.t0 = t
                sp.dur_s = st.scan_s
            if st.stalled:
                qt.add("prefetch_stall", t0=t, nbytes=st.nbytes,
                       tier="capacity", ledger="recovery",
                       joules=st.nbytes * cap_e, cid=st.cid)
            elif st.cancelled:
                qt.add("prefetch_cancel", t0=t, nbytes=st.nbytes,
                       tier="capacity", ledger="prefetch",
                       joules=st.nbytes * cap_e, cid=st.cid)
        t += window
    return t
