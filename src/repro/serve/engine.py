"""Batched serving: prefill + one-token decode steps and a slot-based
continuous-batching engine.

The decode step is the paper's workload reborn: one token streams the whole
parameter set + per-slot cache — arithmetic intensity ~1 FLOP/byte, i.e. the
bandwidth-bound regime the analytical model provisions for (DESIGN.md §2).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.attention import INF_POS
from repro.models.common import axes_names, dtype_of


def bucket_len(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floored at lo): prefill retraces per bucket,
    not per distinct prompt length."""
    b = lo
    while b < n:
        b *= 2
    return b


def make_prefill_step(cfg):
    """(params, inputs, caches) -> (last-position logits, new_caches).

    The head is applied to the LAST hidden state only — computing
    (B, S, vocab) logits and slicing afterwards costs 2*S*d*V extra FLOPs
    that XLA does not DCE through the dot (measured: 6.4 TFLOP/chip for
    minitron-4b at 32k/256k-vocab; EXPERIMENTS.md §Perf)."""

    def step(params, inputs, caches):
        hidden, new_caches, _ = lm.prefill(params, cfg, inputs, caches,
                                           return_hidden=True)
        return lm.head_logits(params, cfg, hidden[:, -1:])[:, 0], new_caches

    return step


def make_serve_step(cfg, sample: str = "greedy", temperature: float = 1.0):
    """(params, tokens (B,1) | embeds (B,1,D), cache_len (B,), caches, key)
    -> (next_token (B,), logits (B,V), new_caches)."""

    def step(params, inputs, cache_len, caches, key):
        logits, new_caches, _ = lm.decode_step(params, cfg, inputs,
                                               cache_len, caches)
        logits = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        return nxt.astype(jnp.int32), logits, new_caches

    return step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    generated: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching on top of the jitted steps.

    Fixed B decode slots with per-slot cache_len; a finished slot is refilled
    by prefilling the new request's prompt in a 1-row cache and inserting
    that row into the batch cache at the slot's batch index.

    Slot/length bookkeeping lives in a host-side numpy mirror so the step
    loop never blocks on a device sync per slot: the only forced transfer
    per decode step is the sampled tokens themselves. Prompts are padded to
    power-of-two buckets (attention-only stacks: padded ring slots are
    re-marked never-written via the pos plane) so `_prefill1` compiles once
    per bucket instead of once per distinct prompt length.
    """

    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        assert cfg.input_mode == "tokens", "engine drives token models"
        self.cfg, self.params = cfg, params
        self.B, self.max_len = batch_slots, max_len
        dt = dtype_of(cfg.dtype)
        self.caches, self.cache_axes = lm.init_caches(cfg, batch_slots,
                                                      max_len, dt)
        # host-side mirror: authoritative, device copy derives from it
        self.cache_len = np.zeros((batch_slots,), np.int32)
        self.slots: list[Request | None] = [None] * batch_slots
        self.key = jax.random.PRNGKey(seed)
        self._serve = jax.jit(make_serve_step(cfg))
        self._prefill1 = jax.jit(self._prefill_row)
        self._insert = jax.jit(self._insert_row)
        # recurrent (ssd/rglru) states carry real content at padded steps,
        # so only pure-attention stacks can bucket prompt lengths
        self._bucket = all(k == "attn" for k in cfg.block_pattern)

    # --- row-isolated prefill + insertion ---------------------------------
    def _prefill_row(self, params, tokens, length):
        caches1, axes1 = lm.init_caches(self.cfg, 1, self.max_len,
                                        dtype_of(self.cfg.dtype))
        logits, caches1, _ = lm.prefill(params, self.cfg, tokens[None],
                                        caches1)

        def mask_pad(c, a):
            # ring slots written by pad tokens revert to never-written
            if axes_names(a)[-1:] == ["kv_seq"] and c.dtype == jnp.int32:
                slot = jnp.arange(c.shape[-1], dtype=jnp.int32)
                return jnp.where(slot < length, c, INF_POS)
            return c

        if tokens.shape[0] > 1:   # padded bucket: mask the pos planes
            caches1 = jax.tree.map(mask_pad, caches1, axes1)
        last = jax.lax.dynamic_index_in_dim(logits[0], length - 1,
                                            keepdims=False)
        return last, caches1

    def _insert_row(self, caches, row_caches, slot):
        def f(c, a, r):
            i = axes_names(a).index("batch")
            return jax.lax.dynamic_update_slice_in_dim(
                c, r.astype(c.dtype), slot, axis=i)

        return jax.tree.map(f, caches, self.cache_axes, row_caches)

    def submit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                n = len(req.prompt)
                # never pad past the ring: pad positions would wrap and
                # evict real prompt K/V that mask_pad (slot-indexed)
                # cannot revert
                padded = (min(bucket_len(n), self.max_len)
                          if self._bucket and n <= self.max_len else n)
                prompt = np.zeros((padded,), np.int32)
                prompt[:n] = np.asarray(req.prompt, np.int32)
                logits, row = self._prefill1(
                    self.params, jnp.asarray(prompt),
                    jnp.asarray(n, jnp.int32))
                self.caches = self._insert(self.caches, row,
                                           jnp.asarray(i, jnp.int32))
                self.cache_len[i] = n
                req.generated.append(int(jnp.argmax(logits)))
                return True
        return False

    def step(self):
        """One decode step for all active slots."""
        active = [i for i, s in enumerate(self.slots) if s is not None
                  and not s.done]
        finished = []
        for i in list(active):
            r = self.slots[i]
            if len(r.generated) >= r.max_new_tokens \
                    or self.cache_len[i] >= self.max_len - 1:
                r.done = True
                finished.append(r)
                self.slots[i] = None
                active.remove(i)
        if not active:
            return finished
        last = np.zeros((self.B, 1), np.int32)
        for i in active:
            last[i, 0] = self.slots[i].generated[-1]
        self.key, sub = jax.random.split(self.key)
        # hand jax a copy it owns: on CPU, jnp.asarray can alias numpy
        # memory zero-copy, and the host mirror is mutated below while the
        # async step may still be reading it
        nxt, _, self.caches = self._serve(
            self.params, jnp.asarray(last), jnp.asarray(self.cache_len.copy()),
            self.caches, sub)
        for i in active:
            self.cache_len[i] += 1
        nxt = np.asarray(nxt)            # the step's one device sync
        for i in active:
            self.slots[i].generated.append(int(nxt[i]))
        return finished

    def run(self, requests):
        """Drive a list of requests to completion; returns them."""
        queue = deque(requests)
        done = []
        while queue or any(s is not None for s in self.slots):
            while queue and self.submit(queue[0]):
                queue.popleft()
            done.extend(self.step())
        return done
