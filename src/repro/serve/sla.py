"""Shared SLA machinery: deadline queues + latency/attainment summaries.

The paper provisions clusters against a response-time SLA; two runtime
subsystems enforce that contract at serving time — the LM request scheduler
(repro.serve.scheduler) and the analytic query engine (repro.query.engine).
Both share this module:

- `DeadlineQueue`: earliest-deadline-first ordering with feasibility-based
  admission control. `est_service_s(item)` estimates how long an item needs
  (tokens / decode rate for LM requests, bytes / measured scan rate for
  queries); items that cannot finish by their deadline even if started now
  are rejected at push, and items that became hopeless while queued are
  dropped at pop so a busy server never spends capacity on guaranteed
  misses.
- `SLAReport` / `summarize`: attained-vs-promised latency (p50/p99 and
  attainment fraction), the numbers the provisioning model's predictions
  are checked against in production.
- `blended_bps` / `VirtualClock`: tiered-memory service estimation. When a
  table spans a fast (die-stacked) and a capacity (DDR) tier, admission
  feasibility must be priced at the *blended* rate the placement engine
  attains, not either tier's datasheet rate; `VirtualClock` lets the
  tiered latency model drive deadlines deterministically in benchmarks
  and tests.
"""
from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


@dataclass(order=True)
class _Entry:
    deadline: float
    seq: int
    item: Any = field(compare=False)


@dataclass
class SLAReport:
    """One served item's attained latency vs its promised deadline."""
    rid: int
    deadline: float
    submitted_at: float
    finished_at: float
    work: float = 0.0            # tokens generated / bytes scanned
    degraded: bool = False       # typed-degraded answer (resilience):
    #                              served, but the SLA's promise — a full,
    #                              exact answer in time — was not kept

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def met(self) -> bool:
        return self.finished_at <= self.deadline and not self.degraded


class DeadlineQueue:
    """EDF queue with feasibility admission and hopeless-item shedding."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 est_service_s: Callable[[Any], float] = lambda item: 0.0):
        self.clock = clock
        self.est_service_s = est_service_s
        self._heap: list[_Entry] = []
        self._seq = 0
        self.rejected: list[Any] = []

    def __len__(self) -> int:
        return len(self._heap)

    def feasible(self, item, deadline: float) -> bool:
        return self.clock() + self.est_service_s(item) <= deadline

    def push(self, item, deadline: float) -> bool:
        """Admit iff the item could still meet its deadline; rejected items
        are recorded, not silently served late."""
        if not self.feasible(item, deadline):
            self.rejected.append(item)
            return False
        self.requeue(item, deadline)
        return True

    def requeue(self, item, deadline: float) -> None:
        """Re-insert without re-checking feasibility (an admitted item that
        could not be placed keeps its admission)."""
        self._seq += 1
        heapq.heappush(self._heap, _Entry(deadline, self._seq, item))

    def _prune(self) -> None:
        while self._heap and not self.feasible(self._heap[0].item,
                                               self._heap[0].deadline):
            self.rejected.append(heapq.heappop(self._heap).item)

    def peek(self):
        """(item, deadline) of the earliest still-feasible entry, or None."""
        self._prune()
        if not self._heap:
            return None
        return self._heap[0].item, self._heap[0].deadline

    def pop(self):
        """Pop the earliest still-feasible entry as (item, deadline)."""
        self._prune()
        if not self._heap:
            return None
        e = heapq.heappop(self._heap)
        return e.item, e.deadline

    def ordered_items(self) -> list:
        """Queued items in deadline order (inspection/tests only)."""
        return [e.item for e in sorted(self._heap)]


def blended_bps(fast_bps: float, capacity_bps: float,
                fast_fraction: float) -> float:
    """Effective service rate when `fast_fraction` of the bytes stream
    from the fast tier and the rest from the capacity tier (harmonic
    blend — time adds, bandwidth doesn't). This is the rate admission
    control must use for a tiered table: pricing feasibility at the fast
    tier's rate admits queries the capacity tier then misses."""
    if not (math.isfinite(fast_bps) and math.isfinite(capacity_bps)) \
            or fast_bps <= 0 or capacity_bps <= 0:
        raise ValueError(f"tier rates must be finite and positive, got "
                         f"fast={fast_bps} capacity={capacity_bps}")
    if not math.isfinite(fast_fraction):
        raise ValueError(f"fast_fraction={fast_fraction} must be finite; "
                         f"a NaN hit rate means the byte accounting "
                         f"upstream is broken")
    f = min(max(fast_fraction, 0.0), 1.0)
    return 1.0 / (f / fast_bps + (1.0 - f) / capacity_bps)


class VirtualClock:
    """A manually-advanced clock with the same callable interface as
    time.monotonic: deadline machinery (DeadlineQueue, QueryEngine) runs
    on modeled service times instead of wall time, so tier placement
    experiments are deterministic and CPU-speed-independent."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if not math.isfinite(dt) or dt < 0:
            # a NaN dt would pass a bare `dt < 0` check and silently
            # poison every later deadline comparison
            raise ValueError(f"cannot advance a clock by {dt} s; dt must "
                             f"be finite and non-negative")
        self.now += dt
        return self.now


def latency_percentile(latencies, q: float) -> float:
    """np.percentile with the edge cases pinned (regression-tested in
    tests/test_obs_analysis.py):

    - empty input  -> 0.0 (no latency evidence; a NaN would poison every
      downstream comparison, and "no queries" is not "slow queries")
    - one sample   -> that sample, for every q (the only order statistic)
    - all-equal    -> that value exactly (linear interpolation between
      equal order statistics introduces no float error)
    """
    lat = np.asarray(latencies, float)
    if lat.size == 0:
        return 0.0
    return float(np.percentile(lat, q))


def summarize(reports: list[SLAReport], rejected: int = 0) -> dict:
    """Attainment + latency percentiles for a batch of SLAReports."""
    lat = [r.latency_s for r in reports]
    met = sum(1 for r in reports if r.met)
    return {
        "served": len(reports),
        "rejected": rejected,
        "degraded": sum(1 for r in reports if r.degraded),
        "sla_attainment": met / len(reports) if reports else 1.0,
        "latency_p50_s": latency_percentile(lat, 50),
        "latency_p99_s": latency_percentile(lat, 99),
    }
