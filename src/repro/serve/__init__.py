"""Serving substrate: prefill/decode steps, continuous-batching engine,
and the shared SLA deadline machinery (repro.serve.sla)."""
