"""SLA-aware request scheduler for the serving engine.

The paper provisions clusters against a response-time SLA; this module is
the runtime half of that contract: requests carry deadlines, the scheduler
orders admission by slack (earliest-deadline-first), rejects requests whose
deadline is already infeasible given the engine's measured decode rate, and
reports attained-vs-promised latency so the advisor's provisioning can be
checked in production.

Pure host-side logic over ServeEngine — deterministic and unit-testable.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field

import numpy as np

from repro.serve.engine import Request, ServeEngine


@dataclass(order=True)
class _Queued:
    deadline: float
    seq: int
    req: Request = field(compare=False)


@dataclass
class SLAReport:
    rid: int
    deadline: float
    finished_at: float
    tokens: int

    @property
    def met(self) -> bool:
        return self.finished_at <= self.deadline


class SLAScheduler:
    """Earliest-deadline-first admission over a ServeEngine.

    decode_rate_tps: measured tokens/sec/slot (from a warmup run or the
    advisor's roofline estimate) used for feasibility-based admission
    control: a request is rejected (not silently late) if even an empty
    slot couldn't finish it by its deadline.
    """

    def __init__(self, engine: ServeEngine, decode_rate_tps: float,
                 clock=time.monotonic):
        self.engine = engine
        self.rate = decode_rate_tps
        self.clock = clock
        self.queue: list[_Queued] = []
        self._seq = 0
        self.reports: list[SLAReport] = []
        self.rejected: list[int] = []

    def submit(self, req: Request, deadline: float):
        """deadline: absolute clock time by which generation must finish."""
        est = self.clock() + req.max_new_tokens / max(self.rate, 1e-9)
        if est > deadline:
            self.rejected.append(req.rid)
            return False
        self._seq += 1
        heapq.heappush(self.queue, _Queued(deadline, self._seq, req))
        return True

    def _admit(self):
        while self.queue:
            head = self.queue[0]
            # drop already-hopeless requests rather than wasting slots
            if self.clock() + head.req.max_new_tokens / self.rate \
                    > head.deadline:
                heapq.heappop(self.queue)
                self.rejected.append(head.req.rid)
                continue
            if not self.engine.submit(head.req):
                return
            head.req._deadline = head.deadline  # type: ignore[attr-defined]
            heapq.heappop(self.queue)

    def run(self) -> list[SLAReport]:
        while self.queue or any(s is not None for s in self.engine.slots):
            self._admit()
            for r in self.engine.step():
                self.reports.append(SLAReport(
                    rid=r.rid,
                    deadline=getattr(r, "_deadline", float("inf")),
                    finished_at=self.clock(),
                    tokens=len(r.generated)))
        return self.reports

    def summary(self) -> dict:
        met = [r for r in self.reports if r.met]
        lat = [r.finished_at for r in self.reports]
        return {
            "served": len(self.reports),
            "rejected": len(self.rejected),
            "sla_attainment": (len(met) / len(self.reports)
                               if self.reports else 1.0),
            "tokens": sum(r.tokens for r in self.reports),
        }
