"""SLA-aware request scheduler for the serving engine.

The paper provisions clusters against a response-time SLA; this module is
the runtime half of that contract for LM serving: requests carry deadlines,
admission/ordering runs through the shared EDF machinery in
`repro.serve.sla` (also used by the analytic query engine), and the summary
reports attained-vs-promised latency so the advisor's provisioning can be
checked in production.

Pure host-side logic over ServeEngine — deterministic and unit-testable.
"""
from __future__ import annotations

import time

from repro.serve.engine import Request, ServeEngine
from repro.serve.sla import DeadlineQueue, SLAReport, summarize


class SLAScheduler:
    """Earliest-deadline-first admission over a ServeEngine.

    decode_rate_tps: measured tokens/sec/slot (from a warmup run or the
    advisor's roofline estimate) used for feasibility-based admission
    control: a request is rejected (not silently late) if even an empty
    slot couldn't finish it by its deadline. A zero/unknown rate estimates
    infinitely slow decode, so only deadline-free requests are admitted.
    """

    def __init__(self, engine: ServeEngine, decode_rate_tps: float,
                 clock=time.monotonic):
        self.engine = engine
        self.rate = decode_rate_tps
        self.clock = clock
        self.queue = DeadlineQueue(clock, self._est_service_s)
        self.reports: list[SLAReport] = []

    def _est_service_s(self, req: Request) -> float:
        return req.max_new_tokens / max(self.rate, 1e-9)

    @property
    def rejected(self) -> list[int]:
        return [r.rid for r in self.queue.rejected]

    def submit(self, req: Request, deadline: float) -> bool:
        """deadline: absolute clock time by which generation must finish."""
        req._submitted_at = self.clock()  # type: ignore[attr-defined]
        return self.queue.push(req, deadline)

    def _admit(self):
        while True:
            got = self.queue.pop()        # sheds now-hopeless requests
            if got is None:
                return
            req, deadline = got
            if not self.engine.submit(req):
                self.queue.requeue(req, deadline)   # engine full; keep it
                return
            req._deadline = deadline      # type: ignore[attr-defined]

    def run(self) -> list[SLAReport]:
        while len(self.queue) or any(s is not None
                                     for s in self.engine.slots):
            self._admit()
            for r in self.engine.step():
                now = self.clock()
                self.reports.append(SLAReport(
                    rid=r.rid,
                    deadline=getattr(r, "_deadline", float("inf")),
                    submitted_at=getattr(r, "_submitted_at", now),
                    finished_at=now,
                    work=len(r.generated)))
        return self.reports

    def summary(self) -> dict:
        out = summarize(self.reports, rejected=len(self.queue.rejected))
        out["tokens"] = int(sum(r.work for r in self.reports))
        return out
