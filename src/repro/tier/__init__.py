"""Tiered-memory placement: the paper's die-stacked-vs-DDR question made
executable inside the query path.

- `tiers`: TierSpecs derived from core.systems Table-1 datasheets (fast
  HBM-like tier, DDR capacity tier, measured-rate calibration) and the
  fast-tier TieredBudget.
- `placement`: chunk-granular placement of a table's packed columns across
  the two tiers under STATIC / CACHE / MEMCACHE policies (Bakhshalipour et
  al.'s memory / cache / memcache designs), with host-side numpy state.
- `trace`: seeded zipfian multi-tenant query streams that exercise the
  hot/cold structure placement exists to exploit.

QueryEngine(table, tiered=PlacementEngine...) wires it into execution:
answers stay bit-exact, latency is charged per chunk at each tier's rate,
and admission feasibility uses the blended rate.
"""
from repro.tier.placement import Access, PlacementEngine, Policy
from repro.tier.prefetch import PrefetchPipeline, PrefetchPlan
from repro.tier.tiers import (TieredBudget, TierPair, TierSpec,
                              measured_fast_gbps, paper_tiers,
                              table1_bandwidth_ratio, tier_from_system)
from repro.tier.trace import (TracedQuery, TraceSpec, make_trace,
                              replay_trace, zipf_hit_curve, zipf_weights)

__all__ = [
    "Access", "PlacementEngine", "Policy",
    "PrefetchPipeline", "PrefetchPlan",
    "TierSpec", "TierPair", "TieredBudget", "paper_tiers",
    "tier_from_system", "table1_bandwidth_ratio", "measured_fast_gbps",
    "TraceSpec", "TracedQuery", "make_trace", "replay_trace",
    "zipf_weights", "zipf_hit_curve",
]
