"""Seeded skewed query traces: the workload that makes tiering matter.

"Processing Data Where It Makes Sense" (Mutlu et al., PAPERS.md): placement
must follow access skew. A production analytics service with millions of
users produces exactly that — a few dashboards (columns) absorb most of
the scans. This module generates that stream reproducibly:

- column popularity is zipfian with exponent `skew`, over a *scrambled*
  rank->column permutation (YCSB-style), so the hot set is not the first
  columns in table order and STATIC first-fit pinning cannot win by
  accident;
- each query is a predicate scan + aggregate whose constant is drawn from
  a selectivity mix (point-ish, medium, broad), with a fraction of
  two-column conjunctions;
- queries carry a tenant id — interleaved multi-tenant streams share the
  global hot set but differ in query mix (even tenants run selective
  probes, odd tenants broad rollups).

Everything is driven by one numpy Generator seeded from `TraceSpec.seed`:
the same spec always yields the same trace, so placement-policy
comparisons and bit-exactness tests are reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.query.plan import GroupBy, HashJoin, Pred, Query


@dataclass(frozen=True)
class TraceSpec:
    n_queries: int = 200
    skew: float = 1.1            # zipf exponent over column popularity
    seed: int = 0
    tenants: int = 4
    selectivities: tuple = (0.1, 0.5, 0.9)
    p_compound: float = 0.25     # fraction of two-predicate AND queries
    # relational mix: fractions of the stream that are GroupBy rollups /
    # HashJoin probes (0.0 keeps old traces byte-identical — the grouped
    # rng draws only happen when a fraction is positive)
    p_grouped: float = 0.0
    p_join: float = 0.0


@dataclass(frozen=True)
class TracedQuery:
    tenant: int
    query: Query


def zipf_weights(n: int, skew: float) -> np.ndarray:
    """Normalized zipfian popularity over ranks 0..n-1 (skew=0: uniform)."""
    if n < 1:
        raise ValueError(f"need at least one item, got n={n}")
    w = 1.0 / np.arange(1, n + 1, dtype=float) ** skew
    return w / w.sum()


def zipf_hit_curve(n: int, skew: float):
    """fraction-of-items-resident -> fraction-of-accesses-hit, for a
    zipfian popularity with the hottest items resident (the analytic
    best-case curve advise_tier_split searches against)."""
    cum = np.concatenate([[0.0], np.cumsum(zipf_weights(n, skew))])

    def hit(fraction: float) -> float:
        k = min(max(fraction, 0.0), 1.0) * n
        lo = int(k)
        if lo >= n:
            return 1.0
        return float(cum[lo] + (k - lo) * (cum[lo + 1] - cum[lo]))

    return hit


def make_trace(table, spec: TraceSpec = TraceSpec()) -> list[TracedQuery]:
    """A skewed multi-tenant stream of Query objects over `table`.

    Popularity is assigned to a seeded permutation of the columns; each
    query draws its predicate column and aggregate column from that
    distribution (so chunk heat concentrates on the zipf head), a
    selectivity from the mix, and a tenant id round-robin-ish at random.
    """
    cols = list(table.columns)
    if len(cols) < 2:
        raise ValueError("trace needs a table with >= 2 columns")
    rng = np.random.default_rng(spec.seed)
    scrambled = list(rng.permutation(cols))          # rank r -> column
    weights = zipf_weights(len(cols), spec.skew)
    p_rel = spec.p_grouped + spec.p_join
    dims: dict = {}

    def dim_for(name: str):
        """One of a small seeded pool (3 variants per probe column) of
        dimension tables: sorted distinct keys at the probe's code width,
        zipf-skewed toward small codes so join hit rates track the same
        head the placement policies chase."""
        from repro.db.columnar import BitPackedColumn, Table
        k = (name, int(rng.integers(3)))
        if k not in dims:
            bits = table.columns[name].code_bits
            vmax = (1 << (bits - 1)) - 1
            nk = int(min(8, vmax + 1))
            pool = np.arange(min(vmax + 1, 4 * nk))
            keys = rng.choice(pool, size=nk, replace=False,
                              p=zipf_weights(len(pool), spec.skew))
            d = Table(f"dim-{name}-{k[1]}")
            d.add(BitPackedColumn.from_values(name, np.sort(keys), bits))
            dims[k] = d
        return dims[k]

    out: list[TracedQuery] = []
    for _ in range(spec.n_queries):
        tenant = int(rng.integers(spec.tenants))
        # even tenants probe selectively, odd tenants run broad rollups
        mix = (spec.selectivities[:1 + len(spec.selectivities) // 2]
               if tenant % 2 == 0 else spec.selectivities)
        sel = float(rng.choice(mix))
        ranks = rng.choice(len(cols), size=min(3, len(cols)),
                           replace=False, p=weights)
        pred_col, agg_col = scrambled[ranks[0]], scrambled[ranks[1]]
        vmax = (1 << (table.columns[pred_col].code_bits - 1)) - 1
        plan = Pred(pred_col, "lt", max(1, round(sel * (vmax + 1))))
        if len(ranks) > 2 and rng.random() < spec.p_compound:
            c2 = scrambled[ranks[2]]
            v2 = (1 << (table.columns[c2].code_bits - 1)) - 1
            plan = plan & Pred(c2, "le", max(1, round(0.9 * v2)))
        if p_rel > 0 and (r := rng.random()) < p_rel:
            # grouped/join slice of the mix: the predicate column doubles
            # as the group/join key (its zipf draw is the key skew), the
            # aggregate column is the rolled-up value; a third of the
            # rollups are pure histograms (count-only — the fused RLE
            # path on pre-grouped keys)
            aggs = () if rng.random() < 1 / 3 else (agg_col,)
            if r < spec.p_join:
                q = HashJoin(dim_for(pred_col), pred_col, pred_col,
                             aggs=aggs, where=plan)
            else:
                q = GroupBy(pred_col, aggs, where=plan)
            out.append(TracedQuery(tenant, q))
            continue
        out.append(TracedQuery(tenant, Query(plan, aggregates=(agg_col,))))
    return out


def replay_trace(table, trace, tiers, policy, *, sla_s: float | None = None,
                 chunk_rows: int = 1024, warmup_fraction: float = 1 / 3,
                 mode: str = "xla_ref", compute_w: float = 0.0,
                 power_cap=None, chaos=None, prefetch_bytes: int = 0,
                 tracer=None, monitor=None):
    """Closed-loop replay of a trace against a tiered QueryEngine — the
    one attainment methodology shared by benchmarks/tier_bench.py,
    examples/tiered_store.py, and tests.

    With `sla_s`, the first `warmup_fraction` of the trace runs
    deadline-free (a cold cache admission-rejecting its own warmup would
    measure the rejection spiral, not the policy) and attainment is
    measured on the rest, counting admission rejections as misses.
    Returns (placement_engine, query_engine, attainment); without
    `sla_s` the whole trace replays deadline-free and attainment is None
    (there was no SLA to attain — not 0%).

    Each query's tenant id tags its line on the energy meter; `compute_w`
    adds the per-chip compute term (repro.energy.meter) and `power_cap` a
    sliding-window watt governor (repro.energy.caps) — power-throttled
    service then counts against the same deadlines, so attainment reports
    the SLA cost of the cap.

    `chaos` (a repro.resilience.ChaosHarness) replays the trace under
    injected faults: recovery extras stretch service on the same clock
    and typed-degraded answers count as misses — the attainment returned
    is the *fault-adjusted* number BENCH_resilience plots.

    `prefetch_bytes` > 0 attaches a repro.tier.PrefetchPipeline with that
    in-flight staging budget (carved out of the fast tier): misses
    overlap with scans, service per stage is max(scan, stream) instead of
    the sum, and in-flight chunks are counted as fast by admission
    projections (never double-charged). Reach it as `eng.prefetch`.

    `tracer` (a repro.obs.Tracer) records every query's span tree on the
    replay's VirtualClock — deterministic, so a seeded chaos replay
    exports byte-identical trace JSON on every run (repro.obs.export).

    `monitor` (a repro.obs.SLOMonitor) samples its burn-rate series at
    cadence ticks of the same VirtualClock and fires multi-window SLO
    alerts at deterministic virtual timestamps (repro.obs.slo).
    """
    from repro.energy.meter import EnergyMeter
    from repro.query import QueryEngine
    from repro.serve.sla import VirtualClock
    from repro.tier.placement import PlacementEngine
    from repro.tier.prefetch import PrefetchPipeline

    pe = PlacementEngine.for_table(table, tiers, policy,
                                   chunk_rows=chunk_rows,
                                   meter=EnergyMeter(tiers, compute_w))
    pf = (PrefetchPipeline(pe, prefetch_bytes) if prefetch_bytes > 0
          else None)
    clk = VirtualClock()
    eng = QueryEngine(table, mode=mode, tiered=pe, clock=clk,
                      power_cap=power_cap, chaos=chaos, prefetch=pf,
                      tracer=tracer, monitor=monitor)
    warmup = int(len(trace) * warmup_fraction) if sla_s is not None else \
        len(trace)
    met = offered = 0
    for i, tq in enumerate(trace):
        measured = i >= warmup
        deadline = clk() + sla_s if measured else float("inf")
        offered += measured
        if eng.submit(tq.query, deadline=deadline,
                      tenant=tq.tenant) is None:
            continue
        met += sum(r.met for r in eng.run() if measured)
    return pe, eng, met / offered if offered else None
