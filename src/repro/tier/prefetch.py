"""Async tier prefetch: overlap as the free bandwidth multiplier.

Lee et al.'s Simultaneous Multi-Layer Access (PAPERS.md) gets 3D-stacked
bandwidth from *overlapping* layer accesses, not faster pins; this module
is the software analogue for the tier model. Without it every tiered read
is charged synchronously: `service = fast/fast_bw + capacity/cap_bw`,
the plain sum. `PrefetchPipeline` models a double-buffered read pipeline
on the VirtualClock — while chunk *i* scans, chunk *i+1* streams up from
the capacity tier into a staging buffer carved out of the fast tier's
`TieredBudget` — so each stage costs `max(scan_i, stream_i+1)`, not the
sum, and a miss-heavy query's blended bandwidth climbs toward the fast
tier's rate.

The pipeline is a *latency/energy model*, never a correctness layer:
placement state evolves through the same `on_access` path with or
without it, query answers are computed by the kernels either way, and a
stalled or cancelled stream degrades that chunk to the synchronous
capacity read — never a wrong answer. Accounting contract:

- the nominal `on_access` line is untouched (a staged miss still charges
  its capacity stream there, exactly once);
- staged chunks add their fast-buffer scan re-read, and cancelled
  streams add their wasted capacity bytes, on a distinguishable
  `kind="prefetch"` ledger line (`PlacementEngine.charge_prefetch`);
- a *stalled* stream's wasted bytes are returned to the caller
  (`PrefetchPlan.stalled_bytes`) so the chaos harness can fold them into
  its single per-query `kind="recovery"` line — charged once, never
  twice;
- while a chunk streams, it sits in `PlacementEngine.inflight`, so
  `project()` admission estimates count it as fast instead of projecting
  a second capacity read.

Scheduling: hits scan first (their fast-tier scans are the shadow the
first streams hide under), then misses; the first miss always reads
synchronously (pipeline fill), and each further miss is staged only when
the overlap pays under the adjacent-stage model — `b/fast_bw <=
prev_scan` — which guarantees `service_s <= sync_service_s` fault-free.
MEMCACHE admission applies its own bar: a first-touch chunk (no
frequency evidence) is not staged, it requeues on the synchronous path.
A circuit-breaker-demoted fast tier stages nothing, and a stall cancels
the one stream the double buffer had in flight behind it.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.tier.placement import PlacementEngine, Policy


@dataclass(frozen=True)
class PrefetchPlan:
    """One query's modeled read pipeline (pure — placement untouched)."""

    service_s: float             # pipelined read time (max per stage)
    sync_service_s: float        # the no-overlap sum (what it replaces)
    staged_bytes: int            # capacity bytes streamed through buffer
    stalled_bytes: int           # streams that stalled (-> recovery line)
    cancelled_bytes: int         # streams cancelled in flight (wasted)
    staged_cids: tuple = ()      # chunks that streamed (incl. stalled)
    n_staged: int = 0
    n_stalled: int = 0
    n_cancelled: int = 0
    stages: tuple = ()           # the per-chunk _Stage timeline, in scan
    #                              order — obs.trace.layout_pipeline
    #                              replays the same window model onto the
    #                              trace's read spans

    @property
    def used(self) -> bool:
        return self.n_staged > 0

    @property
    def overlap_saved_s(self) -> float:
        return max(0.0, self.sync_service_s - self.service_s)


@dataclass
class _Stage:
    cid: tuple
    nbytes: int
    scan_s: float
    stream_s: float = 0.0
    staged: bool = False
    stalled: bool = False
    cancelled: bool = False


class PrefetchPipeline:
    """Double-buffered capacity->fast streaming for a PlacementEngine.

    `inflight_bytes` bounds the staging buffer; it is charged against the
    fast tier's TieredBudget up front (evicting LRU residents if needed —
    buffer space is real capacity), and a chunk larger than the buffer is
    never staged. `close()` returns the reservation.
    """

    def __init__(self, placement: PlacementEngine, inflight_bytes: int):
        self.pe = placement
        self.inflight_bytes = int(inflight_bytes)
        self.reserved_bytes = placement.reserve_prefetch(
            self.inflight_bytes)
        # cumulative observability
        self.plans_total = 0
        self.staged_total = 0
        self.stalled_total = 0
        self.cancelled_total = 0
        self.saved_s_total = 0.0
        # the pipeline's own byte ledger — maintained independently of the
        # PlacementEngine's prefetch_*_bytes_total so obs.unified_snapshot
        # can cross-check the two sources instead of echoing one of them
        self.streamed_bytes_total = 0
        self.wasted_bytes_total = 0

    def close(self) -> None:
        self.pe.release_prefetch(self.reserved_bytes)
        self.reserved_bytes = 0

    # --- planning ---------------------------------------------------------
    def plan(self, chunk_bytes: dict, *, chips: int = 1,
             stalled=None) -> PrefetchPlan:
        """Model one query's reads. Pure: placement state is untouched, so
        admission estimates may call this freely. `stalled(cid) -> bool`
        injects stream stalls (the chaos harness's seeded draws); a
        stalled stream degrades its chunk to the synchronous capacity
        read and cancels the one stream in flight behind it."""
        pe = self.pe
        fast_bw = pe.tiers.fast.bandwidth * chips
        cap_bw = pe.tiers.capacity.bandwidth * chips
        hits, misses = [], []
        for cid, b in sorted(chunk_bytes.items()):
            i = pe.index.get(cid)
            if i is None:
                raise ValueError(
                    f"unknown chunk {cid!r}; placement was built with "
                    f"chunk_rows={pe.chunk_rows}")
            b = int(b)
            if pe.in_fast[i] and not pe.demoted:
                hits.append(_Stage(cid, b, b / fast_bw))
            else:
                misses.append((cid, i, b))
        sync = (sum(s.nbytes for s in hits) / fast_bw
                + sum(b for _, _, b in misses) / cap_bw)

        stages = list(hits)
        prev_scan = stages[-1].scan_s if stages else 0.0
        first_miss = True
        for cid, i, b in misses:
            stageable = (not pe.demoted
                         and not first_miss
                         and b <= self.inflight_bytes
                         and not (pe.policy is Policy.MEMCACHE
                                  and pe.freq[i] == 0)
                         and b / fast_bw <= prev_scan)
            first_miss = False
            if stageable:
                st = _Stage(cid, b, b / fast_bw, stream_s=b / cap_bw,
                            staged=True)
            else:
                st = _Stage(cid, b, b / cap_bw)
            stages.append(st)
            prev_scan = st.scan_s

        # injected stream stalls: the stalled chunk re-reads synchronously
        # and the one stream the double buffer had in flight behind it is
        # cancelled (requeued on the synchronous path)
        if stalled is not None:
            cancel_next = False
            for st in stages:
                if not st.staged:
                    continue
                if cancel_next:
                    st.cancelled = True
                    cancel_next = False
                elif stalled(st.cid):
                    st.stalled = True
                    cancel_next = True
            for st in stages:
                if st.stalled or st.cancelled:
                    st.scan_s = st.nbytes / cap_bw
                    st.stream_s = 0.0

        service = stages[0].stream_s if stages else 0.0
        for k, st in enumerate(stages):
            nxt = stages[k + 1].stream_s if k + 1 < len(stages) else 0.0
            service += max(st.scan_s, nxt)

        ok = [st for st in stages if st.staged
              and not (st.stalled or st.cancelled)]
        stalled_b = sum(st.nbytes for st in stages if st.stalled)
        cancelled_b = sum(st.nbytes for st in stages if st.cancelled)
        if not ok and not stalled_b and not cancelled_b:
            service = sync               # nothing streamed: plain sync
        return PrefetchPlan(
            service_s=service, sync_service_s=sync,
            staged_bytes=sum(st.nbytes for st in ok),
            stalled_bytes=stalled_b, cancelled_bytes=cancelled_b,
            staged_cids=tuple(st.cid for st in stages if st.staged),
            n_staged=len(ok),
            n_stalled=sum(1 for st in stages if st.stalled),
            n_cancelled=sum(1 for st in stages if st.cancelled),
            stages=tuple(stages))

    # --- execution-window bookkeeping -------------------------------------
    def begin(self, plan: PrefetchPlan, chunk_bytes: dict) -> None:
        """Mark the plan's streams in flight: from here until `finish`,
        admission projections count these chunks as fast (never a second
        capacity read at admission)."""
        for cid in plan.staged_cids:
            self.pe.inflight[cid] = int(chunk_bytes[cid])

    def finish(self, plan: PrefetchPlan, *, qid=None, tenant=None):
        """Close the flight window and charge the overlap's own traffic on
        the kind="prefetch" line: staged chunks' fast-buffer scan re-reads
        plus cancelled-stream waste. Stalled-stream waste is NOT charged
        here — the caller owns it (chaos folds it into its single
        kind="recovery" line). Returns the meter line or None."""
        for cid in plan.staged_cids:
            self.pe.inflight.pop(cid, None)
        self.plans_total += 1
        self.staged_total += plan.n_staged
        self.stalled_total += plan.n_stalled
        self.cancelled_total += plan.n_cancelled
        self.saved_s_total += plan.overlap_saved_s
        self.streamed_bytes_total += int(plan.staged_bytes)
        self.wasted_bytes_total += int(plan.cancelled_bytes)
        return self.pe.charge_prefetch(plan.staged_bytes,
                                       plan.cancelled_bytes,
                                       qid=qid, tenant=tenant)

    def stats(self) -> dict:
        return {
            "inflight_bytes": self.inflight_bytes,
            "reserved_bytes": self.reserved_bytes,
            "plans": self.plans_total,
            "staged_chunks": self.staged_total,
            "stalled_chunks": self.stalled_total,
            "cancelled_chunks": self.cancelled_total,
            "overlap_saved_s": self.saved_s_total,
            "streamed_bytes": int(self.streamed_bytes_total),
            "wasted_bytes": int(self.wasted_bytes_total),
        }
