"""Chunk-granular tier placement: memory, cache, or memcache.

Bakhshalipour et al. (arXiv 1809.08828) show die-stacked DRAM can serve as
plain *memory* (OS-placed, static), a hardware *cache* (demand promotion,
LRU eviction), or a software *memcache* (frequency-aware admission) — and
that which wins depends on the workload's locality. This module makes the
three designs executable against the query engine's tables:

- a table's packed columns are split into row-aligned *chunks* (the unit
  of placement, see query.physical.referenced_chunk_bytes);
- `PlacementEngine` assigns each chunk to the fast (die-stacked) or
  capacity (DDR) tier under a `TieredBudget`, updating placement on every
  access according to the chosen `Policy`;
- all policy state is host-side numpy (tier assignment, LRU clocks,
  frequency counters, ghost bits) — the same bookkeeping discipline as the
  serve engine's cache_len/slot tables: placement decisions never enter
  the traced computation, so query *answers* are bit-exact regardless of
  policy; only the latency/energy accounting changes.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.energy.meter import EnergyMeter
from repro.tier.tiers import TieredBudget, TierPair


class Policy(str, enum.Enum):
    STATIC = "static"        # memory-style: pinned once, never moves
    CACHE = "cache"          # hardware-cache-style: LRU promotion/eviction
    MEMCACHE = "memcache"    # software-cache-style: frequency-aware
    #                          admission with a ghost list


@dataclass
class Access:
    """One query's byte split across tiers (the placement engine's answer
    to "how fast was that scan")."""

    fast_bytes: int = 0
    capacity_bytes: int = 0
    n_hit: int = 0           # chunks served from the fast tier
    n_miss: int = 0
    charge: Any = None       # the EnergyMeter line this access opened

    @property
    def total_bytes(self) -> int:
        return self.fast_bytes + self.capacity_bytes

    @property
    def hit_fraction(self) -> float:
        """Byte-weighted fast-tier fraction of this access."""
        t = self.total_bytes
        return self.fast_bytes / t if t else 0.0


class PlacementEngine:
    """Placement of (column, chunk) ids across a fast/capacity TierPair.

    Charging rule (all three policies): a chunk is charged at the tier it
    resided in *when the access arrived* — a promotion triggered by a miss
    does not retroactively discount that miss.
    """

    def __init__(self, chunk_ids: list[tuple[str, int]],
                 chunk_nbytes: list[int], tiers: TierPair, policy: Policy,
                 *, chunk_rows: int, pin_order: list[int] | None = None,
                 age_every: int = 1024, meter: EnergyMeter | None = None):
        if not chunk_ids:
            raise ValueError("placement needs at least one chunk")
        self.ids = list(chunk_ids)
        self.index = {cid: i for i, cid in enumerate(self.ids)}
        self.nbytes = np.asarray(chunk_nbytes, np.int64)
        self.tiers = tiers
        self.policy = Policy(policy)
        self.chunk_rows = int(chunk_rows)
        self.budget = TieredBudget(tiers.fast.capacity)
        n = len(self.ids)
        self.in_fast = np.zeros(n, bool)
        self.last_access = np.zeros(n, np.int64)      # LRU clock per chunk
        self.freq = np.zeros(n, np.int64)             # MEMCACHE counters
        self.ghost = np.zeros(n, bool)                # recently evicted
        self._clock = 0
        self._touches = 0
        self.age_every = int(age_every)
        # cumulative accounting; joules live in the EnergyMeter ledger
        # (per-query/per-tenant lines), not a scalar — a default meter
        # charges memory only (compute_w=0), which keeps energy_j_total
        # exactly what the old scalar accumulated
        self.meter = meter if meter is not None else EnergyMeter(tiers)
        self.fast_bytes_total = 0
        self.capacity_bytes_total = 0
        self.recovery_bytes_total = 0
        self.hits_total = 0
        self.misses_total = 0
        # async prefetch (repro.tier.prefetch): chunks currently streaming
        # capacity -> fast staging buffer, so admission projections count
        # them as fast instead of double-counting a second capacity read;
        # byte counters stay OUT of fast/capacity_bytes_total — hit_rate
        # measures demand traffic, the prefetch ledger measures overlap
        self.inflight: dict[tuple[str, int], int] = {}
        self.prefetch_reserved_bytes = 0
        self.prefetch_streamed_bytes_total = 0
        self.prefetch_wasted_bytes_total = 0
        # circuit-breaker demotion (repro.resilience): while True, every
        # access is *charged* at the capacity tier — the fast copy is not
        # trusted for service — but placement state (residency, LRU
        # clocks, frequency counters, ghost bits) keeps evolving, so the
        # fast tier rejoins warm when the breaker closes
        self.demoted = False
        if self.policy is Policy.STATIC:
            for i in (pin_order if pin_order is not None else range(n)):
                if self.budget.fits(int(self.nbytes[i])):
                    self.budget.alloc(int(self.nbytes[i]))
                    self.in_fast[i] = True

    # --- construction from tables -----------------------------------------
    @classmethod
    def for_table(cls, table, tiers: TierPair, policy: Policy,
                  chunk_rows: int = 4096,
                  hot_columns: tuple[str, ...] = (), **kw
                  ) -> "PlacementEngine":
        """Chunk a Table or ShardedTable into the placement universe.

        Sharded tables are chunked over their padded (device-resident) word
        arrays — the same byte totals ShardedTable.chunk_bytes reports.
        `hot_columns` orders STATIC pinning (an operator hint: pin these
        first); other policies ignore it.
        """
        from repro.query import physical

        source = (table.slices if hasattr(table, "slices")
                  else table.columns)
        # align on the *source* widths: a sharded (or compressed delta)
        # view may store columns at narrower payload widths than the
        # logical table, and chunk boundaries must be word boundaries in
        # the layout actually placed
        chunk_rows = physical.align_chunk_rows(source, chunk_rows)
        universe = physical.chunk_universe(source, chunk_rows)
        ids = list(universe)
        nbytes = list(universe.values())
        order = None
        if hot_columns:
            rank = {c: r for r, c in enumerate(hot_columns)}
            order = sorted(range(len(ids)),
                           key=lambda i: (rank.get(ids[i][0], len(rank)),
                                          i))
        return cls(ids, nbytes, tiers, policy, chunk_rows=chunk_rows,
                   pin_order=order, **kw)

    # --- inspection -------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return int(self.nbytes.sum())

    @property
    def resident_fast_fraction(self) -> float:
        """Fraction of the table's bytes currently in the fast tier."""
        return float(self.nbytes[self.in_fast].sum()) / self.total_bytes

    @property
    def hit_rate(self) -> float:
        """Cumulative byte-weighted fast-tier hit rate."""
        t = self.fast_bytes_total + self.capacity_bytes_total
        return self.fast_bytes_total / t if t else 0.0

    @property
    def energy_j_total(self) -> float:
        """Memory joules streamed so far — the pre-meter scalar, now the
        exact sum of the ledger's per-tier memory lines."""
        return self.meter.memory_j

    def resident(self, cid: tuple[str, int]) -> bool:
        """Is this chunk's authoritative copy in the fast tier right now?
        (True residency, independent of circuit-breaker demotion.)"""
        i = self.index.get(cid)
        if i is None:
            raise ValueError(
                f"unknown chunk {cid!r}; placement was built with "
                f"chunk_rows={self.chunk_rows} over "
                f"{sorted({c for c, _ in self.ids})}")
        return bool(self.in_fast[i])

    def blended_measured_bps(self, chips: int = 1) -> float:
        """The admission-control rate: harmonic blend of the tier rates at
        the *measured* hit fraction (before any access: at the resident
        fast fraction — exact for STATIC, conservative for cold caches)."""
        t = self.fast_bytes_total + self.capacity_bytes_total
        frac = self.hit_rate if t else self.resident_fast_fraction
        return self.tiers.blended(frac, chips)

    def service_s(self, access: Access, chips: int = 1) -> float:
        """The tiered latency model: each tier's bytes at that tier's
        rate, `chips` shards streaming in parallel."""
        return self.tiers.service_s(access.fast_bytes,
                                    access.capacity_bytes, chips)

    def stats(self, chips: int = 1) -> dict:
        """Cumulative placement accounting; pass the shard count so
        blended_gbps is on the same aggregate scale as the engine's
        measured_gbps."""
        return {
            "policy": self.policy.value,
            "chunks": len(self.ids),
            "chunk_rows": self.chunk_rows,
            "table_bytes": self.total_bytes,
            "fast_capacity_bytes": int(self.budget.fast_capacity),
            "fast_resident_fraction": self.resident_fast_fraction,
            "hit_rate": self.hit_rate,
            "fast_bytes": int(self.fast_bytes_total),
            "capacity_bytes": int(self.capacity_bytes_total),
            "chunk_hits": self.hits_total,
            "chunk_misses": self.misses_total,
            "recovery_bytes": int(self.recovery_bytes_total),
            "demoted": self.demoted,
            "energy_j": self.energy_j_total,
            "blended_gbps": self.blended_measured_bps(chips) / 1e9,
            "prefetch_reserved_bytes": int(self.prefetch_reserved_bytes),
            "prefetch_streamed_bytes":
                int(self.prefetch_streamed_bytes_total),
            "prefetch_wasted_bytes": int(self.prefetch_wasted_bytes_total),
        }

    # --- admission-time projection ----------------------------------------
    def project(self, chunk_bytes: dict[tuple[str, int], int]) -> Access:
        """The byte split this access would see if it arrived now, WITHOUT
        touching placement state — admission estimates must not advance
        LRU clocks, frequency counters, or the energy ledger."""
        acc = Access()
        for cid, b in chunk_bytes.items():
            i = self.index.get(cid)
            if i is None:
                raise ValueError(
                    f"unknown chunk {cid!r}; placement was built with "
                    f"chunk_rows={self.chunk_rows} over "
                    f"{sorted({c for c, _ in self.ids})}")
            if (self.in_fast[i] and not self.demoted) \
                    or cid in self.inflight:
                # a chunk already streaming up through the prefetch buffer
                # is charged as fast at admission: its capacity read is in
                # flight and must not be projected (= charged) twice
                acc.fast_bytes += b
                acc.n_hit += 1
            else:
                acc.capacity_bytes += b
                acc.n_miss += 1
        return acc

    # --- the access path --------------------------------------------------
    def on_access(self, chunk_bytes: dict[tuple[str, int], int], *,
                  qid: int | None = None,
                  tenant: int | None = None, trace=None) -> Access:
        """Charge one query's per-chunk byte counts and update placement.

        `chunk_bytes` comes from query.physical.referenced_chunk_bytes or
        ShardedTable.chunk_bytes with this engine's chunk_rows. Returns the
        query's byte split; cumulative totals feed hit_rate and the
        blended admission rate, and the byte split opens a line on the
        energy meter (tagged qid/tenant for the per-tenant bill).

        `trace` (an obs.trace.QueryTrace) gets one "read" span per chunk,
        emitted from the same hit/miss decision being charged — the traced
        split cannot drift from the billed one. Span times are laid out
        afterwards by the caller (obs.trace.layout_sync/layout_pipeline).
        """
        acc = Access()
        for cid, b in chunk_bytes.items():
            i = self.index.get(cid)
            if i is None:
                raise ValueError(
                    f"unknown chunk {cid!r}; placement was built with "
                    f"chunk_rows={self.chunk_rows} over "
                    f"{sorted({c for c, _ in self.ids})}")
            self._clock += 1
            # charging vs residency split: under circuit-breaker demotion
            # a fast-resident chunk is *charged* at the capacity tier, but
            # policy bookkeeping still sees true residency — ghost bits
            # and frequency counters must not drift while the tier heals
            resident = bool(self.in_fast[i])
            hit = resident and not self.demoted
            if resident:
                self.last_access[i] = self._clock
            if hit:
                acc.fast_bytes += b
                acc.n_hit += 1
            else:
                acc.capacity_bytes += b
                acc.n_miss += 1
            if trace is not None:
                tier = self.tiers.fast if hit else self.tiers.capacity
                trace.read(cid, b, tier="fast" if hit else "capacity",
                           hit=hit, inflight=cid in self.inflight,
                           joules=b * tier.energy_per_byte)
            if self.policy is Policy.CACHE:
                self._cache_touch(i, resident)
            elif self.policy is Policy.MEMCACHE:
                self._memcache_touch(i, resident)
        self.fast_bytes_total += acc.fast_bytes
        self.capacity_bytes_total += acc.capacity_bytes
        self.hits_total += acc.n_hit
        self.misses_total += acc.n_miss
        acc.charge = self.meter.charge(acc.fast_bytes, acc.capacity_bytes,
                                       qid=qid, tenant=tenant)
        return acc

    def charge_recovery(self, fast_bytes: int, capacity_bytes: int, *,
                        qid: int | None = None, tenant: int | None = None):
        """Charge retry / failover / repair traffic: the extra bytes the
        recovery machinery streamed beyond the nominal access. They join
        the cumulative ledger (so the blended admission rate reflects
        fault overhead) and open a kind="recovery" line on the energy
        meter — charged exactly once, the no-double-charge invariant the
        property tests pin down. Returns the meter line."""
        fast_bytes, capacity_bytes = int(fast_bytes), int(capacity_bytes)
        if fast_bytes < 0 or capacity_bytes < 0:
            raise ValueError(f"recovery bytes must be >= 0, got "
                             f"({fast_bytes}, {capacity_bytes})")
        self.fast_bytes_total += fast_bytes
        self.capacity_bytes_total += capacity_bytes
        self.recovery_bytes_total += fast_bytes + capacity_bytes
        return self.meter.charge(fast_bytes, capacity_bytes, qid=qid,
                                 tenant=tenant, kind="recovery")

    # --- async prefetch accounting (repro.tier.prefetch) ------------------
    def reserve_prefetch(self, nbytes: int) -> int:
        """Carve a staging buffer for the prefetch pipeline out of the
        fast-tier budget (evicting LRU residents if the tier is full —
        the buffer is real fast-tier capacity, not free space). Returns
        the bytes reserved; raises if the request exceeds the tier."""
        nbytes = int(nbytes)
        if nbytes <= 0:
            raise ValueError(f"prefetch reservation must be > 0, "
                             f"got {nbytes}")
        if nbytes > int(self.budget.fast_capacity):
            raise ValueError(
                f"prefetch reservation {nbytes} exceeds fast tier "
                f"capacity {int(self.budget.fast_capacity)}")
        need = nbytes - int(self.budget.remaining)
        if need > 0:
            self._evict_lru(need)
        self.budget.alloc(nbytes)
        self.prefetch_reserved_bytes += nbytes
        return nbytes

    def release_prefetch(self, nbytes: int) -> None:
        """Return a prefetch reservation to the budget."""
        nbytes = min(int(nbytes), self.prefetch_reserved_bytes)
        self.budget.free(nbytes)
        self.prefetch_reserved_bytes -= nbytes

    def charge_prefetch(self, fast_bytes: int, capacity_bytes: int, *,
                        qid: int | None = None, tenant: int | None = None):
        """Charge prefetch overlap traffic on its own ledger line:
        `fast_bytes` = staged chunks re-read from the fast buffer by the
        scan (the nominal access already charged their capacity stream),
        `capacity_bytes` = streamed-then-cancelled waste. Distinguishable
        from demand traffic (kind="prefetch") and excluded from hit-rate
        totals; returns the meter line, or None for a zero charge."""
        fast_bytes, capacity_bytes = int(fast_bytes), int(capacity_bytes)
        if fast_bytes < 0 or capacity_bytes < 0:
            raise ValueError(f"prefetch bytes must be >= 0, got "
                             f"({fast_bytes}, {capacity_bytes})")
        if fast_bytes == 0 and capacity_bytes == 0:
            return None
        self.prefetch_streamed_bytes_total += fast_bytes
        self.prefetch_wasted_bytes_total += capacity_bytes
        return self.meter.charge(fast_bytes, capacity_bytes, qid=qid,
                                 tenant=tenant, kind="prefetch")

    # --- CACHE: LRU promotion/eviction ------------------------------------
    def _evict_lru(self, need: int, floor_freq: int | None = None) -> bool:
        """Evict coldest fast chunks until `need` bytes are free. With
        `floor_freq`, refuse (and evict nothing) unless every victim is
        strictly colder than that frequency — MEMCACHE's admission test."""
        fast = np.flatnonzero(self.in_fast)
        # victim order: coldest-by-frequency (MEMCACHE) or least-recently
        # used (CACHE), LRU/index tie-breaks keep it deterministic
        order = fast[np.lexsort((fast, self.last_access[fast],
                                 self.freq[fast]))] \
            if floor_freq is not None else fast[np.argsort(
                self.last_access[fast], kind="stable")]
        victims, freed = [], 0
        for v in order:
            if freed >= need:
                break
            if floor_freq is not None and self.freq[v] >= floor_freq:
                return False
            victims.append(v)
            freed += int(self.nbytes[v])
        if freed < need:
            return False
        for v in victims:
            self.in_fast[v] = False
            self.ghost[v] = True
            self.budget.free(int(self.nbytes[v]))
        return True

    def _cache_touch(self, i: int, hit: bool) -> None:
        if hit:
            return
        b = int(self.nbytes[i])
        need = b - int(self.budget.remaining)
        if need > 0 and not self._evict_lru(need):
            return                    # chunk larger than the whole tier
        self.budget.alloc(b)
        self.in_fast[i] = True
        self.last_access[i] = self._clock

    # --- MEMCACHE: frequency-aware admission with a ghost list ------------
    def _memcache_touch(self, i: int, hit: bool) -> None:
        self.freq[i] += 2 if self.ghost[i] else 1   # ghost re-touch bonus
        self.ghost[i] = False
        self._touches += 1
        if self._touches % self.age_every == 0:
            self.freq >>= 1            # periodic aging keeps counters adaptive
        if hit:
            return
        b = int(self.nbytes[i])
        need = b - int(self.budget.remaining)
        if need > 0 and not self._evict_lru(need,
                                            floor_freq=int(self.freq[i])):
            return                     # incumbents are hotter: not admitted
        self.budget.alloc(b)
        self.in_fast[i] = True
        self.last_access[i] = self._clock
