"""Tier datasheets: the paper's Table 1 systems as *memory tiers*.

The paper asks when a die-stacked (bandwidth-rich, capacity-poor) node
beats a traditional (capacity-rich, bandwidth-poor) one for a whole
cluster. A tiered node holds both at once: a fast HBM-like tier and a DDR
capacity tier behind it, and the placement engine (repro.tier.placement)
decides which column chunks live where. This module derives the two
`TierSpec`s from `core.systems.SystemSpec` datasheets so every number —
bandwidth, capacity, per-byte energy, and the fast:capacity bandwidth
ratio — traces back to Table 1, and `TieredBudget` enforces the one hard
constraint that makes the problem interesting: the fast tier does not fit
the database.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from repro.core.systems import DIE_STACKED, TRADITIONAL, SystemSpec
from repro.serve.sla import blended_bps


@dataclass(frozen=True)
class TierSpec:
    """One memory tier of a placement domain.

    Units are deliberately asymmetric, mirroring how a tiered cluster
    works: `bandwidth` is per chip (shards stream their chunks in
    parallel, so callers scale it by the chip count — see
    TierPair.service_s), while `capacity` is the tier's total resident
    bytes across the whole placement domain — one node's stack for a flat
    table, the cluster-aggregate fast tier for a sharded one (placement
    is a single global decision either way).
    """

    name: str
    bandwidth: float            # bytes/s one chip streams from this tier
    capacity: float             # bytes resident across the placement domain
    energy_per_byte: float      # J/byte of streamed access

    def __post_init__(self):
        if self.bandwidth <= 0:
            raise ValueError(f"tier {self.name!r}: bandwidth "
                             f"{self.bandwidth} must be positive")
        if self.capacity < 0:
            raise ValueError(f"tier {self.name!r}: capacity "
                             f"{self.capacity} must be non-negative")

    @property
    def gbps(self) -> float:
        return self.bandwidth / 1e9

    def with_bandwidth(self, bandwidth: float) -> "TierSpec":
        """Same tier calibrated to a measured (not datasheet) rate."""
        return dataclasses.replace(self, bandwidth=bandwidth)

    def as_system(self, cores: int = 32) -> SystemSpec:
        """Express the tier in the paper's Table-1 vocabulary so Eq. 4
        applies unchanged: one module, one channel, cores sized so the
        chip is exactly bandwidth-bound (core_perf * cores == bandwidth),
        the paper's scan regime."""
        return SystemSpec(
            name=f"{self.name}-as-system",
            module_capacity=max(self.capacity, 1.0),
            channel_bandwidth=self.bandwidth,
            memory_channels=1,
            channel_modules=1,
            module_power=self.energy_per_byte * self.bandwidth,
            blade_chips=1,
            core_perf=self.bandwidth / cores,
            max_chip_cores=cores,
        )


def tier_from_system(system: SystemSpec, capacity: float | None = None,
                     bandwidth: float | None = None) -> TierSpec:
    """A Table-1 column as a tier: chip-level bandwidth, capacity
    defaulting to one chip's attached memory (override with the placement
    domain's real budget — e.g. a fraction of the table, times the shard
    count for a sharded cluster), and per-byte energy = module power /
    streamed bandwidth."""
    bw = system.chip_bandwidth if bandwidth is None else bandwidth
    return TierSpec(
        name=system.name,
        bandwidth=bw,
        capacity=system.chip_capacity if capacity is None else capacity,
        energy_per_byte=(system.modules_per_chip * system.module_power)
        / system.chip_bandwidth)


def table1_bandwidth_ratio(fast: SystemSpec = DIE_STACKED,
                           capacity: SystemSpec = TRADITIONAL) -> float:
    """Fast:capacity per-chip bandwidth ratio from Table 1 (2.5x for
    die-stacked vs traditional); derates the capacity tier when the fast
    tier's rate comes from a measured sweep instead of the datasheet."""
    return fast.chip_bandwidth / capacity.chip_bandwidth


@dataclass(frozen=True)
class TierPair:
    """The two-tier memory system one chip scans against."""

    fast: TierSpec
    capacity: TierSpec

    def blended(self, fast_fraction: float, chips: int = 1) -> float:
        """Effective bytes/s when `fast_fraction` of streamed bytes come
        from the fast tier (harmonic blend, Amdahl on bandwidth)."""
        return blended_bps(self.fast.bandwidth, self.capacity.bandwidth,
                           fast_fraction) * chips

    def service_s(self, fast_bytes: float, capacity_bytes: float,
                  chips: int = 1) -> float:
        """Seconds to stream a byte split, each tier at its own rate."""
        return (fast_bytes / (self.fast.bandwidth * chips)
                + capacity_bytes / (self.capacity.bandwidth * chips))

    def energy_components(self, fast_bytes: float, capacity_bytes: float
                          ) -> tuple[float, float]:
        """(fast_j, capacity_j) of a byte split — the one place the
        per-tier pricing formula lives (the EnergyMeter ledger and
        energy_j both build on it)."""
        for name, b in (("fast_bytes", fast_bytes),
                        ("capacity_bytes", capacity_bytes)):
            if not math.isfinite(b) or b < 0:
                raise ValueError(
                    f"{name}={b} must be a finite non-negative byte count; "
                    f"energy charges from broken byte accounting would "
                    f"silently poison the meter's ledger")
        return (fast_bytes * self.fast.energy_per_byte,
                capacity_bytes * self.capacity.energy_per_byte)

    def energy_j(self, fast_bytes: float, capacity_bytes: float) -> float:
        fast_j, capacity_j = self.energy_components(fast_bytes,
                                                    capacity_bytes)
        return fast_j + capacity_j


def paper_tiers(fast_capacity: float, *, fast_gbps: float | None = None,
                fast_system: SystemSpec = DIE_STACKED,
                capacity_system: SystemSpec = TRADITIONAL) -> TierPair:
    """The paper's two-tier node: die-stacked fast tier (capacity capped
    at `fast_capacity` bytes) over a traditional DDR capacity tier.

    With `fast_gbps` (e.g. from the autotuned kernel sweep,
    `measured_fast_gbps`) the fast tier runs at the measured rate and the
    capacity tier is derated by the Table 1 bandwidth ratio, so model and
    measurement stay on one scale.
    """
    if fast_capacity <= 0:
        raise ValueError(f"fast_capacity={fast_capacity} must be positive; "
                         f"a zero fast tier is the flat-memory engine")
    ratio = table1_bandwidth_ratio(fast_system, capacity_system)
    fast_bw = fast_gbps * 1e9 if fast_gbps is not None else None
    fast = tier_from_system(fast_system, capacity=fast_capacity,
                            bandwidth=fast_bw)
    cap_bw = fast.bandwidth / ratio
    cap = tier_from_system(capacity_system, bandwidth=cap_bw)
    return TierPair(fast=fast, capacity=cap)


def measured_fast_gbps(default: float | None = None) -> float | None:
    """Best attained scan rate in the autotune cache (repro.kernels.tune):
    the fast tier priced from the measured sweep, not the datasheet.

    Scans `scan_filter`/`scan_aggregate` entries for the current backend;
    bytes per call are recovered from the `rows=` shape key (rows of
    (rows, LANES) uint32 word planes) times the number of input planes the
    op streams — scan_filter reads one packed array, the fused
    scan_aggregate reads three (pred, agg, valid) — so the two ops'
    attained GB/s are commensurate. Returns `default` when nothing has
    been tuned yet.
    """
    import jax

    from repro.kernels import tune
    from repro.kernels.scan_filter.kernel import LANES

    streamed_planes = {"scan_filter": 1, "scan_aggregate": 3}
    backend = jax.default_backend()
    best = None
    for key, entry in tune.get_cache().entries().items():
        parts = key.split("|")
        if len(parts) != 3 or parts[1] != backend:
            continue
        if parts[0] not in streamed_planes:
            continue
        dims = dict(kv.split("=") for kv in parts[2].split(","))
        us = entry.get("us")
        if "rows" not in dims or not us:
            continue
        nbytes = streamed_planes[parts[0]] * int(dims["rows"]) * LANES * 4
        gbps = nbytes / (us * 1e-6) / 1e9
        best = gbps if best is None else max(best, gbps)
    return best if best is not None else default


class TieredBudget:
    """Fast-tier byte budget the placement engine allocates against.

    The single invariant of the subsystem: resident fast-tier bytes never
    exceed `fast_capacity`. Policies must free (evict) before they alloc
    (admit); over-allocation raises instead of silently overflowing the
    stack.
    """

    def __init__(self, fast_capacity: float):
        if fast_capacity <= 0:
            raise ValueError(
                f"fast_capacity={fast_capacity} must be positive")
        self.fast_capacity = float(fast_capacity)
        self.used = 0.0

    @property
    def remaining(self) -> float:
        return self.fast_capacity - self.used

    def fits(self, nbytes: float) -> bool:
        return nbytes <= self.remaining

    def alloc(self, nbytes: float) -> None:
        if not self.fits(nbytes):
            raise ValueError(
                f"fast-tier overflow: alloc {nbytes} with "
                f"{self.remaining:.0f} of {self.fast_capacity:.0f} free; "
                f"evict before admitting")
        self.used += nbytes

    def free(self, nbytes: float) -> None:
        self.used = max(0.0, self.used - nbytes)
