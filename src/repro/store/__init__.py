"""Compressed columnar store: scan-over-compressed as a bandwidth
multiplier.

- `encode`: chunk-granular RLE / frame-of-reference / plain encodings
  over the bit-packed code planes, with an EncodingStats-driven selector
  that never loses to the plain format.
- `exec`: query execution over compressed chunks — RLE runs through the
  `scan_compressed` kernel family, FOR planes through the existing
  BitWeaving kernels at the delta width (translated predicates, exact
  base fix-up).
- `sharded`: the global-frame delta view that rides the unmodified
  ShardedTable machinery across a mesh.

QueryEngine(EncodedTable...) executes compressed directly; `bytes_scanned`
becomes physical (compressed) traffic with `logical_bytes` preserved
beside it, so tiering, energy metering, and the decision surface all see
the bandwidth compression buys.
"""
from repro.store.encode import (DEFAULT_CHUNK_ROWS, MAX_CHUNK_ROWS,
                                EncodedChunk, EncodedColumn, EncodedTable,
                                Encoding, EncodingStats, choose_encoding,
                                encode_chunk, width_for_span)
from repro.store.exec import execute_encoded, translate_plan, translate_pred
from repro.store.sharded import ShardedEncodedTable

__all__ = [
    "Encoding", "EncodingStats", "EncodedChunk", "EncodedColumn",
    "EncodedTable", "ShardedEncodedTable", "choose_encoding",
    "encode_chunk", "execute_encoded", "translate_plan", "translate_pred",
    "width_for_span", "DEFAULT_CHUNK_ROWS", "MAX_CHUNK_ROWS",
]
