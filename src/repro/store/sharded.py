"""Sharded execution over a compressed store: the delta view on a mesh.

Row-aligned encodings are what shard: a FOR plane is a plain BitWeaving
plane in delta space, so a compressed table shards by building one global
frame of reference per column (base = column min, payload width = span
width), bit-packing the deltas, and handing that *delta table* to the
unmodified `query.sharded.ShardedTable` — per-shard Pallas scans on
compressed words, psum-combined planes, validity masks, the whole
machinery unchanged. Queries translate into the delta domain on the way
in (store.exec.translate_plan) and aggregates fix up their base on the
way out, in exact host ints after the psum.

RLE is a chunk-local layout (runs do not align across shard boundaries),
so sharding re-encodes every column — including RLE-chosen ones — into
the global FOR frame; the device-resident bytes the tier/energy ledgers
charge are the delta words. Columns whose span needs the full logical
width shard at today's plain size: the view never exceeds the plain
format, mirroring `choose_encoding`'s guarantee.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.db.columnar import BitPackedColumn, Table
from repro.query.sharded import ShardedTable
from repro.store.encode import EncodedTable, width_for_span
from repro.store.exec import fixup_base, translate_plan


@dataclass(frozen=True)
class _ColMeta:
    """The metadata surface the engine reads per column: logical width
    for plan validation, physical (device-resident, compressed) bytes
    for admission, logical bytes beside them."""
    code_bits: int
    nbytes: int
    logical_nbytes: int


class ShardedEncodedTable:
    """An EncodedTable partitioned row-wise along one mesh axis.

    Duck-types ShardedTable where QueryEngine touches it: `columns`,
    `num_rows`, `n_shards`, `nbytes`, `slices`, `execute`, `chunk_bytes`.
    """

    def __init__(self, store: EncodedTable, inner: ShardedTable,
                 frames: dict[str, tuple[int, int]]):
        self.store = store
        self.inner = inner
        self.frames = frames           # column -> (base, payload width)

    @classmethod
    def shard(cls, store: EncodedTable, mesh,
              axis: str = "data") -> "ShardedEncodedTable":
        if not store.columns:
            raise ValueError("cannot shard an empty encoded table")
        delta = Table(f"{store.name}-delta")
        frames: dict[str, tuple[int, int]] = {}
        for name, col in store.columns.items():
            codes = col.decode()
            base = int(codes.min()) if codes.size else 0
            width = (width_for_span(int(codes.max()) - base)
                     if codes.size else 2)
            frames[name] = (base, width)
            delta.add(BitPackedColumn.from_values(
                name, codes - np.uint32(base), width))
        return cls(store, ShardedTable.shard(delta, mesh, axis), frames)

    # --- metadata ---------------------------------------------------------
    @property
    def columns(self) -> dict[str, _ColMeta]:
        out = {}
        for name, col in self.store.columns.items():
            dev = 4 * int(self.inner.slices[name].words.size)
            out[name] = _ColMeta(col.code_bits, dev, col.logical_nbytes)
        return out

    @property
    def num_rows(self) -> int:
        return self.store.num_rows

    @property
    def n_shards(self) -> int:
        return self.inner.n_shards

    @property
    def nbytes(self) -> int:
        """Device-resident compressed bytes (shard padding included)."""
        return self.inner.nbytes

    @property
    def slices(self):
        """Delta-word device slices — the tier placement universe, so
        placement chunks hold compressed bytes."""
        return self.inner.slices

    # --- tier accounting --------------------------------------------------
    def chunk_bytes(self, plan, aggregates, chunk_rows: int) -> dict:
        """Per-(column, chunk) device-resident *compressed* bytes this
        query streams (same chunk ids as PlacementEngine.for_table)."""
        return self.inner.chunk_bytes(plan, aggregates, chunk_rows)

    # --- execution --------------------------------------------------------
    def execute(self, plan, aggregates, mode=None) -> dict:
        """Per-shard scan over compressed delta words, psum combine,
        exact host-int base fix-up; bit-identical to the plain table."""
        aggregates = tuple(aggregates)
        raw = self.inner.execute(translate_plan(plan, self.frames),
                                 aggregates, mode=mode)
        return {a: fixup_base(raw[a], self.frames[a][0],
                              self.store.columns[a].code_bits)
                for a in aggregates}

    def execute_grouped(self, query, mode=None) -> dict:
        """GroupBy/HashJoin over the sharded compressed view: the where
        plan translates into the delta domain, the group domain shifts by
        the key's frame base, and the per-shard dense kernels run on delta
        words directly. Host-side absorb restores logical keys
        (key_base=kbase) and value sums (sum += vbase * count), both
        exact, so the result is bit-identical to every other surface."""
        from repro.kernels import dispatch
        from repro.query import relational
        relational.bind_check(query, self.columns)
        if self.num_rows == 0:
            return relational.empty_result()
        kbase, _ = self.frames[query.key]
        dmin, dmax = self.inner.key_code_range(query.key)
        if dmax < dmin:
            return relational.empty_result()
        domain = relational.group_domain(query, kbase + dmin,
                                         kbase + dmax)
        if len(domain) == 0:
            return relational.empty_result()
        if not relational.dense_ok(domain):
            dispatch.count_launch("group_aggregate_fallback",
                                  self.n_shards)
            return relational.execute_grouped_oracle(
                query, self.store.decode_table())
        planes = self.inner.execute_grouped_planes(
            translate_plan(query.plan(), self.frames), query.key,
            query.aggs, np.asarray(domain) - kbase, mode=mode)
        first = query.aggs[0] if query.aggs else ""
        part = relational.new_partial()
        for name, stack in planes.items():
            vbase = self.frames[name][0] if name else 0
            for i in range(stack.shape[0]):
                relational.absorb_plane(
                    part, np.asarray(domain) - kbase, stack[i],
                    name or None, base=vbase, key_base=kbase,
                    count_source=(name == first))
        return relational.finalize(part)
