"""Chunk-granular compressed encodings over the bit-packed code planes.

The paper's whole problem is bytes-per-second: a big-memory system can scan
under 10% of its capacity in a second, and die-stacking is the expensive way
to buy more bandwidth. Compression is the cheap way — every byte not moved
is bandwidth *and* fast-tier capacity gained — so this module gives the
columnar store three chunk-granular encodings and a stats-driven selector:

- RLE: sorted / low-cardinality chunks become (value, length) run pairs,
  run arrays padded to a power of two (TPU-friendly static shapes;
  zero-length padding runs are inert). Scans aggregate directly on runs
  through the `scan_compressed` kernel family — a run of length n matching
  a predicate contributes n to the count and n*value to the sum without
  ever materializing rows.
- FOR (frame-of-reference + delta bit-packing): clustered chunks store
  `code - min(chunk)` packed at the narrowest power-of-two field width
  whose payload holds the chunk's span. The packed delta plane is a valid
  BitWeaving plane, so the *existing* scan/aggregate/fused kernels execute
  on compressed words at the narrower width — predicates translate into
  the delta domain (store.exec) and aggregates get an exact host-side base
  fix-up. Effective scan bandwidth multiplies by code_bits/delta_bits.
- PLAIN: today's packed layout, the fallback the selector never loses to.

All run/word metadata is host-side numpy; payloads land as device arrays
in int32/uint32 planes. Layouts follow "Simultaneous Multi Layer Access"
(Lee et al., PAPERS.md): win bandwidth by moving fewer bits per row, not
by exotic formats — everything stays word-aligned and pow2-sized.
"""
from __future__ import annotations

import enum
import math
import zlib
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.kernels.scan_filter import ref as packref

#: Widths the BitWeaving word layout supports (fields divide 32 bits and
#: payloads stay below 2^15 so exact aggregation holds).
WIDTHS = (2, 4, 8, 16)

#: Hard cap on rows per chunk: keeps every per-chunk sum partial
#: (vmax * rows < 2^31) int32-exact in the RLE kernel and bounds run
#: lengths to one int32 plane.
MAX_CHUNK_ROWS = 65536

DEFAULT_CHUNK_ROWS = 4096


class Encoding(str, enum.Enum):
    PLAIN = "plain"
    RLE = "rle"
    FOR = "for"


def width_for_span(span: int) -> int:
    """Narrowest supported field width whose payload (2^(w-1)-1) holds
    `span`."""
    if span < 0:
        raise ValueError(f"span={span} must be non-negative")
    for w in WIDTHS:
        if span <= (1 << (w - 1)) - 1:
            return w
    raise ValueError(f"span={span} exceeds the 16-bit payload max 32767; "
                     f"codes this wide cannot be stored exactly")


def next_pow2(n: int) -> int:
    return 0 if n <= 0 else 1 << (n - 1).bit_length()


def plain_nbytes(n_rows: int, code_bits: int) -> int:
    """Packed bytes of `n_rows` codes at `code_bits` (the logical size a
    chunk streams uncompressed)."""
    cpw = 32 // code_bits
    return 4 * (-(-n_rows // cpw))


@dataclass(frozen=True)
class EncodingStats:
    """Per-chunk statistics the encoding selector decides from."""

    n_rows: int
    n_runs: int
    n_distinct: int
    vmin: int
    vmax: int
    delta_bits: int          # FOR field width for (vmax - vmin)
    plain_nbytes: int
    rle_nbytes: int          # 8 bytes per pow2-padded run (value + length)
    for_nbytes: int          # delta words + 8 bytes (base, width) metadata

    @classmethod
    def from_codes(cls, codes: np.ndarray, code_bits: int) -> "EncodingStats":
        n = len(codes)
        if n == 0:
            return cls(0, 0, 0, 0, 0, WIDTHS[0], 0, 0, 0)
        vmin, vmax = int(codes.min()), int(codes.max())
        n_runs = 1 + int(np.count_nonzero(np.diff(codes)))
        dbits = width_for_span(vmax - vmin)
        return cls(
            n_rows=n, n_runs=n_runs,
            n_distinct=int(len(np.unique(codes))),
            vmin=vmin, vmax=vmax, delta_bits=dbits,
            plain_nbytes=plain_nbytes(n, code_bits),
            rle_nbytes=8 * next_pow2(n_runs),
            for_nbytes=plain_nbytes(n, dbits) + 8,
        )

    def nbytes(self, encoding: Encoding) -> int:
        return {Encoding.PLAIN: self.plain_nbytes,
                Encoding.RLE: self.rle_nbytes,
                Encoding.FOR: self.for_nbytes}[Encoding(encoding)]


def choose_encoding(stats: EncodingStats) -> Encoding:
    """Smallest physical footprint wins; PLAIN wins ties, so a chosen
    encoding is never larger than today's format."""
    best = Encoding.PLAIN
    for cand in (Encoding.RLE, Encoding.FOR):
        if stats.nbytes(cand) < stats.nbytes(best):
            best = cand
    return best


@dataclass
class EncodedChunk:
    """One row-range of one column in its chosen physical layout.

    PLAIN/FOR hold a packed word plane at `width` (== code_bits for PLAIN,
    the delta width for FOR) plus the matching packed validity mask; the
    codes it stores are `base + packed_field`. RLE holds pow2-padded
    (values, lengths) int32 planes (zero-length runs are padding) plus a
    validity mask at the *logical* width for the decoded fallback path.
    """

    encoding: Encoding
    n_rows: int
    code_bits: int                      # logical width of decoded codes
    stats: EncodingStats
    width: int = 0                      # payload field width (PLAIN/FOR)
    base: int = 0                       # frame of reference (FOR)
    words: jnp.ndarray | None = None    # packed payload (PLAIN/FOR)
    values: jnp.ndarray | None = None   # (n_runs_padded,) int32 (RLE)
    lengths: jnp.ndarray | None = None  # (n_runs_padded,) int32 (RLE)
    n_runs: int = 0
    valid: jnp.ndarray | None = field(default=None, repr=False)
    checksum: int = 0                   # crc32 over payload + layout meta

    @property
    def nbytes(self) -> int:
        """Physical bytes a scan streams for this chunk (a zero-row
        chunk streams nothing, metadata included)."""
        if self.encoding is Encoding.RLE:
            return 4 * (int(self.values.size) + int(self.lengths.size))
        n = 4 * int(self.words.size)
        return n + 8 if self.encoding is Encoding.FOR and n else n

    @property
    def logical_nbytes(self) -> int:
        return plain_nbytes(self.n_rows, self.code_bits)

    # --- integrity --------------------------------------------------------
    def payload_checksum(self) -> int:
        """crc32 over the payload planes plus the layout metadata that
        interprets them — a flipped bit anywhere a scan would read
        changes this, so corruption is *detected* on read, never
        silently aggregated (repro.resilience.ChunkGuard)."""
        crc = zlib.crc32(
            f"{self.encoding.value}|{self.n_rows}|{self.code_bits}|"
            f"{self.width}|{self.base}|{self.n_runs}".encode())
        for plane in (self.words, self.values, self.lengths):
            if plane is not None:
                crc = zlib.crc32(np.asarray(plane).tobytes(), crc)
        return crc

    def seal(self) -> "EncodedChunk":
        """Stamp the checksum of the current payload (encode time, or
        after an authorized repair re-encode)."""
        self.checksum = self.payload_checksum()
        return self

    def verify(self) -> bool:
        """Does the stored payload still match its sealed checksum?"""
        return self.payload_checksum() == self.checksum

    def decode(self) -> np.ndarray:
        """Exact logical codes back out of the physical layout."""
        if self.n_rows == 0:
            return np.zeros(0, np.uint32)
        if self.encoding is Encoding.RLE:
            lens = np.asarray(self.lengths)[:self.n_runs]
            return np.repeat(np.asarray(self.values, np.uint32)
                             [:self.n_runs], lens)
        vals = np.asarray(packref.unpack(self.words, self.width),
                          np.uint32)[:self.n_rows]
        return vals + np.uint32(self.base)


def encode_chunk(codes, code_bits: int,
                 encoding: Encoding | None = None) -> EncodedChunk:
    """Encode one chunk of dictionary codes; `encoding=None` lets the
    stats selector pick. Round-trips exactly (chunk.decode() == codes)."""
    codes = np.asarray(codes, np.uint32)
    n = len(codes)
    if n > MAX_CHUNK_ROWS:
        raise ValueError(
            f"chunk of {n} rows exceeds MAX_CHUNK_ROWS={MAX_CHUNK_ROWS} "
            f"(the bound that keeps per-chunk sum partials int32-exact); "
            f"re-chunk the column")
    vmax = (1 << (code_bits - 1)) - 1
    if n and int(codes.max()) > vmax:
        raise ValueError(
            f"codes exceed the {code_bits}-bit payload max {vmax}; encode "
            f"after db.columnar validation, not before")
    stats = EncodingStats.from_codes(codes, code_bits)
    enc = Encoding(encoding) if encoding is not None \
        else choose_encoding(stats)
    if enc is Encoding.RLE:
        if n == 0:
            values = lengths = np.zeros(0, np.int32)
            n_runs = 0
        else:
            starts = np.r_[0, np.flatnonzero(np.diff(codes)) + 1]
            lengths = np.diff(np.r_[starts, n]).astype(np.int32)
            values = codes[starts].astype(np.int32)
            n_runs = len(starts)
            pad = next_pow2(n_runs) - n_runs
            values = np.pad(values, (0, pad))
            lengths = np.pad(lengths, (0, pad))
        return EncodedChunk(
            enc, n, code_bits, stats, n_runs=n_runs,
            values=jnp.asarray(values), lengths=jnp.asarray(lengths),
            valid=jnp.asarray(packref.pack_mask(
                np.arange(plain_nbytes(n, code_bits) // 4
                          * (32 // code_bits)) < n, code_bits))).seal()
    if enc is Encoding.FOR:
        base, width = stats.vmin, stats.delta_bits
        payload = codes - np.uint32(base)
    else:
        base, width = 0, code_bits
        payload = codes
    words = packref.pack(payload, width)
    valid = packref.pack_mask(
        np.arange(len(words) * (32 // width)) < n, width)
    return EncodedChunk(enc, n, code_bits, stats, width=width, base=base,
                        words=jnp.asarray(words),
                        valid=jnp.asarray(valid)).seal()


@dataclass
class EncodedColumn:
    """A column as a sequence of independently-encoded row chunks.

    Duck-types the metadata surface the query/tier layers need from
    `db.columnar.BitPackedColumn`: `code_bits`, `num_rows`, `nbytes`
    (physical, compressed — what a scan actually streams) plus the new
    `logical_nbytes` (what the plain format would stream).
    """

    name: str
    code_bits: int
    num_rows: int
    chunk_rows: int
    chunks: list[EncodedChunk]
    dictionary: np.ndarray | None = None

    @classmethod
    def from_values(cls, name: str, values, code_bits: int,
                    chunk_rows: int = DEFAULT_CHUNK_ROWS,
                    encoding: Encoding | None = None,
                    dictionary=None) -> "EncodedColumn":
        values = np.asarray(values, np.uint32)
        if not 1 <= chunk_rows <= MAX_CHUNK_ROWS:
            raise ValueError(
                f"column {name!r}: chunk_rows={chunk_rows} outside "
                f"[1, {MAX_CHUNK_ROWS}]")
        chunks = [encode_chunk(values[i:i + chunk_rows], code_bits,
                               encoding)
                  for i in range(0, len(values), chunk_rows)]
        return cls(name, code_bits, len(values), chunk_rows, chunks,
                   None if dictionary is None else np.asarray(dictionary))

    @classmethod
    def from_column(cls, col, chunk_rows: int = DEFAULT_CHUNK_ROWS,
                    encoding: Encoding | None = None) -> "EncodedColumn":
        """Encode an existing BitPackedColumn (exact logical codes)."""
        codes = np.asarray(packref.unpack(col.words, col.code_bits),
                           np.uint32)[:col.num_rows]
        return cls.from_values(col.name, codes, col.code_bits, chunk_rows,
                               encoding, dictionary=col.dictionary)

    @property
    def nbytes(self) -> int:
        """Physical (compressed) bytes — the scan-traffic numerator."""
        return sum(c.nbytes for c in self.chunks)

    @property
    def logical_nbytes(self) -> int:
        return sum(c.logical_nbytes for c in self.chunks)

    @property
    def ratio(self) -> float:
        return self.logical_nbytes / self.nbytes if self.nbytes else 1.0

    def chunk_physical_bytes(self, chunk_rows: int) -> list[int]:
        """Physical bytes per placement chunk (the tier engine's unit).
        `chunk_rows` must be a multiple of the store's chunking so
        placement chunks aggregate whole encoded chunks."""
        if chunk_rows % self.chunk_rows:
            raise ValueError(
                f"column {self.name!r}: placement chunk_rows={chunk_rows} "
                f"is not a multiple of the store's chunk_rows="
                f"{self.chunk_rows}; build the PlacementEngine with the "
                f"store's chunking (or a multiple of it)")
        k = chunk_rows // self.chunk_rows
        return [sum(c.nbytes for c in self.chunks[i:i + k])
                for i in range(0, len(self.chunks), k)]

    def decode(self) -> np.ndarray:
        """Exact logical codes (dictionary not applied — parity with
        BitPackedColumn requires `dictionary[decode()]`)."""
        if not self.chunks:
            return np.zeros(0, np.uint32)
        return np.concatenate([c.decode() for c in self.chunks])

    def encodings(self) -> dict[str, int]:
        out = {e.value: 0 for e in Encoding}
        for c in self.chunks:
            out[c.encoding.value] += 1
        return out


@dataclass
class EncodedTable:
    """A compressed columnar table the QueryEngine executes directly.

    Duck-types `db.columnar.Table` where the engine reads metadata
    (`columns`, `num_rows`, `nbytes`); `nbytes` is *physical* so byte
    accounting (admission, tier service, energy) charges what actually
    crosses the memory bus, with `logical_nbytes` preserved beside it.
    """

    name: str
    chunk_rows: int
    columns: dict[str, EncodedColumn] = field(default_factory=dict)

    @classmethod
    def from_table(cls, table, chunk_rows: int = DEFAULT_CHUNK_ROWS,
                   encodings: dict[str, Encoding] | None = None
                   ) -> "EncodedTable":
        """Encode a db.Table chunk-by-chunk. `chunk_rows` is aligned so a
        chunk boundary is a word boundary for every column's *logical*
        width (the invariant tier placement and shard splitting already
        share); `encodings` pins named columns, others use the selector."""
        if not table.columns:
            return cls(table.name, max(1, chunk_rows))
        align = math.lcm(*(32 // c.code_bits
                           for c in table.columns.values()))
        chunk_rows = -(-max(1, chunk_rows) // align) * align
        if chunk_rows > MAX_CHUNK_ROWS:
            raise ValueError(
                f"chunk_rows={chunk_rows} exceeds MAX_CHUNK_ROWS="
                f"{MAX_CHUNK_ROWS} after width alignment")
        forced = dict(encodings or {})
        unknown = set(forced) - set(table.columns)
        if unknown:
            raise ValueError(f"encodings pin unknown column(s) "
                             f"{sorted(unknown)}; table has "
                             f"{sorted(table.columns)}")
        t = cls(table.name, chunk_rows)
        for name, col in table.columns.items():
            t.columns[name] = EncodedColumn.from_column(
                col, chunk_rows, forced.get(name))
        return t

    @property
    def num_rows(self) -> int:
        return (next(iter(self.columns.values())).num_rows
                if self.columns else 0)

    @property
    def n_chunks(self) -> int:
        return (len(next(iter(self.columns.values())).chunks)
                if self.columns else 0)

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns.values())

    @property
    def logical_nbytes(self) -> int:
        return sum(c.logical_nbytes for c in self.columns.values())

    @property
    def ratio(self) -> float:
        return self.logical_nbytes / self.nbytes if self.nbytes else 1.0

    def decode_table(self):
        """The exact plain-format table (the parity oracle's input)."""
        from repro.db.columnar import BitPackedColumn, Table
        t = Table(self.name)
        for name, col in self.columns.items():
            t.add(BitPackedColumn.from_values(
                name, col.decode(), col.code_bits,
                dictionary=col.dictionary))
        return t

    def stats(self) -> dict:
        return {
            "chunk_rows": self.chunk_rows,
            "physical_bytes": self.nbytes,
            "logical_bytes": self.logical_nbytes,
            "ratio": round(self.ratio, 4),
            "encodings": {n: c.encodings()
                          for n, c in self.columns.items()},
        }
