"""Query execution over an EncodedTable: scan the compressed bytes.

Chunk-by-chunk routing (each chunk carries its own encoding and, for FOR,
its own frame of reference):

- the dominant single-predicate/single-aggregate query over an RLE chunk
  of that same column takes the fused `scan_compressed` kernel — runs
  stream, rows never materialize;
- FOR and PLAIN chunks execute through the *existing* physical operators
  at their payload width: a FOR plane is a plain BitWeaving plane in
  delta space, so predicates are translated into that space
  (`translate_plan`) and the same scan/aggregate/fused kernels run on the
  compressed words — the fused same-width path engages automatically when
  predicate and aggregate chunks share a delta width. Aggregates come
  back in the delta domain and get an exact host-int base fix-up
  (sum += base*count, min/max += base);
- RLE chunks inside general plan shapes (AND/OR trees, cross-column
  aggregates) are decoded to rows in-graph (gather + repack) — the one
  documented case that materializes codes, off the dominant path.

Every path lands on the same empty-selection identity (count=0, sum=0,
min=vmax, max=0 at the *logical* width), so results are bit-identical to
the plain-format engine regardless of encoding mix.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.scan_compressed import ops as rle_ops
from repro.kernels.scan_filter.ref import codes_per_word
from repro.query import physical
from repro.query.physical import ColumnSlice
from repro.query.plan import And, Or, Plan, Pred, columns_of
from repro.store.encode import Encoding, EncodedTable


def identity_ints(code_bits: int) -> dict:
    """The empty-selection aggregate as exact host ints — the one answer
    every path (PALLAS / XLA_REF / sharded / encoded) must agree on."""
    return {"sum": 0, "count": 0, "min": (1 << (code_bits - 1)) - 1,
            "max": 0}


def fixup_base(agg: dict, base: int, code_bits: int) -> dict:
    """Translate a finalized delta-domain aggregate back to code space.

    Exact in Python ints (base*count exceeds int32 long before the planes
    would); an empty selection collapses to the canonical logical-width
    identity — the delta-domain min sentinel must not leak."""
    if agg["count"] == 0:
        return identity_ints(code_bits)
    if base == 0:
        return dict(agg)
    return {"sum": agg["sum"] + base * agg["count"],
            "count": agg["count"],
            "min": agg["min"] + base,
            "max": agg["max"] + base}


def translate_pred(op: str, constant: int, base: int,
                   width: int) -> tuple[str, int]:
    """Rewrite `col <op> constant` into the delta domain of a FOR chunk
    (codes = base + delta, deltas in [0, 2^(width-1)-1]).

    Out-of-range constants clamp to tautologies the kernels already
    short-circuit: `ge 0` matches every valid row, `gt dvmax` matches
    none — so the result is always a plain Pred and the unmodified
    physical operators execute it."""
    dvmax = (1 << (width - 1)) - 1
    c = constant - base
    all_, none = ("ge", 0), ("gt", dvmax)
    if op == "ge":
        o = all_ if c <= 0 else none if c > dvmax else (op, c)
    elif op == "gt":
        o = all_ if c < 0 else none if c >= dvmax else (op, c)
    elif op == "lt":
        o = none if c <= 0 else all_ if c > dvmax else (op, c)
    elif op == "le":
        o = none if c < 0 else all_ if c >= dvmax else (op, c)
    elif op == "eq":
        o = (op, c) if 0 <= c <= dvmax else none
    elif op == "ne":
        o = (op, c) if 0 <= c <= dvmax else all_
    else:
        raise ValueError(f"unknown predicate op {op!r}")
    return o


def translate_plan(plan: Plan, frames: dict[str, tuple[int, int]]) -> Plan:
    """Rewrite every leaf of a plan into its column's delta domain.
    `frames` maps column -> (base, payload width); base 0 at the logical
    width leaves a leaf unchanged."""
    if isinstance(plan, Pred):
        base, width = frames[plan.column]
        op, c = translate_pred(plan.op, plan.constant, base, width)
        return Pred(plan.column, op, c)
    if isinstance(plan, And):
        return And.of(*(translate_plan(p, frames) for p in plan.children))
    if isinstance(plan, Or):
        return Or.of(*(translate_plan(p, frames) for p in plan.children))
    raise ValueError(f"unknown plan node {type(plan).__name__!r}")


def jnp_pack_codes(vals, code_bits: int):
    """In-graph inverse of scan_filter.ref.unpack: row codes -> packed
    words (rows padded to a word multiple with zeros)."""
    c = codes_per_word(code_bits)
    vals = jnp.asarray(vals, jnp.uint32)
    vals = jnp.pad(vals, (0, (-vals.shape[0]) % c)).reshape(-1, c)
    shifts = jnp.arange(c, dtype=jnp.uint32) * code_bits
    return jnp.bitwise_or.reduce(vals << shifts[None, :], axis=1)


def rle_rows(chunk):
    """In-graph decode of an RLE chunk to its row codes (the fallback for
    plan shapes the run kernel does not cover)."""
    ends = jnp.cumsum(jnp.asarray(chunk.lengths, jnp.int32))
    idx = jnp.searchsorted(ends, jnp.arange(chunk.n_rows), side="right")
    return jnp.asarray(chunk.values, jnp.uint32)[idx]


@dataclass(frozen=True)
class _Bound:
    """One chunk of one column, bound for execution: a ColumnSlice plus
    the frame that maps its payload back to logical codes."""
    slice: ColumnSlice
    base: int


def _bind_chunk(col, ci: int) -> _Bound:
    ch = col.chunks[ci]
    if ch.encoding is Encoding.RLE:
        words = jnp_pack_codes(rle_rows(ch), ch.code_bits)
        return _Bound(ColumnSlice(words, ch.valid, ch.code_bits), 0)
    return _Bound(ColumnSlice(ch.words, ch.valid, ch.width), ch.base)


def _accumulate(total: dict, part: dict) -> None:
    total["sum"] += part["sum"]
    total["count"] += part["count"]
    total["min"] = min(total["min"], part["min"])
    total["max"] = max(total["max"], part["max"])


def execute_encoded(plan: Plan, aggregates, table: EncodedTable,
                    mode=None, guard=None) -> dict:
    """Run a bound plan over the compressed chunks -> exact host-int
    aggregates, bit-identical to the plain-format engine.

    `guard` (a resilience.ChunkGuard) makes every chunk read verify its
    checksum first: a corrupt chunk is quarantined and repaired from the
    oracle before its bytes reach a kernel, or the query dies with a
    typed ChunkCorruptionError — corrupt payloads never aggregate.
    """
    aggregates = tuple(aggregates)
    names = sorted(columns_of(plan) | set(aggregates))
    out = {a: identity_ints(table.columns[a].code_bits)
           for a in aggregates}
    fused_rle = (isinstance(plan, Pred) and aggregates == (plan.column,))
    for ci in range(table.n_chunks):
        if guard is not None:
            guard.check([(n, ci) for n in names])
        chunks = {n: table.columns[n].chunks[ci] for n in names}
        if fused_rle and chunks[plan.column].encoding is Encoding.RLE:
            ch = chunks[plan.column]
            d = rle_ops.rle_scan_aggregate(ch.values, ch.lengths,
                                           plan.constant, plan.op,
                                           ch.code_bits, mode=mode)
            _accumulate(out[plan.column], agg_ops.finalize(d))
            continue
        bound = {n: _bind_chunk(table.columns[n], ci) for n in names}
        frames = {n: (b.base, b.slice.code_bits)
                  for n, b in bound.items()}
        tplan = translate_plan(plan, frames)
        raw = physical.execute(tplan, aggregates,
                               {n: b.slice for n, b in bound.items()},
                               mode=mode)
        for a in aggregates:
            part = fixup_base(agg_ops.finalize(raw[a]), bound[a].base,
                              table.columns[a].code_bits)
            _accumulate(out[a], part)
    return out
