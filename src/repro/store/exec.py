"""Query execution over an EncodedTable: scan the compressed bytes.

Default path (`batched=True`): every chunk of a column executes in ONE
kernel launch per (column-group, encoding) instead of one per chunk.

- RLE chunks of the dominant single-pred/single-agg-same-column query
  batch through `scan_compressed.rle_scan_aggregate_batched` — all run
  planes stacked, one grid, one (n_chunks, 5) partial plane;
- everything else is *width-unified*: the chunks touched by a query are
  grouped by W = max payload width of the involved columns, the narrower
  side repacked to W host-side (a delta payload always fits a wider
  field; the reverse never happens because W is the max), and then
  - single-pred/single-agg groups take ONE batched fused launch
    (`scan_aggregate_batched`) whose per-chunk translated constants ride
    in as scalar-prefetched data (each FOR chunk subtracts its own base);
  - And/Or trees and multi-aggregate queries take one batched mask per
    leaf (`scan_filter_batched`) + one batched masked aggregate per
    aggregate column — launches scale with plan size, not chunk count.

Per-chunk (1, 5) partial rows are sliced out host-side, finalized,
base-fixed and accumulated exactly as the per-chunk loop
(`batched=False`, kept as the parity oracle) — results are bit-identical
to it and to the plain-format engine regardless of encoding mix, and
every path lands on the same empty-selection identity (count=0, sum=0,
min=vmax, max=0 at the *logical* width). `translate_plan` is memoized on
the frame tuple, so N chunks sharing a frame translate once.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.scan_aggregate import ops as fused_ops
from repro.kernels.scan_compressed import ops as rle_ops
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter.ref import codes_per_word, pack, pack_mask
from repro.query import physical
from repro.query.physical import ColumnSlice
from repro.query.plan import And, Or, Plan, Pred, columns_of
from repro.store.encode import Encoding, EncodedTable


def identity_ints(code_bits: int) -> dict:
    """The empty-selection aggregate as exact host ints — the one answer
    every path (PALLAS / XLA_REF / sharded / encoded) must agree on."""
    return {"sum": 0, "count": 0, "min": (1 << (code_bits - 1)) - 1,
            "max": 0}


def fixup_base(agg: dict, base: int, code_bits: int) -> dict:
    """Translate a finalized delta-domain aggregate back to code space.

    Exact in Python ints (base*count exceeds int32 long before the planes
    would); an empty selection collapses to the canonical logical-width
    identity — the delta-domain min sentinel must not leak."""
    if agg["count"] == 0:
        return identity_ints(code_bits)
    if base == 0:
        return dict(agg)
    return {"sum": agg["sum"] + base * agg["count"],
            "count": agg["count"],
            "min": agg["min"] + base,
            "max": agg["max"] + base}


def translate_pred(op: str, constant: int, base: int,
                   width: int) -> tuple[str, int]:
    """Rewrite `col <op> constant` into the delta domain of a FOR chunk
    (codes = base + delta, deltas in [0, 2^(width-1)-1]).

    Out-of-range constants clamp to tautologies the kernels already
    short-circuit: `ge 0` matches every valid row, `gt dvmax` matches
    none — so the result is always a plain Pred and the unmodified
    physical operators execute it."""
    dvmax = (1 << (width - 1)) - 1
    c = constant - base
    all_, none = ("ge", 0), ("gt", dvmax)
    if op == "ge":
        o = all_ if c <= 0 else none if c > dvmax else (op, c)
    elif op == "gt":
        o = all_ if c < 0 else none if c >= dvmax else (op, c)
    elif op == "lt":
        o = none if c <= 0 else all_ if c > dvmax else (op, c)
    elif op == "le":
        o = none if c < 0 else all_ if c >= dvmax else (op, c)
    elif op == "eq":
        o = (op, c) if 0 <= c <= dvmax else none
    elif op == "ne":
        o = (op, c) if 0 <= c <= dvmax else all_
    else:
        raise ValueError(f"unknown predicate op {op!r}")
    return o


def translate_plan(plan: Plan, frames: dict[str, tuple[int, int]]) -> Plan:
    """Rewrite every leaf of a plan into its column's delta domain.
    `frames` maps column -> (base, payload width); base 0 at the logical
    width leaves a leaf unchanged."""
    if isinstance(plan, Pred):
        base, width = frames[plan.column]
        op, c = translate_pred(plan.op, plan.constant, base, width)
        return Pred(plan.column, op, c)
    if isinstance(plan, And):
        return And.of(*(translate_plan(p, frames) for p in plan.children))
    if isinstance(plan, Or):
        return Or.of(*(translate_plan(p, frames) for p in plan.children))
    raise ValueError(f"unknown plan node {type(plan).__name__!r}")


def jnp_pack_codes(vals, code_bits: int):
    """In-graph inverse of scan_filter.ref.unpack: row codes -> packed
    words (rows padded to a word multiple with zeros)."""
    c = codes_per_word(code_bits)
    vals = jnp.asarray(vals, jnp.uint32)
    vals = jnp.pad(vals, (0, (-vals.shape[0]) % c)).reshape(-1, c)
    shifts = jnp.arange(c, dtype=jnp.uint32) * code_bits
    return jnp.bitwise_or.reduce(vals << shifts[None, :], axis=1)


def rle_rows(chunk):
    """In-graph decode of an RLE chunk to its row codes (the fallback for
    plan shapes the run kernel does not cover)."""
    ends = jnp.cumsum(jnp.asarray(chunk.lengths, jnp.int32))
    idx = jnp.searchsorted(ends, jnp.arange(chunk.n_rows), side="right")
    return jnp.asarray(chunk.values, jnp.uint32)[idx]


@dataclass(frozen=True)
class _Bound:
    """One chunk of one column, bound for execution: a ColumnSlice plus
    the frame that maps its payload back to logical codes."""
    slice: ColumnSlice
    base: int


def _bind_chunk(col, ci: int) -> _Bound:
    ch = col.chunks[ci]
    if ch.encoding is Encoding.RLE:
        words = jnp_pack_codes(rle_rows(ch), ch.code_bits)
        return _Bound(ColumnSlice(words, ch.valid, ch.code_bits), 0)
    return _Bound(ColumnSlice(ch.words, ch.valid, ch.width), ch.base)


def _accumulate(total: dict, part: dict) -> None:
    total["sum"] += part["sum"]
    total["count"] += part["count"]
    total["min"] = min(total["min"], part["min"])
    total["max"] = max(total["max"], part["max"])


def _translate_cached(plan: Plan, frames: dict, cache: dict) -> Plan:
    """Memoized translate_plan: chunks sharing an identical
    (base, width) frame map translate once per query."""
    key = tuple(sorted(frames.items()))
    tp = cache.get(key)
    if tp is None:
        tp = cache[key] = translate_plan(plan, frames)
    return tp


@dataclass(frozen=True)
class _BoundGroup:
    """All of one column's chunks in a width group, bound for one batched
    launch: stacked packed planes at the group width W plus per-chunk
    frame bases (0 for decoded-RLE and plain chunks)."""
    words: jnp.ndarray          # (n_chunks, n_words) uint32 at width W
    valid: jnp.ndarray          # (n_chunks, n_words) packed validity
    bases: tuple


def _bind_group(col, cids, W: int) -> _BoundGroup:
    """Bind chunks `cids` of a column at the unified width W.

    A chunk narrower than W (smaller FOR delta width, or RLE decoded to
    logical codes) repacks host-side — always exact, since W is the max
    width in the group and payloads only ever widen. Ragged chunks pad to
    the widest with zero words whose validity bits are 0."""
    words_np, bases = [], []
    for ci in cids:
        ch = col.chunks[ci]
        if ch.encoding is Encoding.RLE:
            words_np.append(pack(ch.decode(), W))
            bases.append(0)
        elif ch.width == W:
            words_np.append(np.asarray(ch.words, np.uint32))
            bases.append(ch.base)
        else:
            delta = (ch.decode().astype(np.int64) - ch.base).astype(
                np.uint32)
            words_np.append(pack(delta, W))
            bases.append(ch.base)
    cpw = codes_per_word(W)
    nw = max(w.size for w in words_np)
    words3 = np.zeros((len(cids), nw), np.uint32)
    valid3 = np.zeros((len(cids), nw), np.uint32)
    rows_idx = np.arange(nw * cpw)
    for k, (ci, w) in enumerate(zip(cids, words_np)):
        words3[k, :w.size] = w
        valid3[k] = pack_mask(rows_idx < col.chunks[ci].n_rows, W)[:nw]
    return _BoundGroup(jnp.asarray(words3), jnp.asarray(valid3),
                       tuple(bases))


def _bind_group_cached(col, cids, W: int) -> _BoundGroup:
    """Bound planes are query-independent, so they cache on the column,
    keyed by (W, cids) and validated by chunk object identity: chunk
    payloads are immutable, and every mutation path (quarantine repair)
    *replaces* the chunk object, which invalidates the entry here."""
    cache = col.__dict__.setdefault("_bind_cache", {})
    key = (W, tuple(cids))
    hit = cache.get(key)
    if hit is not None:
        chunks_then, bg = hit
        if all(col.chunks[ci] is ch for ci, ch in zip(key[1], chunks_then)):
            return bg
    bg = _bind_group(col, cids, W)
    cache[key] = (tuple(col.chunks[ci] for ci in cids), bg)
    return bg


def _batched_mask(tplans, bound, W: int, mode):
    """Packed selection masks for a width group, one batched dispatch per
    plan *leaf* (the per-chunk translated plans share the tree structure;
    only leaf constants differ). Mirrors physical.eval_mask: leaf mask
    AND validity, And/Or combined wordwise."""
    def rec(nodes):
        n0 = nodes[0]
        if isinstance(n0, Pred):
            g = bound[n0.column]
            triples = [scan_ops.canonical_pred(nd.op, nd.constant, W)
                       for nd in nodes]
            m = scan_ops.scan_filter_batched(g.words, triples, W,
                                             mode=mode)
            return m & g.valid
        subs = [rec([nd.children[k] for nd in nodes])
                for k in range(len(n0.children))]
        combine = jnp.bitwise_and if isinstance(n0, And) else jnp.bitwise_or
        acc = subs[0]
        for s in subs[1:]:
            acc = combine(acc, s)
        return acc
    return rec(tplans)


def _row_dict(row) -> dict:
    return {"sum_lo": row[0], "sum_hi": row[1], "count": row[2],
            "min": row[3], "max": row[4]}


def _chunk_payload_width(ch) -> int:
    """Payload width a chunk contributes to its group's unified W: RLE
    decodes to logical codes, FOR/plain scan at their stored width."""
    return ch.code_bits if ch.encoding is Encoding.RLE else ch.width


def _execute_batched(plan: Plan, aggregates, table: EncodedTable,
                     mode) -> dict:
    names = sorted(columns_of(plan) | set(aggregates))
    out = {a: identity_ints(table.columns[a].code_bits)
           for a in aggregates}
    fused_rle = (isinstance(plan, Pred) and aggregates == (plan.column,))
    fused = isinstance(plan, Pred) and len(aggregates) == 1

    rle_cids: list[int] = []
    groups: dict[int, list[int]] = {}
    for ci in range(table.n_chunks):
        chunks = [table.columns[n].chunks[ci] for n in names]
        if any(ch.n_rows == 0 for ch in chunks):
            continue                  # a zero-row chunk is the identity
        if fused_rle and chunks[0].encoding is Encoding.RLE:
            rle_cids.append(ci)       # names == (plan.column,) here
            continue
        W = max(_chunk_payload_width(ch) for ch in chunks)
        groups.setdefault(W, []).append(ci)

    if rle_cids:                      # one launch for every RLE chunk
        col = table.columns[plan.column]
        planes = [(col.chunks[ci].values, col.chunks[ci].lengths)
                  for ci in rle_cids]
        res = np.asarray(rle_ops.rle_scan_aggregate_batched(
            planes, plan.constant, plan.op, col.code_bits, mode=mode))
        dispatch.record_batch("rle_scan_aggregate", col.code_bits,
                              len(rle_cids))
        for k in range(len(rle_cids)):
            _accumulate(out[plan.column],
                        agg_ops.finalize(_row_dict(res[k])))

    tcache: dict = {}
    for W, cids in sorted(groups.items()):
        bound = {n: _bind_group_cached(table.columns[n], cids, W)
                 for n in names}
        tplans = [_translate_cached(
            plan, {n: (bound[n].bases[k], W) for n in names}, tcache)
            for k in range(len(cids))]
        if fused:
            pcol, acol = plan.column, aggregates[0]
            triples = [scan_ops.canonical_pred(tp.op, tp.constant, W)
                       for tp in tplans]
            res = np.asarray(fused_ops.scan_aggregate_batched(
                bound[pcol].words, bound[acol].words, bound[pcol].valid,
                triples, W, mode=mode))
            dispatch.record_batch("scan_aggregate", W, len(cids))
            for k in range(len(cids)):
                part = fixup_base(agg_ops.finalize(_row_dict(res[k])),
                                  bound[acol].bases[k],
                                  table.columns[acol].code_bits)
                _accumulate(out[acol], part)
            continue
        mask3 = _batched_mask(tplans, bound, W, mode)
        dispatch.record_batch("scan_filter", W, len(cids))
        for acol in aggregates:
            g = bound[acol]
            res = np.asarray(agg_ops.aggregate_batched(g.words, mask3, W,
                                                       mode=mode))
            dispatch.record_batch("aggregate", W, len(cids))
            for k in range(len(cids)):
                part = fixup_base(agg_ops.finalize(_row_dict(res[k])),
                                  g.bases[k],
                                  table.columns[acol].code_bits)
                _accumulate(out[acol], part)
    return out


def execute_encoded(plan: Plan, aggregates, table: EncodedTable,
                    mode=None, guard=None, batched: bool = True) -> dict:
    """Run a bound plan over the compressed chunks -> exact host-int
    aggregates, bit-identical to the plain-format engine.

    `batched=True` (default) collapses the per-chunk kernel loop into one
    launch per (column group, encoding); `batched=False` keeps the
    original chunk-at-a-time loop as the in-tree parity oracle.

    `guard` (a resilience.ChunkGuard) makes every chunk read verify its
    checksum first: a corrupt chunk is quarantined and repaired from the
    oracle before its bytes reach a kernel, or the query dies with a
    typed ChunkCorruptionError — corrupt payloads never aggregate. All
    checks run before the first kernel launch, in (chunk, column) order,
    so quarantine/repair order matches the per-chunk loop exactly.
    """
    aggregates = tuple(aggregates)
    names = sorted(columns_of(plan) | set(aggregates))
    if guard is not None:
        for ci in range(table.n_chunks):
            guard.check([(n, ci) for n in names])
    if batched:
        return _execute_batched(plan, aggregates, table, mode)

    out = {a: identity_ints(table.columns[a].code_bits)
           for a in aggregates}
    fused_rle = (isinstance(plan, Pred) and aggregates == (plan.column,))
    tcache: dict = {}
    for ci in range(table.n_chunks):
        chunks = {n: table.columns[n].chunks[ci] for n in names}
        if fused_rle and chunks[plan.column].encoding is Encoding.RLE:
            ch = chunks[plan.column]
            d = rle_ops.rle_scan_aggregate(ch.values, ch.lengths,
                                           plan.constant, plan.op,
                                           ch.code_bits, mode=mode)
            _accumulate(out[plan.column], agg_ops.finalize(d))
            continue
        bound = {n: _bind_chunk(table.columns[n], ci) for n in names}
        frames = {n: (b.base, b.slice.code_bits)
                  for n, b in bound.items()}
        tplan = _translate_cached(plan, frames, tcache)
        raw = physical.execute(tplan, aggregates,
                               {n: b.slice for n, b in bound.items()},
                               mode=mode)
        for a in aggregates:
            part = fixup_base(agg_ops.finalize(raw[a]), bound[a].base,
                              table.columns[a].code_bits)
            _accumulate(out[a], part)
    return out


# --------------------------------------------------------------------------
# grouped execution (GroupBy / HashJoin over compressed chunks)
# --------------------------------------------------------------------------

def _grouped_strategy(query, table, names, domain_ok: bool):
    """Pick the kernels/group_aggregate strategy per chunk from its
    EncodingStats: the fused RLE run path when the key chunk is RLE and
    the query is a count-only shape whose predicate the run kernel can
    evaluate, dense accumulator planes while the (FOR-framed) group
    domain stays under DENSE_MAX_GROUPS, the host sort/hash fallback
    otherwise. Zero-row chunks are skipped (the grouped identity)."""
    from repro.query import relational
    kcol = table.columns[query.key]
    kp = relational.key_only_pred(query, kcol.code_bits)
    rle_ok = (not query.aggs) and kp is not False
    rle_cids, dense_cids, fb_cids = [], [], []
    for ci in range(table.n_chunks):
        chunks = [table.columns[n].chunks[ci] for n in names]
        if any(ch.n_rows == 0 for ch in chunks):
            continue
        if rle_ok and domain_ok \
                and kcol.chunks[ci].encoding is Encoding.RLE:
            rle_cids.append(ci)
        elif domain_ok:
            dense_cids.append(ci)
        else:
            fb_cids.append(ci)
    return rle_cids, dense_cids, fb_cids, kp


def execute_grouped_encoded(query, table: EncodedTable, mode=None,
                            guard=None) -> dict:
    """GroupBy/HashJoin over the compressed chunks -> the finalized
    grouped result, bit-identical to relational.execute_grouped_oracle
    on the decoded table.

    Batched like execute_encoded: all RLE-strategy chunks share ONE fused
    run launch, all dense-strategy chunks share ONE accumulator-plane
    launch per value column — `(n_chunks, n_groups, 3)` partials sliced
    host-side with the exact FOR base fix-up (sum += base * count) before
    the partial dicts merge. `guard` semantics match execute_encoded:
    every referenced (column, chunk) verifies before the first launch, in
    (chunk, column) order."""
    from repro.kernels.group_aggregate import ops as gops
    from repro.query import relational
    relational.bind_check(query, table.columns)
    names = sorted(columns_of(query.plan()) | set(query.aggregates))
    if guard is not None:
        for ci in range(table.n_chunks):
            guard.check([(n, ci) for n in names])

    kcol = table.columns[query.key]
    stats = [ch.stats for ch in kcol.chunks if ch.n_rows]
    if not stats:
        return relational.empty_result()
    kmin = min(s.vmin for s in stats)
    kmax = max(s.vmax for s in stats)
    domain = relational.group_domain(query, kmin, kmax)
    domain_ok = relational.dense_ok(domain) and len(domain) > 0
    rle_cids, dense_cids, fb_cids, kp = _grouped_strategy(
        query, table, names, domain_ok)
    part = relational.new_partial()

    if rle_cids:
        planes = [(kcol.chunks[ci].values, kcol.chunks[ci].lengths)
                  for ci in rle_cids]
        pred = None if kp == ("ge", 0, False) else kp
        res = np.asarray(gops.rle_group_accumulate_batched(
            planes, domain, pred=pred, mode=mode))
        dispatch.record_batch("rle_group_accumulate", kcol.code_bits,
                              len(rle_cids))
        # normalized [lo, hi, count] planes are additive in int64:
        # (sum hi << 16) + sum lo == sum((hi << 16) + lo), so all RLE
        # chunks (base 0, shared domain) absorb as one summed plane
        relational.absorb_plane(part, domain,
                                res.astype(np.int64).sum(axis=0), None,
                                count_source=True)

    if dense_cids:
        decoded = {n: [table.columns[n].chunks[ci].decode()
                       for ci in dense_cids] for n in names}
        sels = []
        for k, ci in enumerate(dense_cids):
            cols = {n: decoded[n][k] for n in names}
            sels.append(np.asarray(
                relational.eval_plan_codes(query.plan(), cols), np.int32))
        keys3 = gops.lift_chunks(decoded[query.key])
        sel3 = gops.lift_chunks(sels)
        value_cols = query.aggs if query.aggs else (None,)
        for i, name in enumerate(value_cols):
            if name is None:
                vals3 = jnp.zeros_like(keys3)
                bases = [0] * len(dense_cids)
            else:
                col = table.columns[name]
                bases = [col.chunks[ci].base if col.chunks[ci].encoding
                         is Encoding.FOR else 0 for ci in dense_cids]
                vals3 = gops.lift_chunks(
                    [decoded[name][k].astype(np.int64) - bases[k]
                     for k in range(len(dense_cids))])
            res = np.asarray(gops.group_sum_count_batched(
                keys3, vals3, sel3, domain, mode=mode))
            dispatch.record_batch("group_sum_count", len(domain),
                                  len(dense_cids))
            for k in range(len(dense_cids)):
                relational.absorb_plane(part, domain, res[k], name,
                                        base=bases[k],
                                        count_source=(i == 0))

    if fb_cids:
        bk = relational.build_keys(query) \
            if hasattr(query, "build") else None
        dispatch.count_launch("group_aggregate_fallback", len(fb_cids))
        for ci in fb_cids:
            cols = {n: table.columns[n].chunks[ci].decode()
                    for n in names}
            sel = np.asarray(
                relational.eval_plan_codes(query.plan(), cols), bool)
            if bk is not None:
                sel = sel & np.isin(cols[query.key], bk)
            relational.absorb_fallback(
                part, cols[query.key],
                {a: cols[a] for a in query.aggs}, sel)
    return relational.finalize(part)
