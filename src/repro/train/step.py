"""Train/eval/serve step builders: loss, grads, accumulation, MoE bias hook.

These are the functions the launcher jits with in/out shardings and the
dry-run lowers; they close over the ArchConfig only (no mesh knowledge —
sharding arrives via logical constraints + jit shardings).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import lm, moe
from repro.models.common import chunked_cross_entropy, softmax_cross_entropy
from repro.train import optim

AUX_LOSS_WEIGHT = 0.01


def loss_fn(params, cfg, batch):
    """batch: {'inputs': (B,S) or (B,S,D), 'labels': (B,S)}."""
    inputs, labels = batch["inputs"], batch["labels"]
    b, s = labels.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.fused_ce:
        hidden, _, aux = lm.apply(params, cfg, inputs, positions,
                                  return_hidden=True)
        ce = chunked_cross_entropy(hidden, lm.head_weight(params, cfg), labels)
    else:
        logits, _, aux = lm.apply(params, cfg, inputs, positions)
        ce = softmax_cross_entropy(logits, labels)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def _microbatch(tree, idx, n):
    return jax.tree.map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:])[idx], tree)


def make_train_step(cfg, opt_cfg: optim.AdamWConfig, num_microbatches: int = 1):
    """Returns step(state, batch) -> (state, metrics). state = dict(params,
    opt, step). Gradient accumulation via lax.scan over microbatches."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, parts), grads = grad_fn(params, cfg, batch)
        return loss, parts, grads

    def accumulated(params, batch):
        def body(carry, idx):
            loss_acc, grads_acc = carry
            mb = _microbatch(batch, idx, num_microbatches)
            loss, parts, grads = single(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc,
                                     jax.tree.map(
                                         lambda g: g.astype(jnp.float32),
                                         grads))
            return (loss_acc + loss, grads_acc), parts

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), parts = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros),
            jnp.arange(num_microbatches))
        inv = 1.0 / num_microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        last_parts = jax.tree.map(lambda x: x[-1], parts)
        return loss_sum * inv, last_parts, grads

    def step(state, batch):
        params = state["params"]
        if num_microbatches > 1:
            loss, parts, grads = accumulated(params, batch)
        else:
            loss, parts, grads = single(params, batch)
        new_params, new_opt, om = optim.apply_updates(
            params, grads, state["opt"], opt_cfg)
        if cfg.num_experts and cfg.aux_free_bias:
            new_params = _moe_bias_update(new_params, grads, cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return step


def _moe_bias_update(params, grads, cfg):
    """Aux-loss-free router balancing: the router gradient's per-expert
    magnitude is a live proxy for expert load; nudge the selection bias
    against heavy experts (applied outside the optimizer, DeepSeek-V3
    style)."""

    def fix(tree, gtree):
        if isinstance(tree, (tuple, list)):
            return type(tree)(fix(t, g) for t, g in zip(tree, gtree))
        if isinstance(tree, dict):
            out = dict(tree)
            if "router_bias" in tree and "router" in gtree:
                # router weight (..., d, E) -> per-expert grad mass (..., E)
                load_proxy = jnp.sum(jnp.abs(
                    gtree["router"].astype(jnp.float32)), axis=-2)
                out["router_bias"] = moe.bias_update(
                    tree["router_bias"], load_proxy)
            return {k: fix(v, gtree[k]) if isinstance(v, (dict, tuple, list))
                    else out[k] for k, v in out.items()}
        return tree

    return fix(params, grads)


def make_eval_step(cfg):
    def step(params, batch):
        loss, parts = loss_fn(params, cfg, batch)
        return {"loss": loss, **parts}
    return step


def init_state(key, cfg, opt_cfg: optim.AdamWConfig):
    """Returns (state, axes) — axes mirror state for sharding resolution."""
    params, axes = lm.init(key, cfg)
    opt = optim.init(params, opt_cfg)
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    state_axes = {"params": axes, "opt": optim.opt_axes(axes, opt_cfg),
                  "step": "_scalar_"}
    return state, state_axes
