"""AdamW with fp32 master weights + cosine schedule (pure JAX, no deps).

State layout (all fp32, FSDP-sharded like the params they mirror):
  m, v        — Adam moments
  master      — fp32 master copy of (possibly bf16) params
  count       — step counter (scalar)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    use_master: bool = True


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init(params, cfg: AdamWConfig):
    # np.zeros (not jnp): lazy jnp constants of equal shape can be deduped
    # into ONE device buffer, which breaks donation ("donated twice").
    import numpy as np

    zeros = lambda p: np.zeros(p.shape, np.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.use_master:
        # copy=True: same-dtype astype is a no-op and would alias the param
        # buffer with its master copy (breaking donation).
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def opt_axes(params_axes, cfg: AdamWConfig):
    """Logical axes for the optimizer state (mirror the params)."""
    ax = {"m": params_axes, "v": params_axes, "count": "_scalar_"}
    if cfg.use_master:
        ax["master"] = params_axes
    return ax


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = schedule(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    def upd(p32, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        return p32 - lr * (step + cfg.weight_decay * p32)

    base = state.get("master") or jax.tree.map(
        lambda p: p.astype(jnp.float32), params)
    new_master = jax.tree.map(upd, base, new_m, new_v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"m": new_m, "v": new_v, "count": count}
    if cfg.use_master:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
