"""Training observability: throughput + roofline-referenced MFU logging.

Writes JSONL records per step (host-side, cheap) with:
- wall-time, tokens/sec, step time EWMA,
- achieved MFU against the configured hardware peak,
- the analytic roofline step estimate for the active strategy, so the gap
  between achieved and roofline is a first-class production metric (the
  framework's whole thesis).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import roofline, traffic
from repro.core.systems import TPU_V5E, TPUSpec


class MetricsLogger:
    def __init__(self, path, cfg: ArchConfig, shape: ShapeSpec,
                 chips: int, strategy: str = "megatron",
                 tpu: TPUSpec = TPU_V5E):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.cfg, self.shape, self.chips, self.tpu = cfg, shape, chips, tpu
        self.model_flops = roofline.model_flops(cfg, shape)
        mesh = traffic.MeshShape(chips=chips, tp=1, fsdp=max(chips, 1),
                                 dp=max(chips, 1))
        try:
            hbm = traffic.hbm_traffic(cfg, shape, mesh, strategy)
            coll = traffic.collective_traffic(cfg, shape, mesh, strategy)
            self.roofline_step_s = max(
                self.model_flops / chips / tpu.peak_flops_bf16,
                hbm["total"] / tpu.hbm_bandwidth,
                coll["total"] / tpu.ici_link_bandwidth)
        except Exception:
            self.roofline_step_s = None
        self._ewma = None
        self._f = open(self.path, "a")

    def log(self, step: int, seconds: float, metrics: dict):
        self._ewma = (seconds if self._ewma is None
                      else 0.9 * self._ewma + 0.1 * seconds)
        tokens = self.shape.tokens_per_step
        achieved = self.model_flops / seconds / self.chips
        rec = {
            "step": step,
            "time": time.time(),
            "step_s": seconds,
            "step_s_ewma": self._ewma,
            "tokens_per_s": tokens / seconds,
            "mfu": achieved / self.tpu.peak_flops_bf16,
            "roofline_step_s": self.roofline_step_s,
            "roofline_gap": (seconds / self.roofline_step_s
                             if self.roofline_step_s else None),
            **{k: float(v) for k, v in metrics.items()},
        }
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    def close(self):
        self._f.close()
