"""Deterministic synthetic LM data pipeline.

Design constraints from the fault-tolerance story (DESIGN.md §4):
- batch(step) is a pure function of (seed, step) — restart at step k
  reproduces the exact stream, so checkpoint/restart is bitwise stable.
- Each host materializes only its process-local rows;
  `make_global_batch` assembles the global jax.Array on any mesh, so the
  same logical stream feeds 1 host or 128 (elastic re-scale safe).
- A host-side prefetch thread overlaps generation with device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    embed_dim: int = 0      # >0: embeddings-mode archs (audio/vlm stubs)


class SyntheticLM:
    """Zipf-ish token stream with next-token labels (shifted inputs)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rows(self, step: int, lo: int, hi: int):
        """Rows [lo, hi) of the global batch at `step` (pure function)."""
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        # zipf-like marginal: heavy head like natural text
        u = rng.random((c.global_batch, c.seq_len + 1))
        toks = np.minimum((u ** -1.2 - 1.0) * 37.0,
                          c.vocab_size - 1).astype(np.int32)
        inputs, labels = toks[:, :-1], toks[:, 1:]
        if c.embed_dim:
            emb_rng = np.random.default_rng((c.seed, step, 7))
            inputs = emb_rng.standard_normal(
                (c.global_batch, c.seq_len, c.embed_dim),
                dtype=np.float32)
        return {"inputs": inputs[lo:hi], "labels": labels[lo:hi]}

    def batch(self, step: int):
        """Full global batch (single-host convenience)."""
        return self._rows(step, 0, self.cfg.global_batch)

    def local_batch(self, step: int, process_index: int = None,
                    process_count: int = None):
        pi = jax.process_index() if process_index is None else process_index
        pc = jax.process_count() if process_count is None else process_count
        per = self.cfg.global_batch // pc
        return self._rows(step, pi * per, (pi + 1) * per)


def make_global_batch(host_batch: dict, mesh, specs: dict):
    """Assemble process-local numpy rows into global jax.Arrays."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        sh = NamedSharding(mesh, spec)
        if jax.process_count() == 1:
            return jax.device_put(x, sh)
        return jax.make_array_from_process_local_data(sh, x)

    return {k: put(v, specs[k]) for k, v in host_batch.items()}


class Prefetcher:
    """Background thread that keeps `depth` host batches ready."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.ds = ds
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._loop, daemon=True)
        self.t.start()

    def _loop(self):
        s = self.step
        while not self._stop.is_set():
            b = self.ds.local_batch(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
