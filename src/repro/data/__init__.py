"""Data pipeline: deterministic, sharded, checkpoint-restartable."""
from repro.data.pipeline import DataConfig, SyntheticLM, make_global_batch

__all__ = ["DataConfig", "SyntheticLM", "make_global_batch"]
