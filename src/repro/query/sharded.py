"""Row-wise table sharding across a mesh + per-shard query execution.

"Processing Data Where It Makes Sense" at cluster scale: each device holds a
contiguous row range of every column and scans it locally; only the four
aggregate scalars per shard cross the interconnect (psum/pmin/pmax inside a
shard_map). Rows are padded so one shard boundary works for every column:
rows_per_shard is a multiple of every column's codes-per-word (lcm), hence
each column's word array splits evenly on the same row boundaries despite
mixed code widths. Validity masks cancel all padding rows.

The paper's provisioning model maps directly: chips = shards, and per-shard
scan throughput is what `core_perf` claims each chip sustains — the query
engine compares the two.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.scan_filter import ref as packref
from repro.query import physical
from repro.query.physical import ColumnSlice
from repro.query.plan import columns_of


@dataclass
class ShardedTable:
    """A repro.db Table partitioned row-wise along one mesh axis."""

    table: Any                      # the logical (host) Table
    mesh: Any
    axis: str
    rows_per_shard: int
    slices: dict[str, ColumnSlice]  # device arrays, sharded along `axis`
    _jitted: dict = field(default_factory=dict, repr=False)

    @property
    def num_rows(self) -> int:
        return self.table.num_rows

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def columns(self):              # metadata view, same duck type as Table
        return self.table.columns

    @property
    def nbytes(self) -> int:
        """Device-resident bytes (includes shard-alignment padding)."""
        return sum(int(s.words.size) * 4 for s in self.slices.values())

    @classmethod
    def shard(cls, table, mesh, axis: str = "data") -> "ShardedTable":
        if not table.columns:
            raise ValueError("cannot shard an empty table")
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no axis {axis!r}; axes are "
                             f"{tuple(mesh.shape)}")
        n = int(mesh.shape[axis])
        rps = physical.align_chunk_rows(table.columns,
                                        max(1, -(-table.num_rows // n)))
        total_rows = rps * n
        sharding = NamedSharding(mesh, P(axis))
        slices = {}
        for name, col in table.columns.items():
            cpw = 32 // col.code_bits
            w = np.zeros(total_rows // cpw, np.uint32)
            w[:col.words.size] = np.asarray(col.words)
            valid = packref.pack_mask(
                np.arange(total_rows) < table.num_rows, col.code_bits)
            slices[name] = ColumnSlice(
                jax.device_put(jnp.asarray(w), sharding),
                jax.device_put(jnp.asarray(valid), sharding),
                col.code_bits)
        return cls(table, mesh, axis, rps, slices)

    # --- tier accounting --------------------------------------------------
    def chunk_bytes(self, plan, aggregates,
                    chunk_rows: int) -> dict[tuple[str, int], int]:
        """Per-(column, chunk) *device-resident* bytes this query streams
        (shard-alignment padding included — padded words cross the memory
        bus like real ones), reported to the tier placement engine. Chunk
        ids live in the padded row space; when `chunk_rows` divides
        rows_per_shard no chunk straddles a shard boundary."""
        return physical.chunk_universe(
            self.slices,
            physical.align_chunk_rows(self.table.columns, chunk_rows),
            names=self._referenced(plan, tuple(aggregates)))

    # --- execution --------------------------------------------------------
    def _referenced(self, plan, aggregates: tuple) -> tuple:
        return tuple(sorted(columns_of(plan) | set(aggregates)))

    def execute(self, plan, aggregates, mode=None) -> dict:
        """Per-shard scan+aggregate with a psum combine; returns
        {agg_column: {sum, count, min, max}} as exact host ints.

        Compiled executions are cached per (plan, aggregates, mode) — plans
        are frozen dataclasses, so the query shape is the cache key.
        """
        aggregates = tuple(aggregates)
        key = (plan, aggregates, None if mode is None else str(mode))
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build(plan, aggregates, mode)
        args = []
        for n in self._referenced(plan, aggregates):
            args += [self.slices[n].words, self.slices[n].valid]
        return physical.finalize_aggs(fn(*args))

    def execute_partials(self, plan, aggregates, mode=None) -> list[dict]:
        """Per-shard finalized aggregates in shard order (exact host ints).

        The degraded-mode combine surface: resilience.recover merges the
        surviving shards' partials with lost shards re-executed from the
        host copy, instead of the all-shards psum. Merging all partials
        equals `execute` bit for bit — the psum'd planes are themselves
        per-shard sums, and finalize is linear in the planes.
        """
        aggregates = tuple(aggregates)
        key = (plan, aggregates, None if mode is None else str(mode),
               "partials")
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build_partials(plan, aggregates,
                                                          mode)
        args = []
        for n in self._referenced(plan, aggregates):
            args += [self.slices[n].words, self.slices[n].valid]
        stacked = fn(*args)     # {col: {field: (n_shards,) device arrays}}
        return [physical.finalize_aggs(
                    {col: {k: v[i] for k, v in d.items()}
                     for col, d in stacked.items()})
                for i in range(self.n_shards)]

    def _build_partials(self, plan, aggregates: tuple, mode):
        names = self._referenced(plan, aggregates)
        bits = {n: self.slices[n].code_bits for n in names}
        axis = self.axis

        def per_shard(*flat):
            slices = {n: ColumnSlice(flat[2 * i], flat[2 * i + 1], bits[n])
                      for i, n in enumerate(names)}
            out = physical.execute(plan, aggregates, slices, mode=mode)
            # no psum: each shard contributes its (1,) slice of the
            # stacked per-shard output instead of a combined scalar
            return jax.tree.map(lambda x: jnp.reshape(x, (1,)), out)

        return jax.jit(shard_map(per_shard, mesh=self.mesh,
                                 in_specs=(P(axis),) * (2 * len(names)),
                                 out_specs=P(axis), check_rep=False))

    # --- degraded-mode recovery source ------------------------------------
    def shard_row_range(self, shard: int) -> tuple[int, int]:
        """Logical (unpadded) row range [lo, hi) shard `shard` owns; empty
        when the shard holds only alignment padding."""
        if shard < 0 or shard >= self.n_shards:
            raise ValueError(f"shard={shard} outside [0, {self.n_shards})")
        lo = shard * self.rows_per_shard
        return lo, max(lo, min(lo + self.rows_per_shard, self.num_rows))

    def host_shard_slices(self, shard: int, names=None
                          ) -> dict[str, ColumnSlice]:
        """One shard's row range bound from the logical (host) table — the
        capacity-tier replica degraded execution re-reads when that
        shard's device copy is lost. rows_per_shard is word-aligned for
        every column, so the word slice is exact; a fresh validity mask
        cancels rows past num_rows."""
        lo, hi = self.shard_row_range(shard)
        out = {}
        for name in (sorted(names) if names is not None else
                     self.table.columns):
            col = self.table.columns[name]
            cpw = 32 // col.code_bits
            w0 = lo // cpw
            w1 = min(w0 + self.rows_per_shard // cpw, int(col.words.size))
            words = np.asarray(col.words)[w0:w1]
            valid = packref.pack_mask(
                np.arange(words.size * cpw) < (hi - lo), col.code_bits)
            out[name] = ColumnSlice(jnp.asarray(words), jnp.asarray(valid),
                                    col.code_bits)
        return out

    def _build(self, plan, aggregates: tuple, mode):
        names = self._referenced(plan, aggregates)
        bits = {n: self.slices[n].code_bits for n in names}
        axis = self.axis

        def per_shard(*flat):
            slices = {n: ColumnSlice(flat[2 * i], flat[2 * i + 1], bits[n])
                      for i, n in enumerate(names)}
            return physical.execute(plan, aggregates, slices, mode=mode,
                                    axis=axis)

        # check_rep=False: pallas_call has no replication rule; the outputs
        # are psum-combined and genuinely replicated
        return jax.jit(shard_map(per_shard, mesh=self.mesh,
                                 in_specs=(P(axis),) * (2 * len(names)),
                                 out_specs=P(), check_rep=False))

    # --- grouped execution (GroupBy / HashJoin) ---------------------------
    def key_code_range(self, key: str) -> tuple[int, int]:
        """Observed (kmin, kmax) of a column's codes over the logical
        rows — what bounds the dense group domain. Cached per column on
        the host table (codes are immutable)."""
        cached = self._jitted.get(("range", key))
        if cached is None:
            col = self.table.columns[key]
            codes = np.asarray(packref.unpack(
                col.words, col.code_bits))[: col.num_rows]
            cached = self._jitted[("range", key)] = (
                (int(codes.min()), int(codes.max())) if codes.size
                else (0, -1))
        return cached

    def execute_grouped_planes(self, plan, key: str, aggs: tuple, domain,
                               mode=None) -> dict:
        """Per-shard grouped accumulator planes, the all-gather combine
        surface: {value_column_or_'': (n_shards, n_groups, 3)} int32
        stacks, one normalized [sum_lo, sum_hi, count] plane per shard
        per value column (one '' plane when aggs is empty).

        `domain` (sorted group keys in THIS table's code domain — the
        delta domain for the encoded view) broadcasts replicated to every
        shard, which is exactly how a join's build side ships. Merging
        the shard planes host-side equals an unsharded execution bit for
        bit: the planes are normalized per shard and the partial algebra
        is associative in exact ints."""
        aggs = tuple(aggs)
        cache_key = (plan, key, aggs,
                     None if mode is None else str(mode), "grouped")
        fn = self._jitted.get(cache_key)
        if fn is None:
            fn = self._jitted[cache_key] = self._build_grouped(
                plan, key, aggs, mode)
        args = []
        for n in self._referenced(plan, aggs + (key,)):
            args += [self.slices[n].words, self.slices[n].valid]
        stacked = fn(jnp.asarray(np.asarray(domain), jnp.int32), *args)
        return {name: np.asarray(v) for name, v in stacked.items()}

    def _build_grouped(self, plan, key: str, aggs: tuple, mode):
        from repro.kernels.group_aggregate import ops as gops
        from repro.query import relational
        names = self._referenced(plan, aggs + (key,))
        bits = {n: self.slices[n].code_bits for n in names}
        axis = self.axis

        def per_shard(gk, *flat):
            cols, valid = {}, None
            for i, n in enumerate(names):
                cols[n] = jnp.asarray(
                    packref.unpack(flat[2 * i], bits[n]), jnp.int32)
                if n == key:
                    valid = packref.unpack_mask(flat[2 * i + 1], bits[n])
            sel = relational.eval_plan_codes(plan, cols) & valid
            keys3 = gops.lift_chunks([cols[key]])
            sel3 = gops.lift_chunks([sel.astype(jnp.int32)])
            out = {}
            for name in (aggs if aggs else ("",)):
                vals3 = gops.lift_chunks([cols[name]]) if name \
                    else jnp.zeros_like(keys3)
                out[name] = gops.group_sum_count_batched(
                    keys3, vals3, sel3, gk, mode=mode)
            return out

        return jax.jit(shard_map(
            per_shard, mesh=self.mesh,
            in_specs=(P(),) + (P(axis),) * (2 * len(names)),
            out_specs=P(axis), check_rep=False))

    def execute_grouped(self, query, mode=None) -> dict:
        """GroupBy/HashJoin across the mesh: per-shard dense accumulator
        planes all-gathered and merged in exact host ints. Group domains
        past the dense cutoff fall back to the host numpy path (counted
        as group_aggregate_fallback launches), still bit-exact."""
        from repro.kernels import dispatch
        from repro.query import relational
        relational.bind_check(query, self.table.columns)
        if self.num_rows == 0:
            return relational.empty_result()
        kmin, kmax = self.key_code_range(query.key)
        domain = relational.group_domain(query, kmin, kmax)
        if len(domain) == 0:
            return relational.empty_result()
        if not relational.dense_ok(domain):
            dispatch.count_launch("group_aggregate_fallback",
                                  self.n_shards)
            return relational.execute_grouped_oracle(query, self.table)
        planes = self.execute_grouped_planes(
            query.plan(), query.key, query.aggs, domain, mode=mode)
        first = query.aggs[0] if query.aggs else ""
        part = relational.new_partial()
        for name, stack in planes.items():
            for i in range(stack.shape[0]):
                relational.absorb_plane(part, domain, stack[i],
                                        name or None,
                                        count_source=(name == first))
        return relational.finalize(part)
