"""Physical query execution: kernel-dispatch operators over packed columns.

A logical Plan tree binds to a table's packed columns as `ColumnSlice`s
(words + validity mask + width) and executes bottom-up:

- every leaf Pred is a dispatch-routed scan (repro.kernels.scan_filter)
  whose mask is ANDed with the column's validity mask, so rows that exist
  only as padding — the pack()-to-a-word-multiple tail, or shard-alignment
  rows — can never match a predicate (the seed's scan counted tail-pad
  codes that happened to satisfy the predicate);
- AND/OR combine masks word-wise; when children live at different code
  widths the masks are repacked automatically (delimiter-bit layout of one
  width -> boolean rows -> delimiter layout of the other);
- each aggregate column reduces the selection through the dispatch-routed
  masked aggregate, and the dominant single-predicate/single-aggregate
  query takes the fused scan+aggregate kernel instead (no mask HBM
  round-trip);
- under `axis=...` (inside a shard_map) the four scalars combine across
  shards with psum/pmin/pmax — the only bytes that cross the interconnect.

Everything is traceable jnp/Pallas: the same function executes single-device
and per-shard inside repro.query.sharded's shard_map.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.scan_aggregate import ops as fused_ops
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter.ref import codes_per_word, unpack_mask
from repro.query.plan import And, Or, Plan, Pred, columns_of


@dataclass(frozen=True)
class ColumnSlice:
    """One column's packed words + validity mask, bound for execution.

    `valid` has a delimiter bit set exactly for rows < num_rows; all
    evaluation happens masked by it.
    """
    words: Any                  # (n_words,) uint32
    valid: Any                  # (n_words,) uint32 delimiter-bit mask
    code_bits: int


def table_slices(table) -> dict[str, ColumnSlice]:
    """Bind a repro.db Table's columns for single-device execution."""
    return {name: ColumnSlice(col.words, col.valid_words, col.code_bits)
            for name, col in table.columns.items()}


def jnp_pack_mask(sel, code_bits: int):
    """In-graph inverse of unpack_mask: boolean rows -> packed delimiter
    mask (rows padded to a word multiple with False)."""
    c = codes_per_word(code_bits)
    sel = jnp.pad(jnp.asarray(sel, bool), (0, (-sel.shape[0]) % c))
    sel = sel.reshape(-1, c)
    shifts = (jnp.arange(c, dtype=jnp.uint32) * code_bits + code_bits - 1)
    return jnp.bitwise_or.reduce(
        jnp.where(sel, jnp.uint32(1) << shifts[None, :], jnp.uint32(0)),
        axis=1)


def repack_mask(mask_words, from_bits: int, to_bits: int, to_words: int):
    """Repack a delimiter-bit mask from one code width to another.

    Row counts may differ by padding (each width pads to its own word
    multiple); rows beyond either count are padding and carry zero bits, so
    slicing/zero-extending is exact.
    """
    sel = unpack_mask(mask_words, from_bits)
    rows = to_words * codes_per_word(to_bits)
    if sel.shape[0] >= rows:
        sel = sel[:rows]
    else:
        sel = jnp.pad(sel, (0, rows - sel.shape[0]))
    return jnp_pack_mask(sel, to_bits)


def bind_check(plan: Plan, aggregates, columns: dict) -> None:
    """Validate a logical plan against table metadata; raises ValueError."""
    known = set(columns)
    missing = (columns_of(plan) | set(aggregates)) - known
    if missing:
        raise ValueError(f"unknown column(s) {sorted(missing)}; table has "
                         f"{sorted(known)}")

    def walk(node):
        if isinstance(node, Pred):
            bits = columns[node.column].code_bits
            vmax = (1 << (bits - 1)) - 1
            if node.constant > vmax:
                raise ValueError(
                    f"constant {node.constant} exceeds the {bits}-bit "
                    f"payload max {vmax} of column {node.column!r}")
        else:
            for c in node.children:
                walk(c)

    walk(plan)


def eval_mask(plan: Plan, slices: dict[str, ColumnSlice], mode=None):
    """Evaluate a predicate tree -> (packed mask, code_bits of its layout).

    The mask layout is the leftmost leaf's width; sibling masks at other
    widths are repacked to it before combining. Always validity-masked.
    """
    if isinstance(plan, Pred):
        s = slices[plan.column]
        m = scan_ops.scan_filter(s.words, plan.constant, plan.op,
                                 s.code_bits, mode=mode)
        return m & s.valid, s.code_bits
    if not isinstance(plan, (And, Or)):
        raise ValueError(f"unknown plan node {type(plan).__name__!r}")
    parts = [eval_mask(c, slices, mode) for c in plan.children]
    out, bits = parts[0]
    combine = jnp.bitwise_and if isinstance(plan, And) else jnp.bitwise_or
    for m, b in parts[1:]:
        if b != bits or m.shape != out.shape:
            m = repack_mask(m, b, bits, out.shape[0])
        out = combine(out, m)
    return out, bits


def _psum_aggs(d: dict, axis: str) -> dict:
    """Cross-shard combine: the masked-aggregate fields are associative.
    Sum planes are normalized (< 2^16 lo per shard), so the psum stays
    int32-exact; the planes are reassembled host-side by finalize_aggs."""
    return {"sum_lo": jax.lax.psum(d["sum_lo"], axis),
            "sum_hi": jax.lax.psum(d["sum_hi"], axis),
            "count": jax.lax.psum(d["count"], axis),
            "min": jax.lax.pmin(d["min"], axis),
            "max": jax.lax.pmax(d["max"], axis)}


def finalize_aggs(out: dict) -> dict:
    """{column: device aggregate dict} -> {column: exact host-int dict}
    with the 16-bit sum planes reassembled (the only step allowed to
    exceed int32, hence Python ints)."""
    return {col: agg_ops.finalize(d) for col, d in out.items()}


def referenced_bytes(plan: Plan, aggregates, columns: dict) -> int:
    """Bytes a query streams from memory — every referenced column's
    *physical* footprint (compressed for repro.store columns; the model's
    `percent accessed` numerator either way)."""
    return sum(columns[c].nbytes
               for c in columns_of(plan) | set(aggregates))


def referenced_logical_bytes(plan: Plan, aggregates, columns: dict) -> int:
    """Bytes the query covers in the plain format — equal to
    referenced_bytes on uncompressed tables; on a compressed store the
    physical/logical ratio is the bandwidth multiplier compression buys."""
    return sum(getattr(columns[c], "logical_nbytes", columns[c].nbytes)
               for c in columns_of(plan) | set(aggregates))


# --- chunk-granular accounting (repro.tier placement) ---------------------

def align_chunk_rows(columns: dict, chunk_rows: int) -> int:
    """Round `chunk_rows` up so a row-range boundary is a word boundary
    for every column (multiple of each width's codes-per-word). The one
    alignment invariant shared by tier chunking and shard splitting
    (ShardedTable.shard sizes rows_per_shard through this)."""
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows={chunk_rows} must be >= 1")
    align = math.lcm(*(32 // c.code_bits for c in columns.values()))
    return -(-chunk_rows // align) * align


def column_chunk_bytes(total_words: int, code_bits: int,
                       chunk_rows: int) -> list[int]:
    """Packed bytes per row-chunk of one column (last chunk ragged).
    `chunk_rows` must already be word-aligned for this width."""
    wpc = chunk_rows * code_bits // 32
    return [4 * (min((i + 1) * wpc, total_words) - i * wpc)
            for i in range(-(-total_words // wpc))]


def chunk_universe(source: dict, chunk_rows: int,
                   names=None) -> dict[tuple[str, int], int]:
    """(column, chunk-index) -> bytes over `source` columns (objects with
    `.words`/`.code_bits` — table columns or sharded slices). The single
    enumeration shared by the placement universe, flat-table accounting,
    and sharded accounting, so chunk-id semantics cannot diverge.
    `chunk_rows` must already be aligned (align_chunk_rows)."""
    out: dict[tuple[str, int], int] = {}
    for name in (sorted(names) if names is not None else source):
        col = source[name]
        if hasattr(col, "chunk_physical_bytes"):
            # repro.store encoded columns carry their own per-chunk
            # (compressed) byte counts; chunk ids stay row-range-aligned
            per_chunk = col.chunk_physical_bytes(chunk_rows)
        else:
            per_chunk = column_chunk_bytes(int(col.words.size),
                                           col.code_bits, chunk_rows)
        for i, b in enumerate(per_chunk):
            out[(name, i)] = b
    return out


def referenced_chunk_bytes(plan: Plan, aggregates, columns: dict,
                           chunk_rows: int) -> dict[tuple[str, int], int]:
    """Per-(column, chunk) bytes a query streams — the access record the
    tier placement engine charges. Scans stream every chunk of every
    referenced column; the split across tiers is the placement engine's
    decision, the byte totals are this layer's ground truth."""
    return chunk_universe(columns, align_chunk_rows(columns, chunk_rows),
                          names=columns_of(plan) | set(aggregates))


def execute(plan: Plan, aggregates: tuple, slices: dict[str, ColumnSlice],
            mode=None, axis: str | None = None) -> dict:
    """Run a bound plan -> {agg_column: {sum, count, min, max}}.

    Traceable: called directly for single-device tables and per-shard
    inside shard_map (axis names the mesh axis to combine over).
    """
    out: dict[str, dict] = {}
    fused = (isinstance(plan, Pred) and len(aggregates) == 1
             and slices[plan.column].code_bits
             == slices[aggregates[0]].code_bits
             and slices[plan.column].words.shape
             == slices[aggregates[0]].words.shape)
    if fused:
        p, a = slices[plan.column], slices[aggregates[0]]
        out[aggregates[0]] = fused_ops.scan_aggregate(
            p.words, a.words, p.valid, plan.constant, plan.op, p.code_bits,
            mode=mode)
    else:
        mask, mbits = eval_mask(plan, slices, mode)
        for col in aggregates:
            s = slices[col]
            m = mask
            if s.code_bits != mbits or m.shape != s.words.shape:
                m = repack_mask(m, mbits, s.code_bits, s.words.shape[0])
            out[col] = agg_ops.aggregate(s.words, m, s.code_bits, mode=mode)
    if axis is not None:
        out = {col: _psum_aggs(d, axis) for col, d in out.items()}
    return out
