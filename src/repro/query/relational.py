"""Relational operators: grouped aggregation & hash join execution.

The compile target for `plan.GroupBy` / `plan.HashJoin`: this module owns
the pieces every execution surface (plain tables here, the compressed
store in store/exec.py, the mesh in query/sharded.py, degraded re-runs in
resilience/recover.py) shares —

- bind/validation with actionable errors (unknown column, aggregate over
  the key, join-key width mismatch naming both columns and widths),
- the group-domain choice: a dense arange when the observed/FOR-framed
  key span stays under `DENSE_MAX_GROUPS`, the sorted distinct build
  keys for a join, or the host sort/hash fallback above the cutoff,
- predicate-tree evaluation over int32 code planes (numpy or jnp — the
  unpacked analogue of physical.eval_mask),
- the host-partial algebra: per-chunk/per-shard `(G, 3)` accumulator
  planes become exact Python-int partial dicts (FOR base fix-up applied
  per plane), merged associatively and finalized into
  `{"groups": {key: {"count", "sums"}}, "count": total}`.

Every path — PALLAS kernel, XLA_REF oracle, numpy fallback, sharded
all-gather — lands in the same partial algebra, which is how bit-exact
parity across all four is kept a structural property instead of a test
hope.
"""
from __future__ import annotations

import operator

import jax.numpy as jnp
import numpy as np

from repro.kernels.group_aggregate import ops as gops
from repro.kernels.group_aggregate.ops import DENSE_MAX_GROUPS
from repro.kernels.scan_filter import ref as packref
from repro.query import physical
from repro.query.plan import And, GroupBy, HashJoin, Or, Pred, is_grouped

_OPS = {"lt": operator.lt, "le": operator.le, "gt": operator.gt,
        "ge": operator.ge, "eq": operator.eq, "ne": operator.ne}


# --------------------------------------------------------------------------
# bind / validation
# --------------------------------------------------------------------------

def bind_check(query, columns) -> None:
    """Validate a GroupBy/HashJoin against a table's columns before any
    work: unknown columns (key, aggregates, plan) and join-key width
    mismatches raise actionable ValueErrors."""
    physical.bind_check(query.plan(), query.aggregates, columns)
    if isinstance(query, HashJoin):
        probe_bits = columns[query.probe].code_bits
        build_bits = query.build.columns[query.on].code_bits
        if probe_bits != build_bits:
            raise ValueError(
                f"HashJoin key width mismatch: probe column "
                f"{query.probe!r} is {probe_bits}-bit but build column "
                f"{query.on!r} is {build_bits}-bit; join keys compare "
                f"dictionary codes, so both sides must share one code "
                f"width — re-encode the narrower side")


def build_keys(join: HashJoin) -> np.ndarray:
    """Sorted distinct dictionary codes of the build side's join column —
    the hash table this join broadcasts (a sorted array: membership and
    group slots resolve by binary search, not scatter)."""
    col = join.build.columns[join.on]
    codes = np.asarray(packref.unpack(col.words, col.code_bits))
    codes = codes[:col.num_rows]
    return np.unique(codes).astype(np.int64)


def group_domain(query, kmin: int, kmax: int) -> np.ndarray:
    """Candidate group keys given the observed (or FOR-framed) key code
    range [kmin, kmax] — dense arange for GroupBy, the build side's
    distinct keys (clipped to the observable range) for HashJoin."""
    if isinstance(query, HashJoin):
        bk = build_keys(query)
        return bk[(bk >= kmin) & (bk <= kmax)]
    if kmax < kmin:                      # zero-row table
        return np.zeros(0, np.int64)
    return np.arange(kmin, kmax + 1, dtype=np.int64)


def dense_ok(domain: np.ndarray) -> bool:
    return len(domain) <= DENSE_MAX_GROUPS


# --------------------------------------------------------------------------
# predicate trees over code planes
# --------------------------------------------------------------------------

def eval_plan_codes(plan, cols: dict):
    """Evaluate a Pred/And/Or tree over unpacked int32 code arrays
    (numpy in, numpy out; jnp in, jnp out) -> boolean selection."""
    if isinstance(plan, Pred):
        return _OPS[plan.op](cols[plan.column], plan.constant)
    parts = [eval_plan_codes(c, cols) for c in plan.children]
    out = parts[0]
    for p in parts[1:]:
        out = (out & p) if isinstance(plan, And) else (out | p)
    return out


def key_only_pred(query, code_bits: int):
    """If the query's plan is a single Pred on the group key (the
    tautology included), return its canonical (prim, const, invert)
    triple — what the fused RLE kernel evaluates on run values in
    registers; return False for any other plan shape."""
    from repro.kernels.scan_filter.ops import canonical_pred
    plan = query.plan()
    if not isinstance(plan, Pred) or plan.column != query.key:
        return False
    return canonical_pred(plan.op, plan.constant, code_bits)


# --------------------------------------------------------------------------
# host-partial algebra (exact Python ints)
# --------------------------------------------------------------------------

def new_partial() -> dict:
    return {}


def absorb_plane(partial: dict, domain, plane, col: str | None,
                 base: int = 0, key_base: int = 0,
                 count_source: bool = False) -> dict:
    """Fold one (G, 3) accumulator plane into a host partial.

    domain: the plane's group keys (kernel domain); key_base shifts them
    back to logical codes (FOR delta keys), base is the value column's
    FOR base fix-up (sum += base * count, exact). Counts are added only
    when count_source (one plane per chunk carries them — every value
    column's launch returns identical counts)."""
    keys, sums, counts = gops.finalize_grouped(domain, plane, base)
    for k, s, c in zip(keys, sums, counts):
        if c == 0:
            continue
        entry = partial.setdefault(int(k) + key_base, [0, {}])
        if count_source:
            entry[0] += int(c)
        if col is not None:
            entry[1][col] = entry[1].get(col, 0) + int(s)
    return partial


def absorb_fallback(partial: dict, key_codes, val_cols: dict,
                    sel) -> dict:
    """The sort/hash strategy: numpy bincount/add.at over one chunk's
    decoded codes — exact in int64, no kernel launch."""
    k = np.asarray(key_codes)[np.asarray(sel)]
    if k.size == 0:
        return partial
    uniq, inv = np.unique(k, return_inverse=True)
    counts = np.bincount(inv, minlength=len(uniq)).astype(np.int64)
    sums = {}
    for name, v in val_cols.items():
        acc = np.zeros(len(uniq), np.int64)
        np.add.at(acc, inv, np.asarray(v, np.int64)[np.asarray(sel)])
        sums[name] = acc
    for i, key in enumerate(uniq):
        entry = partial.setdefault(int(key), [0, {}])
        entry[0] += int(counts[i])
        for name in val_cols:
            entry[1][name] = entry[1].get(name, 0) + int(sums[name][i])
    return partial


def combine(a: dict, b: dict) -> dict:
    """Merge two host partials (associative, commutative, exact)."""
    for k, (c, sums) in b.items():
        entry = a.setdefault(k, [0, {}])
        entry[0] += c
        for name, s in sums.items():
            entry[1][name] = entry[1].get(name, 0) + s
    return a


def restrict(partial: dict, keys) -> dict:
    """Keep only groups whose key is in `keys` (join semantics when a
    fallback chunk grouped every key it saw)."""
    allowed = set(int(k) for k in keys)
    return {k: v for k, v in partial.items() if k in allowed}


def finalize(partial: dict) -> dict:
    """Host partial -> the engine's grouped result: groups sorted by key,
    zero-count groups dropped, `count` the total selected rows."""
    groups = {}
    total = 0
    for k in sorted(partial):
        c, sums = partial[k]
        if c == 0:
            continue
        groups[k] = {"count": c, "sums": dict(sorted(sums.items()))}
        total += c
    return {"groups": groups, "count": total}


def empty_result() -> dict:
    return {"groups": {}, "count": 0}


# --------------------------------------------------------------------------
# plain-table execution (the numpy-backed BitPackedColumn path)
# --------------------------------------------------------------------------

def _codes(col) -> np.ndarray:
    vals = np.asarray(packref.unpack(col.words, col.code_bits))
    return vals[: col.num_rows].astype(np.int64)


def execute_grouped_oracle(query, table) -> dict:
    """The numpy oracle: decode, select, group with add.at — the ground
    truth every kernel/sharded/degraded path must match bit-exactly."""
    bind_check(query, table.columns)
    cols = {n: _codes(c) for n, c in table.columns.items()
            if n in set(query.aggregates) | physical.columns_of(
                query.plan())}
    n = table.num_rows
    sel = np.asarray(eval_plan_codes(query.plan(), cols)) \
        if n else np.zeros(0, bool)
    if isinstance(query, HashJoin):
        bk = build_keys(query)
        sel = sel & np.isin(cols[query.key], bk)
    part = absorb_fallback(new_partial(), cols[query.key],
                           {a: cols[a] for a in query.aggs}, sel)
    return finalize(part)


def execute_grouped(query, table, mode=None) -> dict:
    """GroupBy/HashJoin over a plain bit-packed table through the
    group_aggregate kernel family (dense strategy; host fallback above
    the dense cutoff). Returns the finalized grouped result."""
    bind_check(query, table.columns)
    n = table.num_rows
    if n == 0:
        return empty_result()
    need = set(query.aggregates) | physical.columns_of(query.plan())
    # columns of different widths unpack to different padded lengths;
    # truncating to the logical rows puts every plane on one row axis
    planes = {name: jnp.asarray(packref.unpack(
        table.columns[name].words, table.columns[name].code_bits),
        jnp.int32)[:n] for name in need}
    sel = eval_plan_codes(query.plan(), planes)
    kmin, kmax = (int(jnp.min(planes[query.key])),
                  int(jnp.max(planes[query.key])))
    domain = group_domain(query, kmin, kmax)
    part = new_partial()
    if not dense_ok(domain):
        from repro.kernels import dispatch
        dispatch.count_launch("group_aggregate_fallback")
        cols = {name: np.asarray(p)[:n] for name, p in planes.items()}
        sel_np = np.asarray(sel)[:n]
        if isinstance(query, HashJoin):
            sel_np = sel_np & np.isin(cols[query.key], build_keys(query))
        absorb_fallback(part, cols[query.key],
                        {a: cols[a] for a in query.aggs}, sel_np)
        if isinstance(query, HashJoin):
            part = restrict(part, build_keys(query))
        return finalize(part)
    if len(domain) == 0:
        return empty_result()
    sel_i = sel.astype(jnp.int32)
    value_cols = query.aggs if query.aggs else (None,)
    for i, name in enumerate(value_cols):
        vals = planes[name] if name is not None \
            else jnp.zeros_like(planes[query.key])
        plane = gops.group_sum_count(planes[query.key], vals, sel_i,
                                     domain, mode=mode)
        absorb_plane(part, domain, np.asarray(plane), name,
                     count_source=(i == 0))
    return finalize(part)
