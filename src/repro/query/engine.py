"""SLA-aware query engine: EDF admission, dispatch execution, model feedback.

The runtime embodiment of the paper's serving story for analytic scans:

- queries carry deadlines and are admitted/ordered by the shared EDF
  machinery (repro.serve.sla, also used by LM serving) with service-time
  estimates of bytes_scanned / measured scan rate;
- execution routes every operator through repro.kernels.dispatch (fused
  scan+aggregate where the shape allows, sharded with a psum combine when
  the table lives on a mesh);
- every query's bytes_scanned and attained wall-clock latency are recorded,
  so the engine can compare measured scan throughput against the
  `core_perf` roofline the provisioning regimes assume (model_check) and
  re-provision from *attained* rather than datasheet throughput
  (provision) — the loop between repro.core's analytical model and the
  executable system.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

from repro.kernels.dispatch import KernelMode
from repro.query import physical
from repro.query.plan import Query
from repro.query.sharded import ShardedTable
from repro.serve.sla import DeadlineQueue, SLAReport, summarize


@dataclass
class _Pending:
    qid: int
    query: Query
    bytes_scanned: int
    submitted_at: float


@dataclass
class QueryResult:
    qid: int
    query: Query
    aggregates: dict[str, dict]     # column -> {sum, count, min, max} ints
    count: int
    selectivity: float
    bytes_scanned: int
    latency_s: float
    deadline: float
    met: bool


class QueryEngine:
    """Deadline-batched scan/aggregate execution over a (sharded) table.

    est_gbps seeds the admission controller's service-time estimate; it is
    replaced by the measured cumulative scan rate as soon as one query has
    executed, so feasibility decisions track attained (not assumed)
    throughput.
    """

    def __init__(self, table, *, mode=KernelMode.AUTO,
                 clock=time.perf_counter, est_gbps: float = 1.0):
        self.table = table
        self.mode = KernelMode(mode)
        self.clock = clock
        self.queue = DeadlineQueue(clock, self._est_service_s)
        self.reports: list[SLAReport] = []
        self.results: list[QueryResult] = []
        self._qid = 0
        self._est_gbps = float(est_gbps)
        self.bytes_total = 0.0
        self.seconds_total = 0.0

    # --- structure --------------------------------------------------------
    @property
    def sharded(self) -> bool:
        return isinstance(self.table, ShardedTable)

    @property
    def n_shards(self) -> int:
        return self.table.n_shards if self.sharded else 1

    @property
    def num_rows(self) -> int:
        return self.table.num_rows

    def bytes_scanned(self, query: Query) -> int:
        return physical.referenced_bytes(query.plan(), query.aggregates,
                                         self.table.columns)

    # --- admission --------------------------------------------------------
    @property
    def measured_bps(self) -> float:
        if self.seconds_total > 0:
            return self.bytes_total / self.seconds_total
        return self._est_gbps * 1e9

    def _est_service_s(self, p: _Pending) -> float:
        return p.bytes_scanned / max(self.measured_bps, 1e-9)

    @property
    def rejected(self) -> list[int]:
        return [p.qid for p in self.queue.rejected]

    def submit(self, query: Query, deadline: float = math.inf) -> int | None:
        """Admit a query under a deadline (absolute clock time). Returns
        the query id, or None if the deadline is already infeasible.
        Malformed queries raise ValueError."""
        physical.bind_check(query.plan(), query.aggregates,
                            self.table.columns)
        self._qid += 1
        pend = _Pending(self._qid, query, self.bytes_scanned(query),
                        self.clock())
        return pend.qid if self.queue.push(pend, deadline) else None

    # --- execution --------------------------------------------------------
    def _execute(self, query: Query) -> dict:
        """Exact host-int aggregates, whichever path executes."""
        if self.sharded:
            return self.table.execute(query.plan(), query.aggregates,
                                      mode=self.mode)
        return physical.finalize_aggs(physical.execute(
            query.plan(), query.aggregates,
            physical.table_slices(self.table), mode=self.mode))

    def run(self) -> list[QueryResult]:
        """Drain the queue in deadline order; returns this batch's results."""
        batch: list[QueryResult] = []
        while True:
            got = self.queue.pop()        # sheds now-hopeless queries
            if got is None:
                break
            pend, deadline = got
            t0 = self.clock()
            # finalize inside _execute forces the device sync, so t1 - t0
            # covers the full scan
            aggs = self._execute(pend.query)
            t1 = self.clock()
            self.bytes_total += pend.bytes_scanned
            self.seconds_total += max(t1 - t0, 1e-12)
            count = next(iter(aggs.values()))["count"]
            res = QueryResult(
                qid=pend.qid, query=pend.query, aggregates=aggs,
                count=count,
                selectivity=count / max(self.num_rows, 1),
                bytes_scanned=pend.bytes_scanned,
                latency_s=t1 - pend.submitted_at,
                deadline=deadline, met=t1 <= deadline)
            self.reports.append(SLAReport(
                rid=pend.qid, deadline=deadline,
                submitted_at=pend.submitted_at, finished_at=t1,
                work=pend.bytes_scanned))
            self.results.append(res)
            batch.append(res)
        return batch

    # --- reporting / model feedback --------------------------------------
    def summary(self) -> dict:
        out = summarize(self.reports, rejected=len(self.queue.rejected))
        out["bytes_scanned"] = self.bytes_total
        out["measured_gbps"] = (self.bytes_total / self.seconds_total / 1e9
                                if self.seconds_total > 0 else 0.0)
        return out

    def model_check(self, system=None) -> dict:
        """Measured scan throughput vs the analytical model's Eq. 4 roofline
        (chips = shards): the number the provisioning regimes assume each
        chip sustains, checked against what the kernels attained."""
        from repro.core.systems import TPU_V5E, as_paper_system
        sys_ = system or as_paper_system(TPU_V5E)
        model_bps = sys_.chip_peak_perf * self.n_shards
        measured = (self.bytes_total / self.seconds_total
                    if self.seconds_total > 0 else 0.0)
        return {
            "system": sys_.name,
            "chips": self.n_shards,
            "measured_gbps": measured / 1e9,
            "model_gbps": model_bps / 1e9,
            "attained_fraction": measured / model_bps,
        }

    def provision(self, sla_s: float, system=None):
        """The paper's performance-provisioning question answered from this
        engine's *measured* workload: how many chips to meet `sla_s` per
        query, with core_perf calibrated to attained throughput."""
        from repro.core import advisor
        if not self.reports or self.seconds_total <= 0:
            raise ValueError(
                "no measured queries to provision from; submit() and run() "
                "at least one query first")
        return advisor.advise_scan_sla(
            db_bytes=self.table.nbytes,
            bytes_per_query=self.bytes_total / len(self.reports),
            sla_s=sla_s, system=system,
            measured_chip_bps=(self.bytes_total / self.seconds_total
                               / self.n_shards))
