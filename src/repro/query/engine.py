"""SLA-aware query engine: EDF admission, dispatch execution, model feedback.

The runtime embodiment of the paper's serving story for analytic scans:

- queries carry deadlines and are admitted/ordered by the shared EDF
  machinery (repro.serve.sla, also used by LM serving) with service-time
  estimates of bytes_scanned / measured scan rate;
- execution routes every operator through repro.kernels.dispatch (fused
  scan+aggregate where the shape allows, sharded with a psum combine when
  the table lives on a mesh);
- every query's bytes_scanned and attained wall-clock latency are recorded,
  so the engine can compare measured scan throughput against the
  `core_perf` roofline the provisioning regimes assume (model_check) and
  re-provision from *attained* rather than datasheet throughput
  (provision) — the loop between repro.core's analytical model and the
  executable system;
- with `tiered=` a repro.tier.PlacementEngine, the table is treated as
  split across a fast (die-stacked) and a capacity (DDR) tier: every
  query's per-chunk bytes are reported to the placement engine, latency is
  charged per chunk at its tier's rate (the tiered latency model), and
  admission feasibility uses the blended rate. Placement never changes
  answers — execution is identical; only the time/energy accounting moves.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

from repro.kernels.dispatch import KernelMode
from repro.obs import metrics as obs_metrics
from repro.obs.trace import NullTracer, layout_pipeline, layout_sync
from repro.query import physical
from repro.query.plan import HashJoin, Query, is_grouped
from repro.serve.sla import DeadlineQueue, SLAReport, summarize


@dataclass
class _Pending:
    qid: int
    query: Query
    bytes_scanned: int              # physical (compressed) bytes
    submitted_at: float
    chunks: dict | None = None      # tiered mode: per-chunk byte counts
    tenant: int = 0                 # energy-ledger attribution
    logical_bytes: int = 0          # plain-format bytes the query covers


@dataclass
class QueryResult:
    qid: int
    query: Query
    aggregates: dict[str, dict]     # column -> {sum, count, min, max} ints
    count: int
    selectivity: float
    bytes_scanned: int
    latency_s: float
    deadline: float
    met: bool
    tier: dict | None = None        # tiered mode: byte split + modeled s
    logical_bytes: int = 0          # == bytes_scanned unless compressed
    degraded: bool = False          # chaos: no exact answer was produced
    error: str | None = None        # the typed degradation, when degraded


class QueryEngine:
    """Deadline-batched scan/aggregate execution over a (sharded) table.

    est_gbps seeds the admission controller's service-time estimate; it is
    replaced by the measured cumulative scan rate as soon as one query has
    executed, so feasibility decisions track attained (not assumed)
    throughput.

    tiered: a repro.tier.PlacementEngine built over this table. Queries
    still execute (and answer) exactly as in flat mode, but service time
    is *modeled* — each referenced chunk charged at the rate of the tier
    it resides in — and seconds_total accumulates modeled service, so
    measured_bps (and with it admission feasibility) becomes the blended
    tier rate. Tiered mode requires an advanceable clock (e.g.
    serve.sla.VirtualClock) so deadlines live on the same modeled time
    axis the service charges advance.
    """

    def __init__(self, table, *, mode=KernelMode.AUTO,
                 clock=time.perf_counter, est_gbps: float = 1.0,
                 tiered=None, power_cap=None, chaos=None, prefetch=None,
                 tracer=None, metrics=None, monitor=None):
        self.table = table
        self.mode = KernelMode(mode)
        self.tiered = tiered
        self.power_cap = power_cap
        self.chaos = chaos
        self.prefetch = prefetch
        # per-engine metrics scope: execution runs inside scoped(metrics),
        # so launch counts here are this engine's alone while the default
        # (process-global) scope keeps accumulating for the legacy shims
        self.metrics = (metrics if metrics is not None
                        else obs_metrics.MetricsRegistry("engine"))
        self.tracer = tracer if tracer is not None else NullTracer()
        if getattr(self.tracer, "enabled", True) and tracer is not None \
                and tiered is None:
            # spans are stamped in *modeled* time; a flat engine only has
            # the wall clock, which would make traces nondeterministic
            raise ValueError(
                "tracer= records the modeled tiered timeline; pass "
                "tiered=repro.tier.PlacementEngine(...) as well")
        if prefetch is not None:
            if tiered is None:
                # the pipeline overlaps *modeled* tier reads; without the
                # tier model there is nothing to overlap
                raise ValueError(
                    "prefetch needs the tiered service model; pass "
                    "tiered=repro.tier.PlacementEngine(...) as well")
            if prefetch.pe is not tiered:
                raise ValueError(
                    "prefetch pipeline was built over a different "
                    "PlacementEngine than this engine's tiered=")
        if chaos is not None:
            if tiered is None:
                # faults are modeled service/byte penalties on the tier
                # ledger; without tiering there is nothing to charge them to
                raise ValueError(
                    "chaos needs the tiered service model; pass "
                    "tiered=repro.tier.PlacementEngine(...) as well")
            if chaos.guard is not None and chaos.guard.table is not table:
                raise ValueError(
                    "chaos.guard was built over a different table than "
                    "this engine executes; its oracle cannot repair these "
                    "chunks")
        if tiered is not None and not hasattr(clock, "advance"):
            # modeled service needs a modeled time axis: pricing admission
            # at tier rates while deadlines tick on the wall clock would
            # compare incommensurate quantities
            raise ValueError(
                "tiered mode models service time, so deadlines must live "
                "on an advanceable clock; pass "
                "clock=repro.serve.sla.VirtualClock()")
        if power_cap is not None and tiered is None:
            # the governor throttles *modeled* service and prices queries
            # from the placement engine's energy meter; without tiering
            # there is neither a joules ledger nor a rate to derate
            raise ValueError(
                "power_cap needs the tiered energy model; pass "
                "tiered=repro.tier.PlacementEngine(...) as well")
        self.monitor = monitor
        if monitor is not None:
            # bind() enforces tiered mode: the monitor's ticks and burn
            # windows live on the modeled clock, like the tracer's spans
            monitor.bind(self)
        self.clock = clock
        self.queue = DeadlineQueue(clock, self._est_service_s)
        self.reports: list[SLAReport] = []
        self.results: list[QueryResult] = []
        self._qid = 0
        self._est_gbps = float(est_gbps)
        self.bytes_total = 0.0          # physical (compressed) bytes
        self.logical_bytes_total = 0.0  # plain-format coverage
        self.seconds_total = 0.0

    # --- structure --------------------------------------------------------
    @property
    def sharded(self) -> bool:
        # ShardedTable or the compressed store's delta view — anything
        # that executes per-shard and reports a shard count
        return hasattr(self.table, "n_shards")

    @property
    def n_shards(self) -> int:
        return self.table.n_shards if self.sharded else 1

    @property
    def num_rows(self) -> int:
        return self.table.num_rows

    def bytes_scanned(self, query: Query) -> int:
        """Physical bytes the query streams (compressed for a
        repro.store table — what actually crosses the memory bus)."""
        return physical.referenced_bytes(query.plan(), query.aggregates,
                                         self.table.columns)

    def logical_bytes(self, query: Query) -> int:
        """Plain-format bytes the query covers; the physical/logical gap
        is the effective-bandwidth multiplier compression buys."""
        return physical.referenced_logical_bytes(
            query.plan(), query.aggregates, self.table.columns)

    def chunk_accesses(self, query: Query) -> dict:
        """Per-(column, chunk) bytes this query streams, in the tiered
        placement engine's chunking (sharded tables report device-resident
        bytes, padding included)."""
        if self.tiered is None:
            raise ValueError("chunk accounting needs tiered=PlacementEngine")
        cr = self.tiered.chunk_rows
        if self.sharded:
            return self.table.chunk_bytes(query.plan(), query.aggregates,
                                          cr)
        return physical.referenced_chunk_bytes(
            query.plan(), query.aggregates, self.table.columns, cr)

    # --- admission --------------------------------------------------------
    @property
    def measured_bps(self) -> float:
        if self.tiered is not None:
            # blended tier rate at the measured (or resident) hit fraction
            return self.tiered.blended_measured_bps(self.n_shards)
        if self.seconds_total > 0:
            return self.bytes_total / self.seconds_total
        return self._est_gbps * 1e9

    def _projected_energy_j(self, p: _Pending, busy_s: float) -> float:
        """Admission-time joules estimate: memory term from the *current*
        residency (PlacementEngine.project — no state touched), compute
        term at the meter's chip power over the modeled busy time."""
        split = self.tiered.project(p.chunks)
        meter = self.tiered.meter
        return (meter.tiers.energy_j(split.fast_bytes, split.capacity_bytes)
                + meter.compute_w * self.n_shards * busy_s)

    def _est_service_s(self, p: _Pending) -> float:
        if self.prefetch is not None and p.chunks is not None:
            # admission prices the pipelined read, not the sync sum —
            # plan() is pure, so estimating cannot move placement state
            est = self.prefetch.plan(p.chunks,
                                     chips=self.n_shards).service_s
        else:
            est = p.bytes_scanned / max(self.measured_bps, 1e-9)
        if self.chaos is not None:
            # price expected recovery overhead at admission: a query the
            # fault rate would push past its deadline is rejected here
            est = self.chaos.inflate_estimate(
                est, len(p.chunks) if p.chunks else 1)
        if self.power_cap is not None:
            # feasibility must be priced at the power-derated rate: a
            # query the governor would stretch past its deadline is
            # rejected here instead of silently running over budget
            est = self.power_cap.throttled_service_s(
                self.clock(), self._projected_energy_j(p, est), est)
        return est

    @property
    def rejected(self) -> list[int]:
        return [p.qid for p in self.queue.rejected]

    def submit(self, query: Query, deadline: float = math.inf,
               tenant: int = 0) -> int | None:
        """Admit a query under a deadline (absolute clock time). Returns
        the query id, or None if the deadline is already infeasible.
        Malformed queries raise ValueError.

        In tiered mode the admission estimate, bytes_total, and the
        service charge all use the placement engine's chunk accounting
        (device-resident bytes, shard padding included) — one byte basis,
        so an admitted estimate and the charged service can't diverge.
        `tenant` tags the query's line on the energy meter."""
        if is_grouped(query):
            # the relational bind adds the join-key width check on top of
            # the column checks
            from repro.query import relational
            relational.bind_check(query, self.table.columns)
        else:
            physical.bind_check(query.plan(), query.aggregates,
                                self.table.columns)
        self._qid += 1
        chunks = (self.chunk_accesses(query) if self.tiered is not None
                  else None)
        nbytes = (sum(chunks.values()) if chunks is not None
                  else self.bytes_scanned(query))
        pend = _Pending(self._qid, query, nbytes, self.clock(),
                        chunks=chunks, tenant=tenant,
                        logical_bytes=self.logical_bytes(query))
        if self.queue.push(pend, deadline):
            return pend.qid
        if self.monitor is not None:
            self.monitor.observe_rejected(tenant=tenant)
        return None

    # --- execution --------------------------------------------------------
    def _execute(self, query: Query) -> dict:
        """Exact host-int aggregates (or the grouped result dict for
        GroupBy/HashJoin), whichever path executes."""
        if is_grouped(query):
            if self.sharded:
                return self.table.execute_grouped(query, mode=self.mode)
            if hasattr(self.table, "chunk_rows"):    # repro.store table
                from repro.store.exec import execute_grouped_encoded
                guard = (self.chaos.guard if self.chaos is not None
                         else None)
                return execute_grouped_encoded(query, self.table,
                                               mode=self.mode, guard=guard)
            from repro.query import relational
            return relational.execute_grouped(query, self.table,
                                              mode=self.mode)
        if self.sharded:
            return self.table.execute(query.plan(), query.aggregates,
                                      mode=self.mode)
        if hasattr(self.table, "chunk_rows"):        # repro.store table
            from repro.store.exec import execute_encoded
            guard = self.chaos.guard if self.chaos is not None else None
            return execute_encoded(query.plan(), query.aggregates,
                                   self.table, mode=self.mode, guard=guard)
        return physical.finalize_aggs(physical.execute(
            query.plan(), query.aggregates,
            physical.table_slices(self.table), mode=self.mode))

    def run(self) -> list[QueryResult]:
        """Drain the queue in deadline order; returns this batch's results.

        Each query executes inside this engine's metrics scope, so kernel
        launch counts attribute to the engine (and, via the trace's launch
        spans, to the query) without touching the process-global shims."""
        batch: list[QueryResult] = []
        while True:
            n_rej = len(self.queue.rejected)
            got = self.queue.pop()        # sheds now-hopeless queries
            if self.monitor is not None:
                # each shed query broke its promise without being served
                for p in self.queue.rejected[n_rej:]:
                    self.monitor.observe_rejected(tenant=p.tenant)
            if got is None:
                break
            pend, deadline = got
            with obs_metrics.scoped(self.metrics):
                batch.append(self._serve_one(pend, deadline))
        return batch

    def _emit_launches(self, qt, before: dict, ts: float) -> None:
        """Turn this query's per-engine counter deltas into launch spans:
        one per kernel family (attrs: family, n) and one per batched
        width group (attrs: family, width, n, n_chunks)."""
        for key in sorted(self.metrics.counters):
            d = self.metrics.counters[key].value - before.get(key, 0)
            if d <= 0:
                continue
            if key.startswith("launches/"):
                qt.add("launch", t0=ts, family=key[len("launches/"):],
                       n=d)
            elif key.startswith("batch/"):
                _, family, w = key.split("/", 2)
                covered = (self.metrics.counters[
                    f"batch_chunks/{family}/{w}"].value
                    - before.get(f"batch_chunks/{family}/{w}", 0))
                qt.add("launch_batch", t0=ts, family=family,
                       width=int(w[1:]), n=d, n_chunks=covered)

    def _serve_one(self, pend: _Pending, deadline: float) -> QueryResult:
        t0 = self.clock()
        shape = ("join" if isinstance(pend.query, HashJoin)
                 else "grouped" if is_grouped(pend.query) else "scan")
        qt = self.tracer.begin_query(
            pend.qid, tenant=pend.tenant, submitted_at=pend.submitted_at,
            deadline=deadline, bytes_expected=pend.bytes_scanned,
            shape=shape)
        trace = qt if qt.enabled else None
        if trace is not None:
            qt.begin_run(t0)
        launches0 = ({k: c.value
                      for k, c in self.metrics.counters.items()}
                     if trace is not None else None)
        error = None
        tier_info = None
        if self.tiered is not None:
            # charge the modeled tiered service time instead of wall
            # time: each chunk at the rate of the tier it lived in
            if self.chaos is not None:
                # the harness owns the fault-injected path: breaker
                # gating, verify-on-read, degraded failover, and the
                # stall/retry extras folded into busy/joules — and the
                # recovery span tree when tracing
                aggs, acc, busy, query_j, error = \
                    self.chaos.run_query(self, pend, t0, trace=trace)
            else:
                # prefetch plans against residency *before* on_access
                # mutates it — the same residency the charge uses
                pplan = None
                if self.prefetch is not None:
                    pplan = self.prefetch.plan(pend.chunks,
                                               chips=self.n_shards)
                    self.prefetch.begin(pplan, pend.chunks)
                aggs = self._execute(pend.query)
                acc = self.tiered.on_access(pend.chunks, qid=pend.qid,
                                            tenant=pend.tenant,
                                            trace=trace)
                busy = (pplan.service_s if pplan is not None
                        else self.tiered.service_s(acc, self.n_shards))
                self.tiered.meter.charge_compute(acc.charge, busy,
                                                 self.n_shards)
                query_j = acc.charge.total_j
                if trace is not None:
                    if pplan is not None:
                        layout_pipeline(trace, t0, pplan,
                                        self.tiered.tiers, self.n_shards)
                    else:
                        layout_sync(trace, t0, self.tiered.tiers,
                                    self.n_shards)
                    trace.compute(t0, busy, self.n_shards,
                                  self.tiered.meter.compute_w
                                  * self.n_shards * busy)
                if pplan is not None:
                    line = self.prefetch.finish(pplan, qid=pend.qid,
                                                tenant=pend.tenant)
                    if line is not None:
                        query_j += line.total_j
            service = busy
            if self.power_cap is not None:
                # race-to-idle throttling: the governor stretches wall
                # time until no watt window exceeds budget; joules are
                # fixed at the busy-time charge, the chip idles the rest
                service = self.power_cap.throttled_service_s(
                    t0, query_j, busy)
                self.power_cap.record(t0, t0 + service, query_j,
                                      natural_s=busy)
                if trace is not None and service > busy:
                    qt.add("throttle", t0=t0 + busy,
                           dur_s=service - busy)
            t1 = self.clock.advance(service)
            self.seconds_total += service
            tier_info = {"fast_bytes": acc.fast_bytes,
                         "capacity_bytes": acc.capacity_bytes,
                         "hit_fraction": acc.hit_fraction,
                         "service_s": service,
                         "energy_j": query_j}
            if self.power_cap is not None:
                tier_info["throttle_s"] = service - busy
        else:
            aggs = self._execute(pend.query)
            # finalize inside _execute forces the device sync, so
            # t1 - t0 covers the full scan
            t1 = self.clock()
            self.seconds_total += max(t1 - t0, 1e-12)
        if trace is not None:
            self._emit_launches(qt, launches0, t0)
            qt.close(t1, met=t1 <= deadline and error is None,
                     degraded=error is not None, error=error)
        self.bytes_total += pend.bytes_scanned
        self.logical_bytes_total += pend.logical_bytes
        if aggs is not None and "groups" in aggs:
            count = aggs["count"]        # grouped: total selected rows
        else:
            count = (next(iter(aggs.values()))["count"] if aggs else 0)
        res = QueryResult(
            qid=pend.qid, query=pend.query,
            aggregates=aggs if aggs is not None else {},
            count=count,
            selectivity=count / max(self.num_rows, 1),
            bytes_scanned=pend.bytes_scanned,
            latency_s=t1 - pend.submitted_at,
            deadline=deadline,
            met=t1 <= deadline and error is None, tier=tier_info,
            logical_bytes=pend.logical_bytes,
            degraded=error is not None, error=error)
        self.reports.append(SLAReport(
            rid=pend.qid, deadline=deadline,
            submitted_at=pend.submitted_at, finished_at=t1,
            work=pend.bytes_scanned, degraded=error is not None))
        if self.monitor is not None:
            # tick first: a cadence boundary at or before t1 samples the
            # world *before* this completion lands, so a completion at
            # exactly a boundary counts at the next tick — one
            # deterministic convention, byte-identical across replays
            self.monitor.tick(t1)
            self.monitor.observe(self.reports[-1], tenant=pend.tenant)
        self.results.append(res)
        return res

    # --- reporting / model feedback --------------------------------------
    def summary(self) -> dict:
        out = summarize(self.reports, rejected=len(self.queue.rejected))
        out["bytes_scanned"] = self.bytes_total
        out["measured_gbps"] = (self.bytes_total / self.seconds_total / 1e9
                                if self.seconds_total > 0 else 0.0)
        out["logical_bytes"] = self.logical_bytes_total
        # logical coverage per second: > measured_gbps exactly when the
        # store is compressed — the bandwidth compression multiplied
        out["effective_gbps"] = (self.logical_bytes_total
                                 / self.seconds_total / 1e9
                                 if self.seconds_total > 0 else 0.0)
        if self.tiered is not None:
            out["tier"] = self.tiered.stats(self.n_shards)
            out["energy"] = self.tiered.meter.summary()
        if self.prefetch is not None:
            out["prefetch"] = self.prefetch.stats()
        if self.power_cap is not None:
            out["power"] = self.power_cap.report(now=self.clock())
        if self.chaos is not None:
            out["resilience"] = self.chaos.summary()
        if getattr(self.tracer, "enabled", False):
            out["trace"] = self.tracer.summary()
        if self.monitor is not None:
            out["slo"] = self.monitor.summary()
        return out

    def model_check(self, system=None) -> dict:
        """Measured scan throughput vs the analytical model's Eq. 4 roofline
        (chips = shards): the number the provisioning regimes assume each
        chip sustains, checked against what the kernels attained."""
        from repro.core.systems import TPU_V5E, as_paper_system
        if self.seconds_total <= 0:
            raise ValueError(
                "no measured throughput to check the model against "
                "(seconds_total=0); submit() and run() at least one query "
                "before model_check()")
        sys_ = system or as_paper_system(TPU_V5E)
        model_bps = sys_.chip_peak_perf * self.n_shards
        measured = self.bytes_total / self.seconds_total
        return {
            "system": sys_.name,
            "chips": self.n_shards,
            "measured_gbps": measured / 1e9,
            "model_gbps": model_bps / 1e9,
            "attained_fraction": measured / model_bps,
        }

    def provision(self, sla_s: float, system=None):
        """The paper's performance-provisioning question answered from this
        engine's *measured* workload: how many chips to meet `sla_s` per
        query, with core_perf calibrated to attained throughput."""
        from repro.core import advisor
        if not self.reports or self.seconds_total <= 0:
            raise ValueError(
                "no measured queries to provision from; submit() and run() "
                "at least one query first")
        return advisor.advise_scan_sla(
            db_bytes=self.table.nbytes,
            bytes_per_query=self.bytes_total / len(self.reports),
            sla_s=sla_s, system=system,
            measured_chip_bps=(self.bytes_total / self.seconds_total
                               / self.n_shards))
