"""Logical query plans: predicate trees + multi-column aggregates.

WideTable's observation (Li & Patel, VLDB'14) — most analytic queries are
predicate scans feeding aggregates — generalized beyond the seed's
conjunction-of-one-width: predicates compose with AND/OR across columns of
*different* code widths (the physical layer repacks masks automatically),
and one query aggregates any number of columns over the same selection.

Plans are frozen, hashable dataclasses, so compiled/jitted physical
executions can be cached per plan shape.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.kernels.scan_filter.ref import OPS


class Plan:
    """Base predicate-tree node; composes with `&` and `|`."""

    def __and__(self, other: "Plan") -> "And":
        return And.of(self, other)

    def __or__(self, other: "Plan") -> "Or":
        return Or.of(self, other)


@dataclass(frozen=True)
class Pred(Plan):
    """column <op> constant over dictionary codes (op: lt|le|gt|ge|eq|ne)."""
    column: str
    op: str
    constant: int

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(
                f"unknown predicate op {self.op!r}; expected one of {OPS}")
        if self.constant < 0:
            raise ValueError(
                f"predicate constant {self.constant} is negative; codes are "
                f"unsigned dictionary indices")


def _flatten(cls, children):
    out = []
    for c in children:
        out.extend(c.children if isinstance(c, cls) else (c,))
    return tuple(out)


@dataclass(frozen=True)
class And(Plan):
    children: tuple

    def __post_init__(self):
        if not self.children:
            raise ValueError("And() needs at least one child predicate")

    @classmethod
    def of(cls, *children: Plan) -> "And":
        return cls(_flatten(cls, children))


@dataclass(frozen=True)
class Or(Plan):
    children: tuple

    def __post_init__(self):
        if not self.children:
            raise ValueError("Or() needs at least one child predicate")

    @classmethod
    def of(cls, *children: Plan) -> "Or":
        return cls(_flatten(cls, children))


Predicate = Pred       # legacy name (repro.db.queries)


def normalize(where) -> Plan:
    """Accept a Plan node, a single Pred, or the legacy list-of-Preds
    (implicit AND) and return a Plan tree."""
    if isinstance(where, Plan):
        return where
    if isinstance(where, (list, tuple)):
        if not where:
            raise ValueError("need at least one predicate")
        bad = [p for p in where if not isinstance(p, Plan)]
        if bad:
            raise ValueError(f"predicates must be Plan nodes, got {bad!r}")
        return where[0] if len(where) == 1 else And.of(*where)
    raise ValueError(f"cannot build a plan from {type(where).__name__!r}; "
                     f"pass a Pred/And/Or tree or a list of Preds")


def columns_of(plan: Plan) -> set[str]:
    if isinstance(plan, Pred):
        return {plan.column}
    out: set[str] = set()
    for c in plan.children:
        out |= columns_of(c)
    return out


@dataclass(frozen=True)
class Query:
    """SELECT <aggregates> WHERE <where>: the engine's unit of admission.

    where: a Plan tree (or legacy list of Preds, normalized lazily);
    aggregates: columns whose (sum, count, min, max) are computed over the
    selection.
    """
    where: Plan | tuple
    aggregates: tuple[str, ...]

    def __post_init__(self):
        # normalize eagerly so a Query is hashable (jit-cache key) and
        # malformed trees fail at construction, not execution
        object.__setattr__(self, "where", normalize(self.where))
        object.__setattr__(self, "aggregates", tuple(self.aggregates))
        if not self.aggregates:
            raise ValueError("query needs at least one aggregate column")

    def plan(self) -> Plan:
        return self.where
