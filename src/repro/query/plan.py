"""Logical query plans: predicate trees + multi-column aggregates.

WideTable's observation (Li & Patel, VLDB'14) — most analytic queries are
predicate scans feeding aggregates — generalized beyond the seed's
conjunction-of-one-width: predicates compose with AND/OR across columns of
*different* code widths (the physical layer repacks masks automatically),
and one query aggregates any number of columns over the same selection.

Plans are frozen, hashable dataclasses, so compiled/jitted physical
executions can be cached per plan shape.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.kernels.scan_filter.ref import OPS


class Plan:
    """Base predicate-tree node; composes with `&` and `|`."""

    def __and__(self, other: "Plan") -> "And":
        return And.of(self, other)

    def __or__(self, other: "Plan") -> "Or":
        return Or.of(self, other)


@dataclass(frozen=True)
class Pred(Plan):
    """column <op> constant over dictionary codes (op: lt|le|gt|ge|eq|ne)."""
    column: str
    op: str
    constant: int

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(
                f"unknown predicate op {self.op!r}; expected one of {OPS}")
        if self.constant < 0:
            raise ValueError(
                f"predicate constant {self.constant} is negative; codes are "
                f"unsigned dictionary indices")


def _flatten(cls, children):
    out = []
    for c in children:
        out.extend(c.children if isinstance(c, cls) else (c,))
    return tuple(out)


@dataclass(frozen=True)
class And(Plan):
    children: tuple

    def __post_init__(self):
        if not self.children:
            raise ValueError("And() needs at least one child predicate")

    @classmethod
    def of(cls, *children: Plan) -> "And":
        return cls(_flatten(cls, children))


@dataclass(frozen=True)
class Or(Plan):
    children: tuple

    def __post_init__(self):
        if not self.children:
            raise ValueError("Or() needs at least one child predicate")

    @classmethod
    def of(cls, *children: Plan) -> "Or":
        return cls(_flatten(cls, children))


Predicate = Pred       # legacy name (repro.db.queries)


def normalize(where) -> Plan:
    """Accept a Plan node, a single Pred, or the legacy list-of-Preds
    (implicit AND) and return a Plan tree."""
    if isinstance(where, Plan):
        return where
    if isinstance(where, (list, tuple)):
        if not where:
            raise ValueError("need at least one predicate")
        bad = [p for p in where if not isinstance(p, Plan)]
        if bad:
            raise ValueError(f"predicates must be Plan nodes, got {bad!r}")
        return where[0] if len(where) == 1 else And.of(*where)
    raise ValueError(f"cannot build a plan from {type(where).__name__!r}; "
                     f"pass a Pred/And/Or tree or a list of Preds")


def columns_of(plan: Plan) -> set[str]:
    if isinstance(plan, Pred):
        return {plan.column}
    out: set[str] = set()
    for c in plan.children:
        out |= columns_of(c)
    return out


@dataclass(frozen=True)
class Query:
    """SELECT <aggregates> WHERE <where>: the engine's unit of admission.

    where: a Plan tree (or legacy list of Preds, normalized lazily);
    aggregates: columns whose (sum, count, min, max) are computed over the
    selection.
    """
    where: Plan | tuple
    aggregates: tuple[str, ...]

    def __post_init__(self):
        # normalize eagerly so a Query is hashable (jit-cache key) and
        # malformed trees fail at construction, not execution
        object.__setattr__(self, "where", normalize(self.where))
        object.__setattr__(self, "aggregates", tuple(self.aggregates))
        if not self.aggregates:
            raise ValueError("query needs at least one aggregate column")

    def plan(self) -> Plan:
        return self.where


def _normalize_keys(node: str, keys) -> tuple[str, ...]:
    ks = (keys,) if isinstance(keys, str) else tuple(keys)
    if len(ks) != 1:
        raise ValueError(
            f"{node} supports exactly one group-key column, got "
            f"{len(ks)}: {list(ks)!r}; compose single-key queries (or "
            f"widen the dictionary to a composite code) instead")
    if not isinstance(ks[0], str):
        raise ValueError(f"{node} key must be a column name, got "
                         f"{ks[0]!r}")
    return ks


@dataclass(frozen=True)
class GroupBy:
    """SELECT key, count(*), sum(agg)... GROUP BY key [WHERE ...].

    keys: one group-key column (a 1-tuple or bare name); aggs: value
    columns whose per-group exact sums are computed (may be empty — a
    pure histogram); where: optional Plan tree filtering the rows.

    Like Query, a frozen/hashable admission unit: `.plan()` and
    `.aggregates` expose the scanned plan tree and columns so the
    engine's byte/chunk accounting and bind checks work unchanged.
    """
    keys: tuple[str, ...]
    aggs: tuple[str, ...] = ()
    where: Plan | None = None

    def __post_init__(self):
        object.__setattr__(self, "keys", _normalize_keys("GroupBy",
                                                         self.keys))
        object.__setattr__(self, "aggs", (self.aggs,) if isinstance(
            self.aggs, str) else tuple(self.aggs))
        for a in self.aggs:
            if a in self.keys:
                raise ValueError(
                    f"GroupBy aggregates {a!r}, which is the group key: "
                    f"per-group sums of the key are its key * count; drop "
                    f"the aggregate or group by a different column")
        if self.where is not None:
            object.__setattr__(self, "where", normalize(self.where))

    @property
    def key(self) -> str:
        return self.keys[0]

    def plan(self) -> Plan:
        # the tautology keeps every grouped query a plan tree, so the
        # translate/accounting/guard machinery needs no special case
        return self.where if self.where is not None \
            else Pred(self.key, "ge", 0)

    @property
    def aggregates(self) -> tuple[str, ...]:
        """Columns scanned beyond the plan tree: the value columns plus
        the key itself (charged like any other scanned column)."""
        return self.aggs + self.keys


@dataclass(frozen=True, eq=False)
class HashJoin:
    """Probe-side grouped semi-join: group the engine table's rows whose
    `probe` key appears in `build`'s `on` column, aggregating probe value
    columns per join key.

    build: a small dimension table (repro.db.columnar.Table) hashed once
    and broadcast to every shard; probe: the fact-side key column on the
    engine's table; on: the build-side key column. eq=False keeps the
    node hashable-by-identity even though the build table is not, so
    jitted per-shard executions still cache per join instance.
    """
    build: object
    probe: str
    on: str
    aggs: tuple[str, ...] = ()
    where: Plan | None = None

    def __post_init__(self):
        object.__setattr__(self, "aggs", (self.aggs,) if isinstance(
            self.aggs, str) else tuple(self.aggs))
        cols = getattr(self.build, "columns", None)
        if not isinstance(cols, dict) or self.on not in cols:
            have = sorted(cols) if isinstance(cols, dict) else type(
                self.build).__name__
            raise ValueError(
                f"HashJoin build side has no column {self.on!r}; build "
                f"must be a Table carrying the join key (has: {have})")
        for a in self.aggs:
            if a == self.probe:
                raise ValueError(
                    f"HashJoin aggregates {a!r}, which is the probe join "
                    f"key: per-group sums of the key are its key * count; "
                    f"drop the aggregate or aggregate a value column")
        if self.where is not None:
            object.__setattr__(self, "where", normalize(self.where))

    @property
    def key(self) -> str:
        return self.probe

    def plan(self) -> Plan:
        return self.where if self.where is not None \
            else Pred(self.probe, "ge", 0)

    @property
    def aggregates(self) -> tuple[str, ...]:
        return self.aggs + (self.probe,)


def is_grouped(query) -> bool:
    """True for the relational admission units (GroupBy/HashJoin)."""
    return isinstance(query, (GroupBy, HashJoin))
