"""Sharded SLA-aware query engine: the paper's workload, executable.

Logical plans (Pred/And/Or trees + multi-column aggregates) compile to
kernel-dispatch physical operators, shard row-wise across a mesh, and batch
through the shared EDF deadline scheduler — with measured throughput fed
back to the analytical provisioning model in repro.core.
"""
from repro.query.engine import QueryEngine, QueryResult
from repro.query.plan import And, Or, Plan, Pred, Predicate, Query
from repro.query.sharded import ShardedTable

__all__ = ["And", "Or", "Plan", "Pred", "Predicate", "Query",
           "QueryEngine", "QueryResult", "ShardedTable"]
