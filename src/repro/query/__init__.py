"""Sharded SLA-aware query engine: the paper's workload, executable.

Logical plans (Pred/And/Or trees + multi-column aggregates) compile to
kernel-dispatch physical operators, shard row-wise across a mesh, and batch
through the shared EDF deadline scheduler — with measured throughput fed
back to the analytical provisioning model in repro.core. With
`QueryEngine(table, tiered=...)` the same execution path runs against a
two-tier memory system (repro.tier): per-chunk bytes are reported to the
placement engine and latency/admission are charged at per-tier rates.
"""
from repro.query.engine import QueryEngine, QueryResult
from repro.query.plan import (And, GroupBy, HashJoin, Or, Plan, Pred,
                              Predicate, Query, is_grouped)
from repro.query.sharded import ShardedTable

__all__ = ["And", "GroupBy", "HashJoin", "Or", "Plan", "Pred",
           "Predicate", "Query", "QueryEngine", "QueryResult",
           "ShardedTable", "is_grouped"]
