"""Gradient compression: int8 quantized collectives + error feedback.

The cross-pod (DCN) all-reduce is the bandwidth-starved link in multi-pod
training (repro.core.traffic): int8 quantization cuts its bytes 4x vs fp32
at <1% relative error per reduction, and error feedback makes the bias
vanish over steps (the classic EF-SGD argument: residuals are bounded, so
the accumulated sent signal tracks the accumulated true signal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quantize(x):
    """x -> (int8 codes, fp32 scale). Symmetric per-tensor quantization."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_pod(tree, mesh, axis: str = "pod"):
    """psum a replicated pytree over `axis` in int8 (scales reduced in fp32).

    Each shard quantizes locally, the int8 codes psum as int32 (no
    overflow up to 2^23 summands), and the max scale across the group
    bounds the dequantization error at int8 resolution.
    """
    def local(t):
        def one(x):
            q, scale = _quantize(x)
            total = jax.lax.psum(q.astype(jnp.int32), axis)
            s = jax.lax.pmax(scale, axis)
            return _dequantize(total, s)
        return jax.tree.map(one, t)

    specs = jax.tree.map(lambda _: P(), tree)
    return shard_map(local, mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_rep=False)(tree)


def error_feedback_compress(grads, residual=None):
    """One EF step: quantize (grads + residual), carry the new residual.

    Returns (sent, residual): `sent` is the dequantized payload actually
    contributed to the reduction; `residual` must be threaded into the next
    call so quantization error accumulates into later sends instead of
    being lost.
    """
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)

    def one(g, r):
        t = g + r
        q, scale = _quantize(t)
        sent = _dequantize(q, scale)
        return sent, t - sent

    flat = jax.tree.map(one, grads, residual)
    sent = jax.tree.map(lambda pair: pair[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda pair: pair[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return sent, res
