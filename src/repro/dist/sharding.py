"""Logical-axis sharding: name -> mesh-axis resolution + constraint helpers.

Models annotate arrays with *logical* axis names ("embed", "heads", ...);
this module resolves them against a rule table and a mesh into concrete
PartitionSpecs. Resolution is defensive so one rule table works across every
(arch x shape x mesh) cell:

- rules may map a name to one mesh axis, a tuple of axes, or None;
- axes absent from the mesh are silently dropped (a "pod" rule is harmless
  on a single-pod mesh);
- an axis is never used twice within one array (first dim wins);
- a dim that is not divisible by its axis-group product drops axes from the
  end of the group until it is (jit requires even shards).

`logical_constraint` is a no-op unless a `use_rules(mesh, rules)` context is
active, so model code is importable and runnable with zero distribution
setup (single-device tests, interpret-mode kernels).
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Default logical-name -> mesh-axis rules (DESIGN.md §4). Names absent from
# the table resolve to None (replicated); per-cell overrides come from
# repro.launch.specs.rules_for and repro.dist.strategies.
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "embed": "data",             # FSDP: weights gathered over data
    "mlp": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",          # EP when the expert count divides |model|
    "expert_mlp": "model",       # expert-TP fallback when EP drops
    "head_dim": None,
    "seq": None,
    "kv_seq": None,
    "act_embed": None,
    "layers": None,
    "state": None,
    "conv_kernel": None,
}

_SCALAR = "_scalar_"


def _names_of(names):
    """Normalize an axes annotation (tuple | 'a b _' string) to a tuple."""
    if names is None:
        return ()
    if isinstance(names, str):
        if names == _SCALAR:
            return ()
        return tuple(None if n == "_" else n for n in names.split())
    return tuple(names)


def resolve_spec(shape, names, mesh, rules) -> P:
    """Resolve logical `names` for an array of `shape` to a PartitionSpec.

    mesh only needs `.shape` (axis -> size mapping) and `.axis_names`.
    """
    names = _names_of(names)
    rules = dict(DEFAULT_RULES, **rules)   # callers pass only overrides
    mesh_axes = set(mesh.axis_names)
    sizes = dict(mesh.shape)
    claimed: set = set()
    entries = []
    for dim, name in zip(shape, names):
        rule = rules.get(name) if name is not None else None
        if rule is None:
            entries.append(None)
            continue
        group = [rule] if isinstance(rule, str) else list(rule)
        group = [a for a in group if a in mesh_axes and a not in claimed]
        # jit needs even shards: shed axes from the end until divisible
        while group and dim % math.prod(sizes[a] for a in group):
            group.pop()
        if not group:
            entries.append(None)
            continue
        claimed.update(group)
        entries.append(group[0] if len(group) == 1 else tuple(group))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_tree(tree, axes, mesh, rules):
    """Twin-pytree map: (arrays, axes-strings) -> NamedShardings."""
    return jax.tree.map(
        lambda leaf, ax: NamedSharding(
            mesh, resolve_spec(getattr(leaf, "shape", ()), ax, mesh, rules)),
        tree, axes)


# --------------------------------------------------------------------------
# in-jit constraints
# --------------------------------------------------------------------------

_ACTIVE = threading.local()


@contextmanager
def use_rules(mesh, rules):
    """Activate (mesh, rules) for logical_constraint within this thread."""
    stack = getattr(_ACTIVE, "stack", None)
    if stack is None:
        stack = _ACTIVE.stack = []
    stack.append((mesh, rules))
    try:
        yield
    finally:
        stack.pop()


def current_rules():
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1] if stack else None


def logical_constraint(x, names):
    """with_sharding_constraint by logical names; identity outside
    a use_rules context or when the spec resolves to fully-replicated."""
    active = current_rules()
    if active is None:
        return x
    mesh, rules = active
    spec = resolve_spec(x.shape, names, mesh, rules)
    if spec == P():
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
