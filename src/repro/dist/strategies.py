"""Named sharding strategies: per-cell rule overrides + config tweaks.

A strategy is a dict of logical-rule overrides layered on top of
`repro.launch.specs.rules_for` (which itself layers on
`repro.dist.sharding.DEFAULT_RULES`). The analytic traffic model
(repro.core.traffic.layout_for) mirrors these semantics when deriving
roofline terms.

- megatron:      baseline TP over |model| + FSDP over |data| + DP.
- dp:            no TP — batch shards over every axis; weights FSDP only.
- dp_noremat:    dp + remat disabled (trade HBM for recompute FLOPs).
- cp:            context parallel — sequence shards over |model|, K/V
                 replicated via the "cp_seq"/"kv_full" hooks in
                 repro.models.attention (for head counts indivisible by
                 |model|).
- 2d:            decode 2D weight residency — weights stay (data x model)
                 sharded, no per-step re-gather.
- 2d_splitcache: 2d + the KV ring sharded over |model| (split-K decode).
"""
from __future__ import annotations

import dataclasses

_NO_TP = {"mlp": None, "vocab": None, "heads": None, "kv_heads": None,
          "experts": None, "expert_mlp": None}

STRATEGIES: dict = {
    "megatron": {},
    "dp": dict(_NO_TP, batch=("pod", "data", "model")),
    "dp_noremat": dict(_NO_TP, batch=("pod", "data", "model")),
    "cp": dict(_NO_TP, cp_seq="model", kv_full=None),
    "2d": {"embed": ("data", "pod"), "batch": ("data",)},
    "2d_splitcache": {"embed": ("data", "pod"), "batch": ("data",),
                      "kv_seq": "model"},
}

# Hillclimbed winners per (arch, shape) cell — populated by sweeps over the
# dry-run grid (repro.launch.dryrun --opt); absent cells use "megatron".
OPTIMIZED: dict = {}


def strategy_for(cfg, shape, name: str = "megatron"):
    """Resolve a strategy name to (rules_extra, cfg, name).

    The config comes back possibly adjusted (e.g. dp_noremat disables
    remat) so callers thread it through instead of the original.
    """
    if name is None:
        name = "megatron"
    rules = dict(STRATEGIES[name])
    if name == "dp_noremat":
        cfg = dataclasses.replace(cfg, remat="none")
    return rules, cfg, name
