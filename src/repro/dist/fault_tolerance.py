"""Fault tolerance: file-based heartbeats, straggler detection, and
supervised crash-restart.

All host-side and dependency-free: heartbeats are one JSON file per host in
a shared directory (the multi-host lowest common denominator — works over
NFS/GCS-fuse), the straggler detector is a median filter over step times,
and `run_supervised` restarts a training loop from its latest checkpoint up
to a restart budget (tests assert bitwise-identical resumption).
"""
from __future__ import annotations

import json
import statistics
import time
from dataclasses import dataclass, field
from pathlib import Path


class Heartbeat:
    """Per-host liveness + progress beacon over a shared directory."""

    def __init__(self, directory, host: str, timeout_s: float = 30.0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host = host
        self.timeout_s = timeout_s

    def _path(self, host: str) -> Path:
        return self.dir / f"{host}.heartbeat"

    def beat(self, step: int) -> None:
        tmp = self._path(self.host).with_suffix(".tmp")
        tmp.write_text(json.dumps({"host": self.host, "step": int(step),
                                   "time": time.time()}))
        tmp.replace(self._path(self.host))

    def _read_all(self) -> dict:
        out = {}
        for p in sorted(self.dir.glob("*.heartbeat")):
            try:
                rec = json.loads(p.read_text())
                out[rec["host"]] = rec
            except (ValueError, KeyError, OSError):
                continue
        return out

    def fleet(self) -> list:
        return sorted(self._read_all())

    def dead_hosts(self) -> list:
        now = time.time()
        return sorted(h for h, rec in self._read_all().items()
                      if now - rec["time"] > self.timeout_s)

    def lagging_hosts(self, behind_steps: int) -> list:
        recs = self._read_all()
        if not recs:
            return []
        lead = max(rec["step"] for rec in recs.values())
        return sorted(h for h, rec in recs.items()
                      if rec["step"] < lead - behind_steps + 1)


class StragglerDetector:
    """Flags steps slower than `threshold` x the median of clean steps.

    Flagged steps are excluded from the baseline so one straggler does not
    poison the median and mask the next one.
    """

    def __init__(self, threshold: float = 2.0, warmup: int = 3,
                 window: int = 50):
        self.threshold = threshold
        self.warmup = warmup
        self.window = window
        self._clean: list = []
        self.flagged: list = []
        self.ewma = 0.0

    def observe(self, step: int, seconds: float) -> bool:
        self.ewma = (seconds if not self._clean
                     else 0.9 * self.ewma + 0.1 * seconds)
        if len(self._clean) >= self.warmup:
            baseline = statistics.median(self._clean[-self.window:])
            if seconds > self.threshold * baseline:
                self.flagged.append((step, seconds))
                return True
        self._clean.append(seconds)
        return False


@dataclass
class RestartPolicy:
    max_restarts: int = 2
    backoff_s: float = 0.0
    restarts: int = 0
    failures: list = field(default_factory=list)


def run_supervised(loop, restore, policy: RestartPolicy):
    """Run `loop(state)` under crash-restart supervision.

    `restore()` produces the state to (re)start from — typically the latest
    checkpoint. Re-raises once the restart budget is exhausted. Returns
    (final_state, policy).
    """
    state = restore()
    while True:
        try:
            return loop(state), policy
        except Exception as e:  # noqa: BLE001 — any crash is restartable
            policy.failures.append(repr(e))
            policy.restarts += 1
            if policy.restarts > policy.max_restarts:
                raise
            if policy.backoff_s:
                time.sleep(policy.backoff_s * policy.restarts)
            state = restore()
