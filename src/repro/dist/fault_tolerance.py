"""Fault tolerance: file-based heartbeats, straggler detection, and
supervised crash-restart.

All host-side and dependency-free: heartbeats are one JSON file per host in
a shared directory (the multi-host lowest common denominator — works over
NFS/GCS-fuse), the straggler detector is a median filter over step times,
and `run_supervised` restarts a training loop from its latest checkpoint up
to a restart budget (tests assert bitwise-identical resumption).
"""
from __future__ import annotations

import contextlib
import json
import math
import os
import statistics
import tempfile
import time
from dataclasses import dataclass, field
from pathlib import Path


class Heartbeat:
    """Per-host liveness + progress beacon over a shared directory.

    `clock` defaults to wall time; chaos tests and the resilience
    harness inject a VirtualClock so liveness verdicts are deterministic
    (dead_hosts at modeled time, no sleeps, no flakes).
    """

    def __init__(self, directory, host: str, timeout_s: float = 30.0,
                 clock=time.time):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host = host
        self.timeout_s = timeout_s
        self.clock = clock

    def _path(self, host: str) -> Path:
        return self.dir / f"{host}.heartbeat"

    def beat(self, step: int) -> None:
        # mkstemp + os.replace (the tune-cache idiom): with_suffix would
        # mangle dotted host names ("node.0.heartbeat" -> "node.0.tmp",
        # clobbering a sibling host's temp file) and an in-place write
        # could be read torn; a rename is atomic on POSIX
        final = self._path(self.host)
        fd, tmp = tempfile.mkstemp(dir=self.dir, prefix=final.name + ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps({"host": self.host, "step": int(step),
                                    "time": self.clock()}))
            os.replace(tmp, final)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise

    def _read_all(self) -> dict:
        out = {}
        for p in sorted(self.dir.glob("*.heartbeat")):
            try:
                rec = json.loads(p.read_text())
                out[rec["host"]] = rec
            except (ValueError, KeyError, OSError):
                continue
        return out

    def fleet(self) -> list:
        return sorted(self._read_all())

    def dead_hosts(self) -> list:
        now = self.clock()
        return sorted(h for h, rec in self._read_all().items()
                      if now - rec["time"] > self.timeout_s)

    def lagging_hosts(self, behind_steps: int) -> list:
        recs = self._read_all()
        if not recs:
            return []
        lead = max(rec["step"] for rec in recs.values())
        return sorted(h for h, rec in recs.items()
                      if rec["step"] < lead - behind_steps + 1)


class StragglerDetector:
    """Flags steps slower than `threshold` x the median of clean steps.

    Flagged steps are excluded from the baseline so one straggler does not
    poison the median and mask the next one.
    """

    def __init__(self, threshold: float = 2.0, warmup: int = 3,
                 window: int = 50):
        self.threshold = threshold
        self.warmup = warmup
        self.window = window
        self._clean: list = []
        self.flagged: list = []
        self.ewma = 0.0

    def observe(self, step: int, seconds: float) -> bool:
        self.ewma = (seconds if not self._clean
                     else 0.9 * self.ewma + 0.1 * seconds)
        if len(self._clean) >= self.warmup:
            baseline = statistics.median(self._clean[-self.window:])
            if seconds > self.threshold * baseline:
                self.flagged.append((step, seconds))
                return True
        self._clean.append(seconds)
        return False


@dataclass
class RestartPolicy:
    max_restarts: int = 2
    backoff_s: float = 0.0       # linear backoff: restart k waits k * this
    restarts: int = 0
    failures: list = field(default_factory=list)

    def __post_init__(self):
        if self.max_restarts < 0:
            raise ValueError(f"max_restarts={self.max_restarts} must be "
                             f">= 0")
        if not math.isfinite(self.backoff_s) or self.backoff_s < 0:
            raise ValueError(f"backoff_s={self.backoff_s} must be finite "
                             f"and non-negative")

    def backoff(self, restart: int) -> float:
        """Seconds to wait before restart number `restart` (1-based)."""
        if restart < 1:
            raise ValueError(f"restart={restart} must be >= 1")
        return self.backoff_s * restart


def run_supervised(loop, restore, policy: RestartPolicy, clock=None):
    """Run `loop(state)` under crash-restart supervision.

    `restore()` produces the state to (re)start from — typically the latest
    checkpoint. Each restart waits `policy.backoff(k)` first: on the wall
    clock by default, or on an injected advanceable clock (e.g.
    serve.sla.VirtualClock) so supervised chaos tests model the backoff
    instead of sleeping it. Re-raises once the restart budget is
    exhausted. Returns (final_state, policy).
    """
    state = restore()
    while True:
        try:
            return loop(state), policy
        except Exception as e:  # noqa: BLE001 — any crash is restartable
            policy.failures.append(repr(e))
            policy.restarts += 1
            if policy.restarts > policy.max_restarts:
                raise
            delay = policy.backoff(policy.restarts)
            if delay:
                if clock is not None and hasattr(clock, "advance"):
                    clock.advance(delay)
                else:
                    time.sleep(delay)
            state = restore()
