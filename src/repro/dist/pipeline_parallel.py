"""GPipe-style pipeline parallelism over one mesh axis.

`gpipe` places stage s on device s of `axis` and streams M microbatches
through the ring with `ppermute`: at tick t device j runs its stage on
microbatch t-j, so the pipe drains in M + S - 1 ticks with the classic
bubble fraction (S-1)/(M+S-1) of idle device-ticks.

Composes with other axes (DP on "data" while PP on "pod"): specs mention
only `axis`, everything else is untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(microbatches: int, stages: int) -> float:
    """Idle fraction of the device-tick grid for a drained GPipe schedule."""
    return (stages - 1) / (microbatches + stages - 1)


def gpipe(stage, weights, xs, *, mesh, axis: str):
    """Run `stage(w_s, x)` for s = 0..S-1 composed in sequence, pipelined.

    weights: (S, ...) per-stage params, sharded over `axis` (one stage per
    device). xs: (M, ...) microbatches, replicated over `axis`. Output must
    have the same shape as a microbatch. Returns (M, ...) outputs,
    replicated.
    """
    s = int(mesh.shape[axis])
    m = int(xs.shape[0])
    if weights.shape[0] != s:
        raise ValueError(f"{weights.shape[0]} stages on a {s}-way "
                         f"'{axis}' axis")
    perm = [(i, (i + 1) % s) for i in range(s)]

    def body(w, xs):
        w = w[0]                                     # this device's stage
        idx = jax.lax.axis_index(axis)

        def tick(carry, t):
            out, recv = carry
            feed = xs[jnp.clip(t, 0, m - 1)]         # device-0 ingest
            x = jnp.where(idx == 0, feed, recv)
            y = stage(w, x)
            nxt = jax.lax.ppermute(y, axis, perm)
            done = t - (s - 1)                       # mb finishing this tick
            j = jnp.clip(done, 0, m - 1)
            keep = (idx == s - 1) & (done >= 0) & (done < m)
            out = out.at[j].set(jnp.where(keep, y, out[j]))
            return (out, nxt), None

        out0 = jnp.zeros(xs.shape, xs.dtype)
        (out, _), _ = jax.lax.scan(tick, (out0, jnp.zeros_like(xs[0])),
                                   jnp.arange(m + s - 1))
        # only the last device holds real outputs; broadcast to the ring
        return jax.lax.psum(jnp.where(idx == s - 1, out, 0.0), axis)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis), P()), out_specs=P(),
                     check_rep=False)(weights, xs)
