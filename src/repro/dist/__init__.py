"""Distribution substrate: logical-axis sharding, sharding strategies,
fault tolerance, gradient compression, and pipeline parallelism.

Models never name mesh axes directly — they annotate arrays with logical
axis names (repro.models.common) and this package resolves those names to
mesh axes through per-cell rule tables (sharding.py), optionally overridden
by a named strategy (strategies.py).
"""
