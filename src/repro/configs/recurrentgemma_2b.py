"""recurrentgemma-2b — Griffin: RG-LRU + local attention, (R,R,A) cycle
[arXiv:2402.19427]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
    num_heads=10, num_kv_heads=1, head_dim=256, d_ff=7680,
    vocab_size=256000, lru_width=2560,
    block_pattern=("rglru", "rglru", "swa"), window=2048,
    tie_embeddings=True,
)
