"""mixtral-8x22b — MoE 8 experts top-2, sliding-window attn [arXiv:2401.04088]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16384,
    vocab_size=32768, num_experts=8, experts_per_token=2,
    block_pattern=("swa",), window=4096,
)
