"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone only: the EnCodec frontend is a stub; input_specs() provides
precomputed frame embeddings (task spec)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192,
    vocab_size=2048, input_mode="embeddings",
)
