"""minitron-4b — pruned Nemotron, GQA [arXiv:2407.14679]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense", num_layers=32, d_model=3072,
    num_heads=24, num_kv_heads=8, head_dim=128, d_ff=9216,
    vocab_size=256000, tie_embeddings=True,  # published 4.19B implies tying
)
