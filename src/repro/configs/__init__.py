"""Architecture registry: --arch <id> -> ArchConfig (assigned pool)."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, cell_applicable

_MODULES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "internlm2-1.8b": "internlm2_1p8b",
    "minitron-4b": "minitron_4b",
    "llama3-405b": "llama3_405b",
    "mistral-large-123b": "mistral_large_123b",
    "mixtral-8x22b": "mixtral_8x22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "musicgen-large": "musicgen_large",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-76b": "internvl2_76b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_cells():
    """Every applicable (arch, shape) cell plus skip records."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            yield arch_id, shape.name, ok, why


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "ARCH_IDS", "get_config",
           "all_cells", "cell_applicable"]
