"""internvl2-76b — InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone only: the ViT frontend is a stub; input_specs() provides
precomputed patch embeddings (task spec)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=28672,
    vocab_size=128256, input_mode="embeddings",
)
