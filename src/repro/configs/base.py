"""Architecture + shape configuration for the assigned workload pool."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (exact published dims).

    `block_pattern` is the repeating cycle of mixer types through the stack:
    "attn" (full causal), "swa" (sliding-window causal), "ssd" (Mamba-2),
    "rglru" (Griffin recurrent block). Homogeneous stacks scan over layers;
    patterned stacks scan over pattern groups.
    """

    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    aux_free_bias: bool = False     # moonshot/deepseek-style aux-loss-free routing
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # --- hybrid (RG-LRU) ---
    lru_width: int = 0          # 0 -> d_model
    # --- structure ---
    block_pattern: tuple[str, ...] = ("attn",)
    window: int = 0             # sliding-window size for "swa"/local attn
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm stub frontends)
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- runtime knobs (hillclimbable) ---
    remat: str = "block"        # none | block | dots
    scan_layers: bool = True
    fused_ce: bool = False      # chunked/fused cross-entropy (beyond-paper opt)
    attn_impl: str = "auto"     # auto | naive | blockwise | flash

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return all(b in ("ssd", "rglru") for b in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no block attends to unbounded context quadratically."""
        return all(b in ("ssd", "rglru", "swa") for b in self.block_pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def pattern_at(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        return _param_count(self, active_only=True)

    def reduced(self, **over) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        d = {
            "num_layers": min(self.num_layers, len(self.block_pattern) * 2),
            "d_model": 64,
            "num_heads": min(self.num_heads, 4) or 0,
            "num_kv_heads": min(self.num_kv_heads, 2) or 0,
            "head_dim": 16 if self.num_heads else 0,
            "d_ff": 128 if self.d_ff else 0,
            "vocab_size": 256,
            "num_experts": min(self.num_experts, 4),
            "experts_per_token": min(self.experts_per_token, 2),
            # no-drop capacity so cached/split passes equal the full pass
            # (capacity-based MoE drops depend on segment length)
            "moe_capacity_factor": (float(min(self.num_experts, 4))
                                    / max(min(self.experts_per_token, 2), 1)
                                    if self.num_experts else 1.25),
            "ssm_state": min(self.ssm_state, 16),
            "ssm_head_dim": 16 if self.ssm_state else 64,
            "ssm_chunk": 32,
            "lru_width": 64 if self.lru_width or self.family == "hybrid" else 0,
            "window": min(self.window, 32) if self.window else 0,
            "scan_layers": self.scan_layers,
        }
        d.update(over)
        return dataclasses.replace(self, **d)


def _param_count(c: ArchConfig, active_only: bool = False) -> int:
    hd = c.resolved_head_dim
    total = 0
    if c.input_mode == "tokens":
        total += c.vocab_size * c.d_model     # embedding
    if not c.tie_embeddings:
        total += c.d_model * c.vocab_size     # lm head
    total += c.d_model                        # final norm
    for layer in range(c.num_layers):
        kind = c.pattern_at(layer)
        total += c.d_model                    # pre-mixer norm
        if kind in ("attn", "swa"):
            total += c.d_model * (c.num_heads + 2 * c.num_kv_heads) * hd
            total += c.num_heads * hd * c.d_model
        elif kind == "ssd":
            din, h, n = c.d_inner, c.ssm_heads, c.ssm_state
            total += c.d_model * (2 * din + 2 * n + h)     # in_proj
            total += (din + 2 * n) * c.ssm_conv            # conv
            total += 3 * h                                  # A, dt_bias, D
            total += din                                    # gate norm
            total += din * c.d_model                        # out_proj
        elif kind == "rglru":
            w = c.resolved_lru_width
            total += c.d_model * w * 2          # proj_x, proj_gate
            total += 2 * w * w + 2 * w          # dense r/i gates + biases
            total += w * c.ssm_conv + w         # conv + lambda
            total += w * c.d_model              # out_proj
        if c.d_ff and kind != "ssd":
            total += c.d_model                # pre-ffn norm
            ffn = 3 * c.d_model * c.d_ff      # SwiGLU
            if c.num_experts:
                total += c.d_model * c.num_experts          # router
                if c.aux_free_bias:
                    total += c.num_experts                  # selection bias
                e = c.experts_per_token if active_only else c.num_experts
                total += e * ffn
            else:
                total += ffn
    return total


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: int = 0     # 0 -> no gradient accumulation

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped-by-design: full quadratic attention at 512k "
                       "context (see DESIGN.md §3)")
    return True, ""
