"""Production training launcher.

Wires every substrate together: mesh + logical-rule shardings, sharded
train step (with optional microbatch accumulation), deterministic sharded
data pipeline with prefetch, versioned async checkpoints, heartbeats,
straggler detection, and crash-restart supervision.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 20 --seq-len 128 --global-batch 8

On real hardware, run one process per host (jax.distributed) and pass
--mesh data,model dims matching the slice; on this container it runs on
whatever devices exist.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, SyntheticLM, make_global_batch
from repro.data.pipeline import Prefetcher
from repro.dist.fault_tolerance import (Heartbeat, RestartPolicy,
                                        StragglerDetector, run_supervised)
from repro.dist.sharding import sharding_tree
from repro.launch import specs
from repro.launch.mesh import make_mesh
from repro.train import optim, step as step_lib


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="",
                    help="comma dims for (data,model); default 1 device")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--heartbeat-dir", default="")
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--metrics-file", default="",
                    help="JSONL per-step metrics incl. MFU vs roofline")
    return ap.parse_args(argv)


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(dims, ("data", "model")[:len(dims)])
    else:
        mesh = make_mesh((jax.device_count(),), ("data",))
    shape = ShapeSpec("cli", "train", args.seq_len, args.global_batch,
                      microbatch=args.microbatches)
    opt_cfg = optim.AdamWConfig(lr=args.lr, warmup_steps=10,
                                decay_steps=max(args.steps, 100))
    jitted, _ = specs.build_train(cfg, shape, mesh, opt_cfg=opt_cfg,
                                  num_microbatches=args.microbatches)
    return cfg, mesh, shape, opt_cfg, jitted


def main(argv=None):
    args = parse_args(argv)
    cfg, mesh, shape, opt_cfg, jitted = build(args)
    rules = specs.rules_for(cfg, shape)

    ds = SyntheticLM(DataConfig(
        seed=1234, vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0))

    mgr = (CheckpointManager(args.checkpoint_dir, async_save=True)
           if args.checkpoint_dir else None)
    hb = (Heartbeat(args.heartbeat_dir, f"host-{jax.process_index()}")
          if args.heartbeat_dir else None)
    straggler = StragglerDetector()
    mlog = None
    if args.metrics_file:
        from repro.train.metrics import MetricsLogger
        mlog = MetricsLogger(args.metrics_file, cfg, shape,
                             chips=mesh.devices.size)

    def fresh_state():
        state, axes = step_lib.init_state(jax.random.PRNGKey(0), cfg,
                                          opt_cfg)
        sh = sharding_tree(state, axes, mesh, rules)
        return jax.tree.map(jax.device_put, state, sh)

    def restore():
        if mgr and mgr.latest_step() is not None:
            skeleton = jax.eval_shape(fresh_state)
            state, axes = step_lib.init_state(jax.random.PRNGKey(0), cfg,
                                              opt_cfg)
            sh = sharding_tree(state, axes, mesh, rules)
            restored, meta = mgr.restore(state, shardings=sh)
            print(f"[restore] resumed from step {meta['step']}")
            return restored
        return fresh_state()

    batch_spec = {"inputs": P("data"), "labels": P("data")}

    def loop(state):
        step0 = int(state["step"])
        pf = Prefetcher(ds, start_step=step0)
        try:
            while int(state["step"]) < args.steps:
                t0 = time.time()
                _, host_batch = pf.next()
                batch = make_global_batch(host_batch, mesh, batch_spec)
                state, metrics = jitted(state, batch)
                s = int(state["step"])
                dt = time.time() - t0
                if straggler.observe(s, dt):
                    print(f"[straggler] step {s} took {dt:.2f}s "
                          f"(ewma {straggler.ewma:.2f}s)")
                if hb:
                    hb.beat(s)
                if mlog:
                    mlog.log(s, dt, {"loss": metrics["loss"],
                                     "grad_norm": metrics["grad_norm"]})
                if mgr and s % args.checkpoint_every == 0:
                    mgr.save(s, state, metadata={"arch": cfg.name})
                if s % args.log_every == 0:
                    print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            return state
        finally:
            pf.close()

    state, policy = run_supervised(
        loop, restore, RestartPolicy(max_restarts=args.max_restarts))
    if mgr:
        mgr.save(int(state["step"]), state, metadata={"final": True})
        mgr.wait()
    print(f"done at step {int(state['step'])} "
          f"(restarts: {policy.restarts})")
    return state


if __name__ == "__main__":
    main()
