"""Serving launcher: continuous-batching engine over the sharded steps.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --requests 8 --max-new 16

Reports per-token latency percentiles — the SLA the paper provisions for.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = lm.init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 17))),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    lat = []
    queue = list(reqs)
    done = []
    t_start = time.time()
    while queue or any(s is not None for s in engine.slots):
        while queue and engine.submit(queue[0]):
            queue.pop(0)
        t0 = time.time()
        done.extend(engine.step())
        lat.append(time.time() - t0)
    wall = time.time() - t_start

    toks = sum(len(r.generated) for r in done)
    lat_ms = np.array(lat) * 1e3
    print(f"served {len(done)} requests, {toks} tokens in {wall:.2f}s")
    if len(lat_ms):
        print(f"per-step latency ms: p50={np.percentile(lat_ms, 50):.1f} "
              f"p95={np.percentile(lat_ms, 95):.1f} "
              f"p99={np.percentile(lat_ms, 99):.1f}")
    print(f"throughput: {toks / wall:.1f} tok/s")
    return done


if __name__ == "__main__":
    main()
