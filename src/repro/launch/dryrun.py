import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (artifacts/dryrun/<mesh>/<arch>__<shape>.json):
  - proof of compilation on the production mesh (the deliverable),
  - memory_analysis (bytes per device: arguments/outputs/temps),
  - loop-correct cost measurements via two small unrolled probe compiles
    extrapolated to the full depth (see repro.core.roofline),
  - the collective schedule (op kinds, counts, ring bytes),
  - the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Usage:
  python -m repro.launch.dryrun --arch mamba2-1.3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, ARCH_IDS, cell_applicable, get_config
from repro.core import hlo as hlolib
from repro.core import roofline, traffic
from repro.dist import strategies
from repro.launch import specs
from repro.launch.mesh import make_production_mesh

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _probe_cfg(cfg, layers: int):
    """Unrolled, loop-free variant for loop-correct cost measurement."""
    return dataclasses.replace(cfg, num_layers=layers, scan_layers=False,
                               attn_impl="naive", fused_ce=False,
                               remat="none")


def _costs(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    coll = hlolib.collective_summary(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "ring_bytes": float(coll["total_ring_bytes"]),
        "collective_count": float(coll["total_count"]),
    }


def _memory(compiled) -> dict:
    m = compiled.memory_analysis()
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: int(getattr(m, k, 0)) for k in keys}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             *, probes: bool = True, cfg_override=None,
             strategy: str | None = None) -> dict:
    cfg = cfg_override or get_config(arch_id)
    shape = SHAPES[shape_name]
    rules_extra, cfg, strat_name = strategies.strategy_for(
        cfg, shape, strategy or "megatron")
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec: dict = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": int(chips), "kind": shape.kind,
        "strategy": strat_name,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped-by-design"
        rec["why"] = why
        return rec

    # --- full-scale compile (the runnability proof) -----------------------
    t0 = time.time()
    jitted, abstract = specs.build_step(cfg, shape, mesh,
                                        rules_extra=rules_extra)
    lowered = jitted.lower(*abstract)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["memory"] = _memory(compiled)
    full_coll = hlolib.collective_summary(compiled.as_text())
    rec["collective_schedule"] = full_coll["ops"]
    rec["status"] = "ok"

    # --- loop-correct cost probes -----------------------------------------
    if probes:
        p = len(cfg.block_pattern)
        cost_p = _costs(_compile_probe(cfg, shape, mesh, p, rules_extra))
        cost_2p = _costs(_compile_probe(cfg, shape, mesh, 2 * p,
                                        rules_extra))
        est = roofline.extrapolate(cost_p, cost_2p, cfg.num_layers, p)
        rec["probe_costs"] = {"p": cost_p, "2p": cost_2p, "est_full": est}

        # analytic TPU-faithful memory/collective terms (primary; the CPU
        # backend inflates bf16 byte counts — see core/traffic.py docstring)
        mshape = traffic.MeshShape.production(multi_pod)
        hbm = traffic.hbm_traffic(cfg, shape, mshape, strat_name)
        coll = traffic.collective_traffic(cfg, shape, mshape, strat_name)
        rec["analytic_hbm"] = hbm
        rec["analytic_collective"] = coll

        terms = roofline.terms(est["flops"], hbm["total"], coll["total"])
        rec["roofline"] = terms.to_dict()
        cpu_terms = roofline.terms(est["flops"], est["bytes"],
                                   est["ring_bytes"])
        rec["roofline_cpu_measured"] = cpu_terms.to_dict()
        mf = roofline.model_flops(cfg, shape)
        rec["utilization"] = roofline.utilization(terms, mf, chips)
    return rec


def _compile_probe(cfg, shape, mesh, layers: int, rules_extra=None):
    pc = _probe_cfg(cfg, layers)
    jitted, abstract = specs.build_step(pc, shape, mesh,
                                        rules_extra=rules_extra)
    return jitted.lower(*abstract).compile()


def cell_path(arch_id, shape_name, mesh_name, opt: bool = False) -> Path:
    d = ART / (f"{mesh_name}-opt" if opt else mesh_name)
    d.mkdir(parents=True, exist_ok=True)
    return d / f"{arch_id}__{shape_name}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="use the hillclimbed strategy per cell "
                         "(repro.dist.strategies.OPTIMIZED); results go to "
                         "artifacts/dryrun/<mesh>-opt/")
    ap.add_argument("--strategy", choices=tuple(strategies.STRATEGIES),
                    help="force one strategy for every requested cell")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    failures = []
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                opt = args.opt or bool(args.strategy)
                strategy = args.strategy
                if args.opt and not strategy:
                    strategy = strategies.OPTIMIZED.get((arch, shape))
                    if strategy is None:
                        continue   # --opt touches only hillclimbed cells
                path = cell_path(arch, shape, mesh_name, opt=opt)
                if path.exists() and not args.force:
                    print(f"[skip] {mesh_name}/{arch}/{shape} (cached)")
                    continue
                print(f"[run ] {mesh_name}/{arch}/{shape} "
                      f"strategy={strategy or 'megatron'} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_name == "multi",
                                   probes=not args.no_probes,
                                   strategy=strategy)
                except Exception as e:  # record, keep going
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append((mesh_name, arch, shape, repr(e)))
                path.write_text(json.dumps(rec, indent=1, default=str))
                print(f"[done] {mesh_name}/{arch}/{shape}: {rec['status']}"
                      + (f" compile={rec.get('compile_s')}s" if
                         rec.get("compile_s") else ""), flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nall requested cells ok")


if __name__ == "__main__":
    main()
