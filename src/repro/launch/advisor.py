"""Advisor CLI: the paper's provisioning questions from the command line.

  PYTHONPATH=src python -m repro.launch.advisor --arch llama3-405b \
      --batch 128 --seq 32768 --sla-ms 20
  PYTHONPATH=src python -m repro.launch.advisor --arch mixtral-8x22b \
      --power-kw 250
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_IDS, get_config
from repro.core import advisor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seq", type=int, default=32768)
    ap.add_argument("--sla-ms", type=float)
    ap.add_argument("--power-kw", type=float)
    ap.add_argument("--compare-host", action="store_true",
                    help="paper Fig. 3 for 2026: TPU vs DDR5-host cluster")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    wl = advisor.lm_decode_workload(cfg, args.batch, args.seq)
    print(f"# {args.arch} decode: batch={args.batch} seq={args.seq}")
    print(f"  resident bytes (params+cache): {wl.db_size/1e9:.1f} GB; "
          f"touched per token: {wl.bytes_accessed/1e9:.1f} GB "
          f"({wl.percent_accessed*100:.1f}%)")

    if args.sla_ms:
        a = advisor.advise_decode_sla(cfg, args.batch, args.seq,
                                      args.sla_ms / 1e3)
        print(f"  SLA {args.sla_ms:g} ms ->")
        print(json.dumps(a.summary(), indent=2, default=float))
    if args.power_kw:
        a = advisor.advise_power(cfg, args.batch, args.seq,
                                 args.power_kw * 1e3)
        print(f"  power budget {args.power_kw:g} kW ->")
        print(json.dumps(a.summary(), indent=2, default=float))
    if not args.sla_ms and not args.power_kw:
        a = advisor.advise_capacity(cfg, args.batch, args.seq)
        print("  capacity-provisioned ->")
        print(json.dumps(a.summary(), indent=2, default=float))
    if args.compare_host:
        print("  when-to-use (TPU vs DDR5 host):")
        for row in advisor.when_to_use_tpu(cfg, args.batch, args.seq):
            print(f"    SLA {row['sla_ms']:6.1f} ms: tpu "
                  f"{row['tpu_power_kw']:9.1f} kW vs host "
                  f"{row['host_power_kw']:9.1f} kW -> "
                  f"{'TPU' if row['tpu_wins_power'] else 'host'}")


if __name__ == "__main__":
    main()
