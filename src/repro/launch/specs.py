"""Abstract input specs (ShapeDtypeStruct) + shardings for every step kind.

This is the no-allocation layer the dry-run builds on: every model input,
train state, and decode cache is described by eval_shape and mapped to
NamedShardings through the logical-axis rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist import sharding as shlib
from repro.models import lm
from repro.models.common import dtype_of
from repro.serve import engine
from repro.train import optim, step as train_step_lib


def rules_for(cfg: ArchConfig, shape: ShapeSpec,
              extra: dict | None = None) -> dict:
    """Per-cell logical-axis rule overrides (see DESIGN.md §4).

    `extra` (a strategy's overrides, repro.dist.strategies) wins last.
    """
    rules: dict = {}
    # FSDP over data (+pod when present) — needed to fit >=100B optimizer
    # state; harmless elsewhere.
    rules["embed"] = ("data", "pod")
    if shape.name == "long_500k":
        # batch=1: the data axis is useless for batch; use it for split-K
        # over the KV ring / sequence instead.
        rules["batch"] = None
        rules["kv_seq"] = ("data", "model")
    rules.update(extra or {})
    return rules


def batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Training batch abstract values + logical axes."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        in_axes = "batch seq"
    else:
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                      dtype_of(cfg.dtype))
        in_axes = "batch seq act_embed"
    return ({"inputs": inputs,
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)},
            {"inputs": in_axes, "labels": "batch seq"})


def state_specs(cfg: ArchConfig, opt_cfg: optim.AdamWConfig):
    key = jax.random.PRNGKey(0)
    captured = {}

    def f(k):
        st, ax = train_step_lib.init_state(k, cfg, opt_cfg)
        captured["axes"] = ax
        return st

    state = jax.eval_shape(f, key)
    return state, captured["axes"]


def params_specs(cfg: ArchConfig):
    captured = {}

    def f(k):
        p, a = lm.init(k, cfg)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["axes"]


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    captured = {}

    def f():
        c, a = lm.init_caches(cfg, batch, max_len, dtype_of(cfg.dtype))
        captured["axes"] = a
        return c

    shapes = jax.eval_shape(f)
    return shapes, captured["axes"]


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    b = shape.global_batch
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        in_axes = "batch seq"
    else:
        inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dtype_of(cfg.dtype))
        in_axes = "batch seq act_embed"
    return inputs, in_axes


def shardings(tree, axes, mesh, rules):
    return shlib.sharding_tree(tree, axes, mesh, rules)


def replicated(mesh):
    from jax.sharding import PartitionSpec as P
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# step builders used by dryrun / train / serve launchers
# --------------------------------------------------------------------------

def build_train(cfg, shape, mesh, opt_cfg=None, num_microbatches: int = 1,
                rules_extra: dict | None = None):
    """Returns (jitted_fn, abstract_args) for train_step(state, batch)."""
    opt_cfg = opt_cfg or optim.AdamWConfig()
    rules = rules_for(cfg, shape, rules_extra)
    state, state_axes = state_specs(cfg, opt_cfg)
    batch, batch_axes = batch_specs(cfg, shape)
    state_sh = shardings(state, state_axes, mesh, rules)
    batch_sh = shardings(batch, batch_axes, mesh, rules)
    fn = train_step_lib.make_train_step(cfg, opt_cfg, num_microbatches)

    def wrapped(state, batch):
        with shlib.use_rules(mesh, rules):
            return fn(state, batch)

    jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    return jitted, (state, batch)


def build_prefill(cfg, shape, mesh, rules_extra: dict | None = None):
    """prefill_step(params, inputs, caches) -> (last logits, caches)."""
    rules = rules_for(cfg, shape, rules_extra)
    b, s = shape.global_batch, shape.seq_len
    params, p_axes = params_specs(cfg)
    caches, c_axes = cache_specs(cfg, b, s)
    batch, batch_axes = batch_specs(cfg, shape)
    p_sh = shardings(params, p_axes, mesh, rules)
    c_sh = shardings(caches, c_axes, mesh, rules)
    in_sh = shardings(batch["inputs"], batch_axes["inputs"], mesh, rules)
    fn = engine.make_prefill_step(cfg)

    def wrapped(params, inputs, caches):
        with shlib.use_rules(mesh, rules):
            return fn(params, inputs, caches)

    jitted = jax.jit(wrapped, in_shardings=(p_sh, in_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
    return jitted, (params, batch["inputs"], caches)


def build_serve(cfg, shape, mesh, rules_extra: dict | None = None):
    """serve_step(params, inputs, cache_len, caches, key)."""
    rules = rules_for(cfg, shape, rules_extra)
    b, s = shape.global_batch, shape.seq_len
    params, p_axes = params_specs(cfg)
    caches, c_axes = cache_specs(cfg, b, s)
    inputs, in_axes = decode_input_specs(cfg, shape)
    p_sh = shardings(params, p_axes, mesh, rules)
    c_sh = shardings(caches, c_axes, mesh, rules)
    in_sh = shardings(inputs, in_axes, mesh, rules)
    len_sh = shardings(jax.ShapeDtypeStruct((b,), jnp.int32), "batch",
                       mesh, rules)
    fn = engine.make_serve_step(cfg)

    def wrapped(params, inputs, cache_len, caches, key):
        with shlib.use_rules(mesh, rules):
            return fn(params, inputs, cache_len, caches, key)

    jitted = jax.jit(wrapped,
                     in_shardings=(p_sh, in_sh, len_sh, c_sh, replicated(mesh)),
                     out_shardings=(None, None, c_sh), donate_argnums=(3,))
    abstract = (params, inputs,
                jax.ShapeDtypeStruct((b,), jnp.int32), caches,
                jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jitted, abstract


def build_step(cfg, shape, mesh, rules_extra: dict | None = None, **kw):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, rules_extra=rules_extra, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, rules_extra=rules_extra)
    return build_serve(cfg, shape, mesh, rules_extra=rules_extra)
