"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh stacks 2 pods on a
    leading "pod" axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (host devices or real)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
