"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; plain meshes behave identically
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    _AXIS_KW = lambda n: {}


def _make(shape, axes):
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))
    import math

    import numpy as np
    from jax.sharding import Mesh
    n = math.prod(shape)
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh stacks 2 pods on a
    leading "pod" axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (host devices or real)."""
    return _make(tuple(shape), tuple(axes))
