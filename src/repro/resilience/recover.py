"""Recovery machinery the injected faults exercise.

Three recoveries, one contract — a query's answer is bit-exact vs the
numpy oracle or the query fails with a *typed* error; nothing in between
(no wrapped, partial, or silently-degraded sums):

- `ChunkGuard`: verify-on-read for the store's checksummed chunks.
  A failed checksum quarantines the chunk and either re-encodes it from
  the oracle replica (the durable capacity-tier copy captured at guard
  construction) or raises `ChunkCorruptionError` when repair is off.
- `execute_degraded`: shard failover. A lost shard's row range is
  re-executed from the capacity-tier (host) copy through the same
  kernel-dispatch operators and merged with the surviving shards'
  partials in exact host ints — aggregates decompose exactly over row
  ranges, so the merged answer equals the all-shards psum bit for bit.
  All shards lost raises `DegradedResultError`; a zero-row table
  degrades to the canonical aggregate identity.
- `CircuitBreaker`: a repeatedly-faulting fast tier is demoted to
  capacity-tier *service* (PlacementEngine.demoted) — placement state
  (LRU clocks, MEMCACHE frequency counters, ghost bits) keeps evolving
  so the tier rejoins warm when the breaker closes.
"""
from __future__ import annotations

import math

import numpy as np

from repro.query import physical
from repro.store.exec import fixup_base, identity_ints


class DegradedResultError(RuntimeError):
    """A query could not produce its full, exact answer (shards lost
    beyond recovery, corruption without repair). Raised instead of ever
    returning a partial or wrapped aggregate."""


class ChunkCorruptionError(DegradedResultError):
    """A stored chunk failed its checksum and repair is disabled."""


# --------------------------------------------------------------------------
# circuit breaker: demote a faulting fast tier
# --------------------------------------------------------------------------
class CircuitBreaker:
    """CLOSED -> OPEN after `fail_threshold` consecutive fast-tier faults.

    OPEN serves every read from the capacity tier for `cooldown_s` of
    modeled time, then HALF-OPEN lets one access probe the fast tier —
    a clean read closes the breaker, a fault re-opens it. All times come
    from the engine's clock (VirtualClock under chaos), so breaker
    behavior is deterministic and replayable.
    """

    def __init__(self, fail_threshold: int = 4, cooldown_s: float = 0.05):
        if fail_threshold < 1:
            raise ValueError(f"fail_threshold={fail_threshold} must be "
                             f">= 1")
        if not math.isfinite(cooldown_s) or cooldown_s <= 0:
            raise ValueError(f"cooldown_s={cooldown_s} must be a finite "
                             f"positive duration")
        self.fail_threshold = int(fail_threshold)
        self.cooldown_s = float(cooldown_s)
        self.state = "closed"
        self.consecutive_faults = 0
        self.opened_at: float | None = None
        self.opens = 0

    def allow_fast(self, now: float) -> bool:
        """May the next access be served from the fast tier?"""
        if self.state == "open":
            if now - self.opened_at >= self.cooldown_s:
                self.state = "half-open"
                return True
            return False
        return True

    def record_fault(self, now: float) -> None:
        self.consecutive_faults += 1
        if (self.state == "half-open"
                or self.consecutive_faults >= self.fail_threshold):
            if self.state != "open":
                self.opens += 1
            self.state = "open"
            self.opened_at = now
            self.consecutive_faults = 0

    def record_ok(self, now: float) -> None:
        self.consecutive_faults = 0
        if self.state == "half-open":
            self.state = "closed"

    def summary(self) -> dict:
        return {"state": self.state, "opens": self.opens,
                "fail_threshold": self.fail_threshold,
                "cooldown_s": self.cooldown_s}


# --------------------------------------------------------------------------
# chunk integrity: verify-on-read, quarantine, re-encode from oracle
# --------------------------------------------------------------------------
class ChunkGuard:
    """Checksum verification + repair for a store.EncodedTable.

    The oracle is the exact logical codes of every column, captured at
    construction — i.e. *before* any fault is injected — standing in for
    the durable capacity-tier replica a production system re-reads when
    a fast-tier copy rots. Repair re-encodes the chunk's row range from
    the oracle (selector re-applied, checksum re-sealed) and the caller
    charges the re-read bytes as capacity-tier recovery traffic.
    """

    def __init__(self, table, repair: bool = True):
        if not getattr(table, "columns", None) or \
                not hasattr(table, "chunk_rows"):
            raise ValueError(
                "ChunkGuard needs a repro.store.EncodedTable with at "
                "least one encoded column (checksums live on "
                "EncodedChunk payloads)")
        self.table = table
        self.repair = bool(repair)
        self.oracle = {name: col.decode()
                       for name, col in table.columns.items()}
        self.quarantined: list[tuple[str, int]] = []
        self.repaired: list[tuple[str, int]] = []
        self.repair_logical_bytes_total = 0

    def chunk_ids(self) -> list[tuple[str, int]]:
        return [(name, ci) for name, col in self.table.columns.items()
                for ci in range(len(col.chunks))]

    def check(self, ids, repair: bool | None = None) -> list:
        """Verify the given (column, chunk-index) ids. Corrupt chunks are
        quarantined and — with repair on — re-encoded from the oracle;
        returns [((column, ci), capacity_bytes_reread)]. With repair off
        the first corrupt chunk raises ChunkCorruptionError: detection
        always happens, silent aggregation never does."""
        do_repair = self.repair if repair is None else bool(repair)
        out = []
        for name, ci in ids:
            col = self.table.columns[name]
            ch = col.chunks[ci]
            if ch.verify():
                continue
            self.quarantined.append((name, ci))
            if not do_repair:
                raise ChunkCorruptionError(
                    f"chunk ({name!r}, {ci}) failed its checksum "
                    f"(stored {ch.checksum:#010x}, payload "
                    f"{ch.payload_checksum():#010x}) and repair is "
                    f"disabled; refusing to aggregate corrupt bytes")
            from repro.store.encode import encode_chunk
            lo = ci * col.chunk_rows
            hi = min(lo + col.chunk_rows, col.num_rows)
            col.chunks[ci] = encode_chunk(self.oracle[name][lo:hi],
                                          col.code_bits)
            nb = col.chunks[ci].logical_nbytes
            self.repaired.append((name, ci))
            self.repair_logical_bytes_total += nb
            out.append(((name, ci), nb))
        return out

    def scrub(self, repair: bool | None = None) -> list:
        """Whole-table integrity pass (background scrubber / tests)."""
        return self.check(self.chunk_ids(), repair=repair)

    def summary(self) -> dict:
        return {"chunks": len(self.chunk_ids()),
                "quarantined": len(self.quarantined),
                "repaired": len(self.repaired),
                "repair_bytes": self.repair_logical_bytes_total}


# --------------------------------------------------------------------------
# degraded-mode sharded execution
# --------------------------------------------------------------------------
def _merge(total: dict, part: dict) -> None:
    total["sum"] += part["sum"]
    total["count"] += part["count"]
    total["min"] = min(total["min"], part["min"])
    total["max"] = max(total["max"], part["max"])


def execute_degraded(table, plan, aggregates, lost, mode=None
                     ) -> tuple[dict, int]:
    """Execute a query with `lost` shard indices unavailable.

    Surviving shards contribute their per-shard partials (the same
    kernel path as the psum combine, finalized per shard); each lost
    shard's row range is re-executed from the capacity-tier host copy.
    Returns (aggregates, recovered_bytes) where recovered_bytes is the
    device-resident bytes the re-execution re-streamed from the
    capacity tier. Bit-exact vs the fault-free execution by
    construction: aggregates decompose exactly over row ranges.

    Raises DegradedResultError when every shard is lost (there is no
    surviving device to re-execute on); a zero-row table returns the
    canonical aggregate identity on every path.
    """
    aggregates = tuple(aggregates)
    n = table.n_shards
    lost = sorted(set(int(i) for i in lost))
    if any(i < 0 or i >= n for i in lost):
        raise ValueError(f"lost shard ids {lost} outside [0, {n})")
    if len(lost) >= n:
        raise DegradedResultError(
            f"all {n} shards lost; no surviving device can re-execute "
            f"the lost row ranges — the query has no exact answer")
    frames = getattr(table, "frames", None)
    inner = table.inner if frames is not None else table
    # raw-domain plan: the delta view translates predicates into each
    # column's frame; a plain ShardedTable executes the plan as-is
    if frames is not None:
        from repro.store.exec import translate_plan
        raw_plan = translate_plan(plan, frames)
    else:
        raw_plan = plan
    parts = inner.execute_partials(raw_plan, aggregates, mode=mode)
    referenced = inner._referenced(raw_plan, aggregates)
    recovered_bytes = 0
    for i in lost:
        lo, hi = inner.shard_row_range(i)
        if hi <= lo:
            parts[i] = {a: identity_ints(inner.slices[a].code_bits)
                        for a in aggregates}
        else:
            slices = inner.host_shard_slices(i, names=referenced)
            parts[i] = physical.finalize_aggs(physical.execute(
                raw_plan, aggregates, slices, mode=mode))
        recovered_bytes += sum(
            int(inner.slices[c].words.size) * 4 // n for c in referenced)
    out = {a: identity_ints(inner.slices[a].code_bits)
           for a in aggregates}
    for part in parts:
        for a in aggregates:
            _merge(out[a], part[a])
    if frames is not None:
        out = {a: fixup_base(out[a], frames[a][0],
                             table.store.columns[a].code_bits)
               for a in aggregates}
    return out, recovered_bytes


def execute_grouped_degraded(table, query, lost, mode=None
                             ) -> tuple[dict, int]:
    """GroupBy/HashJoin failover: surviving shards contribute their
    per-shard accumulator planes (execute_grouped_planes, the same
    kernel path the all-gather combine uses), each lost shard's row
    range is re-aggregated from the capacity-tier host copy in exact
    numpy ints, and everything merges through the associative host
    partial algebra — bit-exact vs the fault-free grouped execution by
    construction. All shards lost raises DegradedResultError; domains
    past the dense cutoff recover via the host oracle (counted as
    group_aggregate_fallback launches)."""
    from repro.kernels import dispatch
    from repro.kernels.scan_filter import ref as packref
    from repro.query import relational
    n = table.n_shards
    lost = sorted(set(int(i) for i in lost))
    if any(i < 0 or i >= n for i in lost):
        raise ValueError(f"lost shard ids {lost} outside [0, {n})")
    if len(lost) >= n:
        raise DegradedResultError(
            f"all {n} shards lost; no surviving device can re-execute "
            f"the lost row ranges — the query has no exact answer")
    frames = getattr(table, "frames", None)
    inner = table.inner if frames is not None else table
    key = query.key
    kbase = frames[key][0] if frames is not None else 0
    if frames is not None:
        from repro.store.exec import translate_plan
        raw_plan = translate_plan(query.plan(), frames)
    else:
        raw_plan = query.plan()
    referenced = inner._referenced(raw_plan, tuple(query.aggs) + (key,))
    recovered_bytes = len(lost) * sum(
        int(inner.slices[c].words.size) * 4 // n for c in referenced)
    dmin, dmax = inner.key_code_range(key)
    if dmax < dmin:
        return relational.empty_result(), recovered_bytes
    domain = relational.group_domain(query, kbase + dmin, kbase + dmax)
    if len(domain) == 0:
        return relational.empty_result(), recovered_bytes
    if not relational.dense_ok(domain):
        dispatch.count_launch("group_aggregate_fallback", n)
        host = table.store.decode_table() if frames is not None \
            else table.table
        return (relational.execute_grouped_oracle(query, host),
                recovered_bytes)
    raw_domain = np.asarray(domain) - kbase
    planes = inner.execute_grouped_planes(raw_plan, key,
                                          tuple(query.aggs), raw_domain,
                                          mode=mode)
    first = query.aggs[0] if query.aggs else ""
    part = relational.new_partial()
    lost_set = set(lost)
    for name, stack in planes.items():
        vbase = frames[name][0] if (frames is not None and name) else 0
        for i in range(stack.shape[0]):
            if i in lost_set:
                continue
            relational.absorb_plane(part, raw_domain, stack[i],
                                    name or None, base=vbase,
                                    key_base=kbase,
                                    count_source=(name == first))
    dom = np.asarray(domain, np.int64)
    for i in lost:
        lo, hi = inner.shard_row_range(i)
        if hi <= lo:
            continue
        slices = inner.host_shard_slices(i, names=referenced)
        cols = {}
        for cname in referenced:
            s = slices[cname]
            cols[cname] = np.asarray(packref.unpack(
                s.words, s.code_bits)).astype(np.int64)[: hi - lo]
        sel = np.asarray(relational.eval_plan_codes(raw_plan, cols))
        keys_log = cols[key] + kbase
        sel = sel & np.isin(keys_log, dom)
        vals_log = {a: cols[a] + (frames[a][0] if frames is not None
                                  else 0) for a in query.aggs}
        relational.absorb_fallback(part, keys_log, vals_log, sel)
    return relational.finalize(part), recovered_bytes
