"""Deterministic seeded fault injection on the modeled time axis.

Every provisioning answer upstream assumes a fault-free machine; this
module makes the machine lie on purpose, reproducibly. A `FaultInjector`
draws every fault decision from a generator keyed by *(seed, event key)*
through `numpy.random.SeedSequence`, so the fault stream is a pure
function of the spec — independent of execution order, retries, or how
many other faults fired first. Replaying the same trace with the same
seed injects byte-identical faults (examples/chaos_replay.py), and fault
timing rides the `serve.sla.VirtualClock`: stalls are modeled service
penalties, never wall-clock sleeps.

Fault classes (all optional, rates in [0, 1]):

- *tier-read stalls / stragglers*: a fast-tier chunk read takes
  `stall_factor` x its nominal time (a flaky stack channel / row-hammer
  refresh storm) — the fault the RetryPolicy and CircuitBreaker exist
  for;
- *shard dropout*: a query arrives while one shard of the mesh is gone;
  degraded execution re-runs that shard's rows from the capacity tier
  (repro.resilience.recover) or the query fails typed;
- *chunk payload corruption*: a bit flips in a stored compressed chunk
  (repro.store); per-chunk checksums detect it on read — corruption is
  never silently aggregated;
- *torn file writes*: a heartbeat or tune-cache file is truncated
  mid-write (`tear_file`) — the reader-side contract is that a torn file
  reads as missing/miss, never as garbage.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, fields
from pathlib import Path

import numpy as np


def _key_ints(parts: tuple) -> list[int]:
    """Stable uint32 words from a mixed (str/int) event key — crc32 for
    strings so the entropy is platform- and run-independent (Python's
    hash() is salted per process and would break replay)."""
    out = []
    for p in parts:
        if isinstance(p, str):
            out.append(zlib.crc32(p.encode()))
        elif isinstance(p, (int, np.integer)):
            out.append(int(p) & 0xFFFFFFFF)
        else:
            raise TypeError(f"fault event keys are strings and ints, got "
                            f"{type(p).__name__!r}")
    return out


@dataclass(frozen=True)
class FaultSpec:
    """Rates and shapes of the injected fault classes (one seed drives
    every draw; rate 0.0 disables a class)."""

    seed: int = 0
    stall_rate: float = 0.0        # P[a fast-tier chunk read stalls]
    stall_factor: float = 8.0      # stalled read takes factor x nominal
    corrupt_rate: float = 0.0      # P[a stored chunk has a flipped bit]
    shard_loss_rate: float = 0.0   # P[a query sees one shard dropped]

    def __post_init__(self):
        for f in ("stall_rate", "corrupt_rate", "shard_loss_rate"):
            v = getattr(self, f)
            if not (math.isfinite(v) and 0.0 <= v <= 1.0):
                raise ValueError(f"{f}={v} must be a probability in [0, 1]")
        if not math.isfinite(self.stall_factor) or self.stall_factor < 1.0:
            raise ValueError(
                f"stall_factor={self.stall_factor} must be >= 1; a stall "
                f"that finishes early is not a fault")

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


class FaultInjector:
    """Draws every fault decision of a chaos run from `FaultSpec.seed`.

    Each event gets its own generator seeded by (seed, event key), so
    decisions commute: whether chunk A's read stalls does not depend on
    whether chunk B was checked first, and a replay probes the same
    stream in any order.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    def _rng(self, *key) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.spec.seed & 0xFFFFFFFF,
                                    *_key_ints(key)]))

    # --- tier-read stalls -------------------------------------------------
    def stalled(self, qid: int, cid: tuple, attempt: int) -> bool:
        """Does read `attempt` of chunk `cid` by query `qid` stall?
        Retries re-draw (a straggling channel usually recovers)."""
        if self.spec.stall_rate <= 0.0:
            return False
        r = self._rng("stall", qid, cid[0], cid[1], attempt)
        return bool(r.random() < self.spec.stall_rate)

    # --- shard dropout ----------------------------------------------------
    def lost_shards(self, qid: int, n_shards: int) -> tuple[int, ...]:
        """Shard indices missing while query `qid` executes (at most one
        per query — correlated multi-shard loss is a test-only scenario
        exercised through recover.execute_degraded directly)."""
        if self.spec.shard_loss_rate <= 0.0 or n_shards <= 1:
            return ()
        r = self._rng("shard", qid)
        if r.random() >= self.spec.shard_loss_rate:
            return ()
        return (int(r.integers(n_shards)),)

    # --- stored-chunk corruption ------------------------------------------
    def corrupt_chunks(self, ids) -> list:
        """The subset of (column, chunk-index) ids whose payload gets a
        flipped bit — decided per chunk, independent of list order."""
        if self.spec.corrupt_rate <= 0.0:
            return []
        out = []
        for name, ci in ids:
            if self._rng("corrupt", name, ci).random() \
                    < self.spec.corrupt_rate:
                out.append((name, ci))
        return out

    def flip_bit(self, chunk, name: str, ci: int) -> bool:
        """Flip one payload bit of a store.encode.EncodedChunk in place
        (device array updated functionally). Returns False for chunks
        with no payload to corrupt (zero rows)."""
        import jax.numpy as jnp
        r = self._rng("flip", name, ci)
        if chunk.values is not None and chunk.values.size:
            i = int(r.integers(chunk.values.size))
            bit = jnp.int32(1 << int(r.integers(30)))
            chunk.values = chunk.values.at[i].set(chunk.values[i] ^ bit)
            return True
        if chunk.words is not None and chunk.words.size:
            i = int(r.integers(chunk.words.size))
            bit = jnp.uint32(1 << int(r.integers(31)))
            chunk.words = chunk.words.at[i].set(chunk.words[i] ^ bit)
            return True
        return False

    # --- torn file writes -------------------------------------------------
    def tear_file(self, path, event: str = "tear") -> bool:
        """Truncate `path` at a seeded fraction of its length — the torn
        write a crashed host leaves behind when it writes in place
        instead of mkstemp + os.replace. Returns False on empty/missing
        files (nothing to tear)."""
        p = Path(path)
        if not p.exists():
            return False
        raw = p.read_bytes()
        if not raw:
            return False
        r = self._rng(event, str(p.name))
        p.write_bytes(raw[:int(r.integers(len(raw)))])
        return True
