"""SLA-aware retry for tiered chunk reads: timeout, capped backoff, budget.

A stalled fast-tier read can either ride to completion (stall_factor x
nominal — the no-recovery baseline) or be abandoned at `timeout_s`,
backed off, and re-issued. Every re-issued read is *real traffic*: its
bytes are charged into the PlacementEngine ledger and the EnergyMeter,
and its joules land in the PowerCap window, so retrying under load costs
watts the governor sees. The policy is also priced at admission
(ChaosHarness.inflate_estimate): a query whose retry-inflated service
estimate no longer fits its deadline or watt budget is rejected at
submit — the SLA story stays honest under faults.

Backoff is capped exponential: attempt k waits
`min(backoff_s * growth**k, backoff_cap_s)`. `max_retries` bounds the
per-chunk re-issue budget; an exhausted budget fails over to the
capacity tier (the durable copy), which this model treats as stable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, fields


@dataclass(frozen=True)
class RetryPolicy:
    """Per-chunk-read retry contract on the modeled clock."""

    timeout_s: float               # abandon a stalled read after this
    backoff_s: float = 0.0         # base backoff before re-issue
    backoff_cap_s: float = math.inf
    growth: float = 2.0            # exponential base
    max_retries: int = 3           # re-issues per chunk before failover

    def __post_init__(self):
        if not math.isfinite(self.timeout_s) or self.timeout_s <= 0:
            raise ValueError(f"timeout_s={self.timeout_s} must be a finite "
                             f"positive duration")
        if not math.isfinite(self.backoff_s) or self.backoff_s < 0:
            raise ValueError(f"backoff_s={self.backoff_s} must be finite "
                             f"and non-negative")
        if math.isnan(self.backoff_cap_s) or self.backoff_cap_s < 0:
            raise ValueError(f"backoff_cap_s={self.backoff_cap_s} must be "
                             f"non-negative (inf = uncapped)")
        if not math.isfinite(self.growth) or self.growth < 1.0:
            raise ValueError(f"growth={self.growth} must be >= 1")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries} must be >= 0")

    def backoff(self, attempt: int) -> float:
        """Seconds to wait before re-issue number `attempt` (0-based)."""
        if attempt < 0:
            raise ValueError(f"attempt={attempt} must be >= 0")
        return min(self.backoff_s * self.growth ** attempt,
                   self.backoff_cap_s)

    def worst_case_extra_s(self) -> float:
        """Upper bound on extra modeled seconds one chunk's recovery can
        cost: every attempt times out, every backoff is taken, and the
        read fails over (capacity read priced by the caller)."""
        budget = self.max_retries
        return budget * self.timeout_s + sum(
            self.backoff(k) for k in range(budget))

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}
