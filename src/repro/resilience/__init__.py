"""repro.resilience: deterministic chaos + the recovery it exercises.

The paper's 10 ms SLA verdict is only as good as its worst fault: this
package injects seeded, replayable faults on the modeled clock
(faults.FaultInjector), and supplies the recovery machinery — checksum
verify-on-read with re-encode-from-oracle repair (recover.ChunkGuard),
SLA-aware retry/backoff with failover (retry.RetryPolicy), degraded-mode
shard re-execution (recover.execute_degraded), and a circuit breaker
demoting a faulting fast tier (recover.CircuitBreaker) — wired into the
query path by harness.ChaosHarness via QueryEngine(chaos=...).
"""
from repro.resilience.faults import FaultInjector, FaultSpec
from repro.resilience.harness import ChaosHarness
from repro.resilience.recover import (ChunkCorruptionError, ChunkGuard,
                                      CircuitBreaker, DegradedResultError,
                                      execute_degraded)
from repro.resilience.retry import RetryPolicy

__all__ = [
    "ChaosHarness",
    "ChunkCorruptionError",
    "ChunkGuard",
    "CircuitBreaker",
    "DegradedResultError",
    "FaultInjector",
    "FaultSpec",
    "RetryPolicy",
    "execute_degraded",
]
