"""ChaosHarness: the fault-injected query path, end to end.

Owns the per-query choreography the QueryEngine delegates to when
`chaos=` is set: circuit-breaker gating of the fast tier, checksum
verify-on-read (through the store executor), shard-loss failover
(recover.execute_degraded), nominal tier charging, and the stall /
retry / failover time model for every fast-tier chunk read. Everything
runs on the engine's VirtualClock from seeded draws — a chaos run is a
pure function of (workload, FaultSpec, RetryPolicy), replayable bit for
bit (examples/chaos_replay.py).

Accounting contract (the property tests pin this down):

- the nominal access is charged exactly once (PlacementEngine.on_access,
  kind="query"), covering one clean read of every chunk;
- every *extra* byte recovery streams — re-issued reads after a timeout,
  capacity-tier failover, oracle re-reads for chunk repair, lost-shard
  re-execution — lands in exactly one kind="recovery" ledger line per
  query; retries never double-charge;
- extra modeled seconds are `total_time - one_clean_read` per chunk, so
  a fault-free run charges zero extras and is bit-identical to the
  plain tiered path.
"""
from __future__ import annotations

from repro.query.plan import is_grouped
from repro.resilience.faults import FaultInjector, FaultSpec
from repro.resilience.recover import (ChunkCorruptionError, ChunkGuard,
                                      CircuitBreaker, DegradedResultError,
                                      execute_degraded,
                                      execute_grouped_degraded)
from repro.resilience.retry import RetryPolicy


class ChaosHarness:
    """Fault injection + recovery policy bundle for one QueryEngine.

    `recover=False` keeps the faults but disables every recovery: stalls
    ride to completion, corruption raises typed, lost shards fail the
    query — the no-recovery baseline BENCH_resilience compares against.
    """

    # reserved stall-draw slot for prefetch streams: far above any retry
    # policy's attempt numbers, so stream-stall draws are independent of
    # (and never aliased with) the fast-read stall draws per chunk
    PREFETCH_ATTEMPT = 1 << 20

    def __init__(self, spec: FaultSpec | FaultInjector, *,
                 retry: RetryPolicy | None = None,
                 breaker: CircuitBreaker | None = None,
                 guard: ChunkGuard | None = None,
                 recover: bool = True):
        self.injector = (spec if isinstance(spec, FaultInjector)
                         else FaultInjector(spec))
        self.spec = self.injector.spec
        self.retry = retry
        self.breaker = breaker
        self.guard = guard
        self.recover = bool(recover)
        if self.guard is not None:
            self.guard.repair = self.recover
        # fault/recovery counters (summary + modeled MTTR)
        self.stalls = 0
        self.prefetch_stalls = 0     # capacity->fast streams that stalled
        self.retries = 0
        self.failovers = 0
        self.repairs = 0
        self.shard_losses = 0
        self.shard_recoveries = 0
        self.failures = 0            # queries that ended typed-degraded
        self._recovered_faults = 0
        self._recovery_s = 0.0

    # --- fault application (setup-time) -----------------------------------
    def inject_corruption(self) -> list:
        """Flip one seeded payload bit in each chunk the injector picks
        (requires a ChunkGuard — its oracle was captured pre-corruption).
        Returns the corrupted (column, chunk-index) ids."""
        if self.guard is None:
            raise ValueError("corruption injection needs guard=ChunkGuard "
                             "(the repair oracle must be captured before "
                             "any bit flips)")
        out = []
        for name, ci in self.injector.corrupt_chunks(self.guard.chunk_ids()):
            ch = self.guard.table.columns[name].chunks[ci]
            if self.injector.flip_bit(ch, name, ci):
                out.append((name, ci))
        return out

    # --- admission --------------------------------------------------------
    def inflate_estimate(self, est_s: float, n_chunks: int) -> float:
        """Fold first-order expected recovery overhead into the admission
        service estimate: a query whose retry-inflated estimate no longer
        fits its deadline (or, downstream, its watt budget) is rejected
        at submit instead of missing after the fact."""
        p = self.spec.stall_rate
        if p <= 0.0:
            return est_s
        if not (self.recover and self.retry is not None):
            # stalls ride to completion: expected slowdown on the stalled
            # fraction of the stream
            return est_s * (1.0 + p * (self.spec.stall_factor - 1.0))
        # with retries: each stalled read is abandoned near timeout_s and
        # re-issued; E[abandons per chunk] = p/(1-p) (geometric)
        exp_abandons = p / max(1.0 - p, 1e-9)
        per_chunk = exp_abandons * (self.retry.timeout_s
                                    + self.retry.backoff(0))
        return est_s * (1.0 + exp_abandons) + max(n_chunks, 1) * per_chunk

    # --- the fault-injected query path ------------------------------------
    def run_query(self, engine, pend, t0: float, trace=None):
        """Execute one admitted query under injected faults.

        Returns (aggs | None, access, busy_s, query_j, error | None):
        `busy_s` is nominal tiered service plus recovery extras, `query_j`
        the nominal charge plus the recovery line, `error` a typed
        degraded message (aggs is None exactly when error is set).

        `trace` (obs.trace.QueryTrace) gets the recovery span tree on top
        of the nominal reads: repair / shard_failover / stall / retry /
        failover / prefetch_stall spans whose byte sums are exactly the
        (extra_fast_b, extra_cap_b) this method folds into its single
        kind="recovery" ledger line — the conservation the obs.audit
        checker proves per query.
        """
        pe = engine.tiered
        chips = engine.n_shards
        error = None
        extra_s = 0.0
        extra_fast_b = 0
        extra_cap_b = 0
        rec_events = []   # (kind, bytes, seconds, attrs) gathered during
        #                   execution, laid out after the nominal reads
        # 1. circuit breaker gates the fast tier for this access
        if self.breaker is not None:
            pe.demoted = not self.breaker.allow_fast(t0)
        # 2. snapshot which chunk reads hit the fast tier *before*
        #    on_access mutates placement — stalls afflict only those
        #    (the capacity tier is the durable, stable failover target)
        if pe.demoted:
            fast_cids = {}
        else:
            fast_cids = {cid: b for cid, b in pend.chunks.items()
                         if pe.resident(cid)}
        # prefetch (if the engine carries a pipeline) plans against the
        # same pre-access residency; capacity->fast streams can stall too
        # — a seeded draw at a reserved attempt slot, independent of the
        # fast-read stall draws below — and a stalled stream degrades its
        # chunk to the synchronous path (never a wrong answer)
        pplan = None
        if engine.prefetch is not None:
            pplan = engine.prefetch.plan(
                pend.chunks, chips=chips,
                stalled=lambda cid: self.injector.stalled(
                    pend.qid, cid, self.PREFETCH_ATTEMPT))
            engine.prefetch.begin(pplan, pend.chunks)
        # 3. execute — verify-on-read + repair (store tables) or shard
        #    failover (sharded tables); typed errors, never silent
        aggs = None
        repaired_b0 = (self.guard.repair_logical_bytes_total
                       if self.guard is not None else 0)
        repaired_n0 = len(self.guard.repaired) if self.guard else 0
        lost = (self.injector.lost_shards(pend.qid, chips)
                if engine.sharded else ())
        try:
            if lost:
                self.shard_losses += 1
                if not self.recover:
                    raise DegradedResultError(
                        f"shard {lost[0]} lost during qid={pend.qid} and "
                        f"recovery is disabled")
                if is_grouped(pend.query):
                    aggs, rec_b = execute_grouped_degraded(
                        engine.table, pend.query, lost, mode=engine.mode)
                else:
                    aggs, rec_b = execute_degraded(
                        engine.table, pend.query.plan(),
                        pend.query.aggregates, lost, mode=engine.mode)
                extra_cap_b += rec_b
                rs = pe.tiers.service_s(0, rec_b, chips)
                extra_s += rs
                self._recovered(rs)
                self.shard_recoveries += 1
                rec_events.append(("shard_failover", rec_b, rs,
                                   {"shards": tuple(lost)}))
            else:
                aggs = engine._execute(pend.query)
        except DegradedResultError as e:
            error = str(e)
            self.failures += 1
        if self.guard is not None:
            rb = self.guard.repair_logical_bytes_total - repaired_b0
            if rb:
                # repair re-read the oracle bytes from the capacity tier
                extra_cap_b += rb
                rs = pe.tiers.service_s(0, rb, chips)
                extra_s += rs
                self._recovered(rs)
                n_rep = len(self.guard.repaired) - repaired_n0
                self.repairs += n_rep
                rec_events.append(("repair", rb, rs, {"chunks": n_rep}))
        # 4. nominal access: charged once whether or not the query
        #    degraded — the bytes streamed up to the failure either way;
        #    with a prefetch pipeline the busy time is the pipelined
        #    (stall-degraded) service, the byte charge is unchanged
        acc = pe.on_access(pend.chunks, qid=pend.qid, tenant=pend.tenant,
                           trace=trace)
        busy = pplan.service_s if pplan is not None \
            else pe.service_s(acc, chips)
        pe.meter.charge_compute(acc.charge, busy, chips)
        cursor = t0
        if trace is not None:
            from repro.obs.trace import layout_pipeline, layout_sync
            cursor = (layout_pipeline(trace, t0, pplan, pe.tiers, chips)
                      if pplan is not None
                      else layout_sync(trace, t0, pe.tiers, chips))
            trace.compute(t0, busy, chips,
                          pe.meter.compute_w * chips * busy)
            cap_e = pe.tiers.capacity.energy_per_byte
            for kind, b, rs, attrs in rec_events:
                trace.add(kind, t0=cursor, dur_s=rs, nbytes=b,
                          tier="capacity", ledger="recovery",
                          joules=b * cap_e, **attrs)
                cursor += rs
        query_j_extra = 0.0
        if pplan is not None:
            # overlap's own traffic on the kind="prefetch" line; the
            # *stalled* streams' wasted bytes instead join this query's
            # single kind="recovery" line below — charged exactly once
            self.prefetch_stalls += pplan.n_stalled
            extra_cap_b += pplan.stalled_bytes
            line = engine.prefetch.finish(pplan, qid=pend.qid,
                                          tenant=pend.tenant)
            if line is not None:
                query_j_extra += line.total_j
        # 5. stall / retry / failover on each fast-tier chunk read
        saw_stall = False
        for cid in sorted(fast_cids):
            ex, fb, cb, stalled, cursor = self._chunk_read(
                engine, pend.qid, cid, fast_cids[cid], chips,
                trace=trace, at=cursor)
            extra_s += ex
            extra_fast_b += fb
            extra_cap_b += cb
            saw_stall = saw_stall or stalled
        if self.breaker is not None and not saw_stall and fast_cids:
            self.breaker.record_ok(t0)
        # 6. every recovery byte lands in one ledger line — exactly once
        recovery_j = 0.0
        if extra_fast_b or extra_cap_b:
            line = pe.charge_recovery(extra_fast_b, extra_cap_b,
                                      qid=pend.qid, tenant=pend.tenant)
            recovery_j = line.total_j
        return (aggs, acc, busy + extra_s,
                acc.charge.total_j + query_j_extra + recovery_j, error)

    def _chunk_read(self, engine, qid: int, cid, nbytes: int, chips: int,
                    trace=None, at: float = 0.0):
        """Model one fast-tier chunk read under the stall fault + retry
        policy. Returns (extra_s, extra_fast_bytes, extra_capacity_bytes,
        stalled, cursor): extras beyond the one clean read the nominal
        service already priced; `cursor` advances past the recovery spans
        emitted on `trace` starting at `at`."""
        pe = engine.tiered
        clean_s = pe.tiers.service_s(nbytes, 0, chips)
        fast_e = pe.tiers.fast.energy_per_byte
        cap_e = pe.tiers.capacity.energy_per_byte
        total = 0.0
        fast_b = 0
        cap_b = 0
        attempt = 0
        faulted = False
        while True:
            stalled = self.injector.stalled(qid, cid, attempt)
            if not stalled:
                total += clean_s
                break
            faulted = True
            self.stalls += 1
            if self.breaker is not None:
                self.breaker.record_fault(engine.clock())
            if not (self.recover and self.retry is not None):
                # no retry policy: the stalled read rides to completion
                total += self.spec.stall_factor * clean_s
                if trace is not None:
                    ride = (self.spec.stall_factor - 1.0) * clean_s
                    trace.add("stall", t0=at, dur_s=ride, cid=cid,
                              attempt=attempt)
                    at += ride
                break
            if self.spec.stall_factor * clean_s <= self.retry.timeout_s:
                # slow, but lands inside the timeout: no abandon
                total += self.spec.stall_factor * clean_s
                if trace is not None:
                    ride = (self.spec.stall_factor - 1.0) * clean_s
                    trace.add("stall", t0=at, dur_s=ride, cid=cid,
                              attempt=attempt)
                    at += ride
                break
            if attempt >= self.retry.max_retries:
                # retry budget exhausted: fail over to the durable
                # capacity copy
                fo = (self.retry.timeout_s
                      + pe.tiers.service_s(0, nbytes, chips))
                total += fo
                cap_b += nbytes
                self.failovers += 1
                if trace is not None:
                    # the span's duration is the failover's *extra* beyond
                    # the clean read the nominal service already priced
                    # (this method returns total - clean_s), so the
                    # recovery timeline closes exactly at the query's
                    # modeled t_end — the critical-path closure invariant
                    ride = max(fo - clean_s, 0.0)
                    trace.add("failover", t0=at, dur_s=ride, nbytes=nbytes,
                              tier="capacity", ledger="recovery",
                              joules=nbytes * cap_e, cid=cid,
                              attempt=attempt)
                    at += ride
                break
            rt = self.retry.timeout_s + self.retry.backoff(attempt)
            total += rt
            fast_b += nbytes        # the re-issued read streams again
            self.retries += 1
            if trace is not None:
                trace.add("retry", t0=at, dur_s=rt, nbytes=nbytes,
                          tier="fast", ledger="recovery",
                          joules=nbytes * fast_e, cid=cid,
                          attempt=attempt)
                at += rt
            attempt += 1
        extra = max(total - clean_s, 0.0)
        if faulted and self.recover and self.retry is not None:
            self._recovered(extra)
        return extra, fast_b, cap_b, faulted, at

    # --- reporting --------------------------------------------------------
    def _recovered(self, seconds: float) -> None:
        self._recovered_faults += 1
        self._recovery_s += seconds

    @property
    def mttr_s(self) -> float | None:
        """Modeled mean time to recover: extra seconds per recovered
        fault (None until something recovered)."""
        if self._recovered_faults == 0:
            return None
        return self._recovery_s / self._recovered_faults

    def summary(self) -> dict:
        out = {
            "spec": self.spec.as_dict(),
            "recover": self.recover,
            "retry": self.retry.as_dict() if self.retry else None,
            "stalls": self.stalls,
            "prefetch_stalls": self.prefetch_stalls,
            "retries": self.retries,
            "failovers": self.failovers,
            "repairs": self.repairs,
            "shard_losses": self.shard_losses,
            "shard_recoveries": self.shard_recoveries,
            "degraded_queries": self.failures,
            "recovered_faults": self._recovered_faults,
            "mttr_s": self.mttr_s,
        }
        if self.breaker is not None:
            out["breaker"] = self.breaker.summary()
        if self.guard is not None:
            out["integrity"] = self.guard.summary()
        return out
