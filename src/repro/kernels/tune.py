"""Block-size autotuner with an on-disk JSON cache.

The right tile shape is workload-dependent (Bakhshalipour et al., arXiv
1809.08828: the best memory configuration must be tuned, not hardcoded), so
instead of five families of `DEFAULT_*` constants the ops consult this
module:

- `best_params(op, shape_key, defaults)` — the hot-path lookup: returns the
  cached winner for (op, backend, shape) or the heuristic defaults. Never
  times anything, so op call latency is unaffected.
- `autotune(op, shape_key, candidates, bench)` — the timed sweep: runs
  `bench(params)` over the candidate grid, persists the winner to the JSON
  cache, and is a pure cache hit on every later call with the same key.

Cache keys are `op|backend|shape_key` so TPU and CPU-interpret tunings
coexist in one file. The cache lives at artifacts/tune_cache.json (override
with REPRO_TUNE_CACHE) and is written atomically.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import tempfile
import time
from pathlib import Path

import jax

_DEFAULT_PATH = Path(__file__).resolve().parents[3] / "artifacts" \
    / "tune_cache.json"


def cache_path() -> Path:
    return Path(os.environ.get("REPRO_TUNE_CACHE", _DEFAULT_PATH))


class TuneCache:
    """A {key: {params, us, sweep}} JSON file, loaded lazily."""

    def __init__(self, path=None):
        self.path = Path(path) if path else cache_path()
        self._data: dict | None = None

    def _load(self) -> dict:
        if self._data is None:
            try:
                self._data = json.loads(self.path.read_text())
            except (OSError, ValueError):
                self._data = {}
        return self._data

    @staticmethod
    def key(op: str, shape_key: str) -> str:
        return f"{op}|{jax.default_backend()}|{shape_key}"

    def lookup(self, op: str, shape_key: str):
        return self._load().get(self.key(op, shape_key))

    def entries(self) -> dict:
        """All cached {key: entry} pairs (read-only view for consumers
        that scan the cache, e.g. repro.tier.measured_fast_gbps)."""
        return dict(self._load())

    def store(self, op: str, shape_key: str, entry: dict) -> None:
        data = self._load()
        data[self.key(op, shape_key)] = entry
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # unique temp file per writer + atomic rename: concurrent bench/CI
        # runs may lose each other's *entries* (last rename wins) but can
        # never interleave bytes into one file and leave it truncated
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name + ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps(data, indent=1, sort_keys=True))
            os.replace(tmp, self.path)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise


_cache: TuneCache | None = None


def get_cache() -> TuneCache:
    global _cache
    if _cache is None:
        _cache = TuneCache()
    return _cache


def set_cache_path(path) -> TuneCache:
    """Point the tuner at a different cache file (tests, sweeps)."""
    global _cache
    _cache = TuneCache(path)
    return _cache


def shape_key(**dims) -> str:
    """Canonical 'a=1,b=2' key fragment from shape-defining ints."""
    return ",".join(f"{k}={v}" for k, v in sorted(dims.items()))


def fit(n: int, block: int) -> int:
    """Largest divisor of n that is <= block (block-shape validity)."""
    block = max(1, min(int(block), int(n)))
    while n % block:
        block -= 1
    return block


def best_params(op: str, skey: str, defaults: dict) -> dict:
    """Hot-path lookup: cached winner for this (op, backend, shape) or the
    heuristic defaults. Unknown cached keys are ignored, so stale cache
    entries can't break an op whose tunables changed."""
    entry = get_cache().lookup(op, skey)
    if not entry:
        return dict(defaults)
    tuned = entry.get("params", {})
    return {k: tuned.get(k, v) for k, v in defaults.items()}


def autotune(op: str, skey: str, candidates: dict, bench,
             repeat: int = 3) -> dict:
    """Timed sweep over the candidate grid; persists + returns the entry.

    bench(params) runs the op once with those block sizes (it should
    block_until_ready). Candidates that raise are skipped. A cache hit
    returns immediately without timing anything.
    """
    cache = get_cache()
    hit = cache.lookup(op, skey)
    if hit is not None:
        return hit
    sweep = []
    for combo in itertools.product(*candidates.values()):
        params = dict(zip(candidates.keys(), combo))
        try:
            bench(params)                       # warm: trace/compile
            t0 = time.perf_counter()
            for _ in range(repeat):
                bench(params)
            us = (time.perf_counter() - t0) / repeat * 1e6
        except Exception:                       # invalid tile for this shape
            continue
        sweep.append({"params": params, "us": round(us, 1)})
    if not sweep:
        raise ValueError(f"no viable candidates for {op}|{skey}")
    best = min(sweep, key=lambda r: r["us"])
    entry = {"params": best["params"], "us": best["us"], "sweep": sweep}
    cache.store(op, skey, entry)
    return entry
