"""Scan-over-compressed Pallas TPU kernel: fused predicate + aggregate
directly on RLE runs.

The bandwidth argument, squared: the plain fused kernel already avoids the
mask round-trip; this one avoids touching *rows* at all. Per grid step a
(block_rows, 128) tile of run values is compared against the constant on
the VPU (runs hold decoded codes, so all six predicates are plain int32
compares — no BitWeaving masks needed) and reduced against the matching
run-length tile: a run of length n contributes n to the count and n*value
to the sum, entirely in registers/VMEM. A chunk of r rows in k runs
streams 8k bytes instead of 4*ceil(r/cpw) — on sorted or low-cardinality
columns that is a 10-100x traffic cut at identical answers.

Exactness: the store bounds chunks at 65536 rows with payloads < 2^15, so
every partial (value*length summed over a chunk) stays below 2^31 and the
int32 accumulator is exact; the sum leaves as the normalized 16-bit
planes all aggregate paths share. Zero-length runs (pow2 padding) are
cancelled by the `lengths > 0` term of the selection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES


def _rle_kernel(v_ref, l_ref, o_ref, acc, *, op: str, constant: int,
                vmax: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _():
        acc[0, 0] = jnp.int32(0)      # raw sum (chunk-bounded, exact)
        acc[0, 1] = jnp.int32(0)      # unused until the final normalize
        acc[0, 2] = jnp.int32(0)      # count
        acc[0, 3] = jnp.int32(vmax)   # min
        acc[0, 4] = jnp.int32(0)      # max

    v = v_ref[...]
    l = l_ref[...]
    c = jnp.int32(constant)
    cmp = {"lt": v < c, "le": v <= c, "gt": v > c, "ge": v >= c,
           "eq": v == c, "ne": v != c}[op]
    sel = cmp & (l > 0)

    acc[0, 0] += jnp.sum(jnp.where(sel, v * l, 0))
    acc[0, 2] += jnp.sum(jnp.where(sel, l, 0))
    acc[0, 3] = jnp.minimum(acc[0, 3], jnp.min(jnp.where(sel, v, vmax)))
    acc[0, 4] = jnp.maximum(acc[0, 4], jnp.max(jnp.where(sel, v, 0)))

    @pl.when(i == n - 1)
    def _():
        s = acc[0, 0]
        o_ref[0, 0] = s & 0xFFFF              # normalized sum planes
        o_ref[0, 1] = s >> 16
        o_ref[0, 2] = acc[0, 2]
        o_ref[0, 3] = acc[0, 3]
        o_ref[0, 4] = acc[0, 4]


def _rle_batched_kernel(v_ref, l_ref, o_ref, acc, *, op: str, constant: int,
                        vmax: int):
    """Batched variant: grid (n_chunks, inner); one (1, 5) partial row per
    chunk. The inner dimension iterates fastest (TPU grid order), so the
    per-chunk accumulator resets at inner step 0 and writes back normalized
    at the last inner step — chunk c's partial never sees chunk c±1's
    tiles, keeping every row bit-identical to the per-chunk kernel."""
    i = pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when(i == 0)
    def _():
        acc[0, 0] = jnp.int32(0)      # raw sum (chunk-bounded, exact)
        acc[0, 1] = jnp.int32(0)      # unused until the final normalize
        acc[0, 2] = jnp.int32(0)      # count
        acc[0, 3] = jnp.int32(vmax)   # min
        acc[0, 4] = jnp.int32(0)      # max

    v = v_ref[0]
    l = l_ref[0]
    c = jnp.int32(constant)
    cmp = {"lt": v < c, "le": v <= c, "gt": v > c, "ge": v >= c,
           "eq": v == c, "ne": v != c}[op]
    sel = cmp & (l > 0)

    acc[0, 0] += jnp.sum(jnp.where(sel, v * l, 0))
    acc[0, 2] += jnp.sum(jnp.where(sel, l, 0))
    acc[0, 3] = jnp.minimum(acc[0, 3], jnp.min(jnp.where(sel, v, vmax)))
    acc[0, 4] = jnp.maximum(acc[0, 4], jnp.max(jnp.where(sel, v, 0)))

    @pl.when(i == ni - 1)
    def _():
        s = acc[0, 0]
        o_ref[0, 0] = s & 0xFFFF              # normalized sum planes
        o_ref[0, 1] = s >> 16
        o_ref[0, 2] = acc[0, 2]
        o_ref[0, 3] = acc[0, 3]
        o_ref[0, 4] = acc[0, 4]


@functools.partial(jax.jit,
                   static_argnames=("constant", "op", "code_bits",
                                    "block_rows", "interpret"))
def rle_scan_aggregate_batched_packed(values3d, lengths3d, *, constant: int,
                                      op: str, code_bits: int,
                                      block_rows: int = DEFAULT_BLOCK_ROWS,
                                      interpret: bool = True):
    """(n_chunks, rows, 128) int32 run planes -> int32[n_chunks, 5]: one
    [sum_lo, sum_hi, count, min, max] row per chunk, all chunks in ONE
    kernel launch. Rows are zero-padded per chunk to the block multiple
    and across chunks to the widest chunk; padded runs carry length 0 and
    contribute to no accumulator, so each output row equals the per-chunk
    `rle_scan_aggregate_packed` bit-for-bit."""
    n_chunks, rows = values3d.shape[0], values3d.shape[1]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        values3d = jnp.pad(values3d, ((0, 0), (0, pad), (0, 0)))
        lengths3d = jnp.pad(lengths3d, ((0, 0), (0, pad), (0, 0)))
        rows += pad
    vmax = (1 << (code_bits - 1)) - 1
    kernel = functools.partial(_rle_batched_kernel, op=op,
                               constant=int(constant), vmax=vmax)
    spec = pl.BlockSpec((1, block_rows, LANES), lambda c, i: (c, i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n_chunks, rows // block_rows),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 5), lambda c, i: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 5), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
        interpret=interpret,
    )(values3d, lengths3d)


@functools.partial(jax.jit,
                   static_argnames=("constant", "op", "code_bits",
                                    "block_rows", "interpret"))
def rle_scan_aggregate_packed(values2d, lengths2d, *, constant: int,
                              op: str, code_bits: int,
                              block_rows: int = DEFAULT_BLOCK_ROWS,
                              interpret: bool = True):
    """(rows, 128) int32 run-value/run-length planes -> int32[1, 5]
    = [sum_lo, sum_hi, count, min, max] over the rows the runs encode.

    Rows are zero-padded to the block multiple; padded (and pow2-pad)
    runs carry length 0 and contribute to no accumulator."""
    rows = values2d.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        values2d = jnp.pad(values2d, ((0, pad), (0, 0)))
        lengths2d = jnp.pad(lengths2d, ((0, pad), (0, 0)))
        rows += pad
    vmax = (1 << (code_bits - 1)) - 1
    kernel = functools.partial(_rle_kernel, op=op, constant=int(constant),
                               vmax=vmax)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 5), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 5), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
        interpret=interpret,
    )(values2d, lengths2d)
