"""Pure-jnp oracle for the scan-over-compressed (RLE) fused aggregate.

Semantics: the run arrays are an exact RLE of a code column — a run of
length n with value v stands for n identical rows — and the op computes
the same (sum planes, count, min, max) the plain-format fused kernel
returns over the decoded rows: a matching run contributes n to the count
and n*v to the sum; zero-length runs are layout padding and inert.

Exactness: per-chunk totals fit int32 because the store bounds chunks at
MAX_CHUNK_ROWS (65536) rows and payloads at 2^15-1, so vmax * rows <
2^31; the sum leaves as the same normalized 16-bit (lo, hi) planes every
aggregate path carries (psum-safe, reassembled by
repro.kernels.aggregate.ops.finalize).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.aggregate.ref import identity
from repro.kernels.scan_filter.ref import OPS

_CMP = {"lt": jnp.less, "le": jnp.less_equal, "gt": jnp.greater,
        "ge": jnp.greater_equal, "eq": jnp.equal, "ne": jnp.not_equal}


def rle_scan_aggregate_ref(values, lengths, constant: int, op: str,
                           code_bits: int):
    """SELECT agg(col) WHERE col <op> constant over one RLE-encoded
    column chunk -> dict(sum_lo, sum_hi, count, min, max)."""
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    v = jnp.asarray(values, jnp.int32)
    l = jnp.asarray(lengths, jnp.int32)
    if v.size == 0:
        return identity(code_bits)
    vmax = jnp.int32((1 << (code_bits - 1)) - 1)
    sel = _CMP[op](v, jnp.int32(constant)) & (l > 0)
    s = jnp.sum(jnp.where(sel, v * l, 0))      # < 2^31 per chunk: exact
    return {
        "sum_lo": s & 0xFFFF,
        "sum_hi": s >> 16,
        "count": jnp.sum(jnp.where(sel, l, 0)),
        "min": jnp.min(jnp.where(sel, v, vmax)),
        "max": jnp.max(jnp.where(sel, v, 0)),
    }


def rle_scan_aggregate_batched_ref(values3d, lengths3d, constant: int,
                                   op: str, code_bits: int):
    """Vectorized oracle for the batched RLE kernel: (n_chunks, rows, 128)
    run planes -> int32[n_chunks, 5] of [sum_lo, sum_hi, count, min, max]
    rows, one per chunk, in a single jnp dispatch. Zero-length padding
    runs (lane/block/width padding alike) select nothing, so each row
    matches the per-chunk `rle_scan_aggregate_ref` bit-for-bit."""
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    v = jnp.asarray(values3d, jnp.int32)
    l = jnp.asarray(lengths3d, jnp.int32)
    vmax = jnp.int32((1 << (code_bits - 1)) - 1)
    sel = _CMP[op](v, jnp.int32(constant)) & (l > 0)
    ax = (1, 2)
    s = jnp.sum(jnp.where(sel, v * l, 0), axis=ax)
    return jnp.stack([
        s & 0xFFFF,
        s >> 16,
        jnp.sum(jnp.where(sel, l, 0), axis=ax),
        jnp.min(jnp.where(sel, v, vmax), axis=ax),
        jnp.max(jnp.where(sel, v, 0), axis=ax),
    ], axis=1)
