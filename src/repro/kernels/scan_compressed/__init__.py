"""Fused predicate scan + aggregate directly on compressed (RLE) runs."""
