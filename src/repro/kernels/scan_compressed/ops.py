"""Public scan-over-compressed API, dispatched through
repro.kernels.dispatch.

`rle_scan_aggregate` is the fused SELECT agg(col) WHERE col <op> const
over one RLE-encoded chunk: runs stream instead of rows, so effective
bandwidth multiplies by rows/runs. FOR-encoded chunks need no kernel of
their own — a FOR plane *is* a plain BitWeaving plane at the delta width,
so repro.store.exec routes them through the existing scan_filter /
aggregate / scan_aggregate families at the narrower width with a
translated constant and an exact host-side base fix-up.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.aggregate import ref as agg_ref
from repro.kernels.scan_compressed import kernel as K
from repro.kernels.scan_compressed import ref
from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES
from repro.kernels.scan_filter.ref import OPS


def rle_scan_aggregate(values, lengths, constant: int, op: str,
                       code_bits: int, block_rows: int | None = None,
                       mode=None) -> dict:
    """Fused predicate + aggregate over RLE run planes ->
    dict(sum_lo, sum_hi, count, min, max); reassemble the exact sum with
    repro.kernels.aggregate.ops.finalize.

    values/lengths are the (n_runs_padded,) int32 planes of one store
    chunk (repro.store.encode); zero-length runs are inert padding.
    """
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    r = dispatch.resolve(mode)
    dispatch.count_launch("scan_compressed")
    if not r.use_pallas:
        return ref.rle_scan_aggregate_ref(values, lengths, constant, op,
                                          code_bits)
    v = jnp.asarray(values, jnp.int32)
    l = jnp.asarray(lengths, jnp.int32)
    if v.size == 0:                   # zero-run grid is undefined
        return agg_ref.identity(code_bits)

    def to2d(x):
        return jnp.pad(x, (0, (-x.shape[0]) % LANES)).reshape(-1, LANES)

    v2d, l2d = to2d(v), to2d(l)
    rows = v2d.shape[0]
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("scan_compressed",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    out = K.rle_scan_aggregate_packed(v2d, l2d, constant=int(constant),
                                      op=op, code_bits=code_bits,
                                      block_rows=br, interpret=r.interpret)
    return {"sum_lo": out[0, 0], "sum_hi": out[0, 1], "count": out[0, 2],
            "min": out[0, 3], "max": out[0, 4]}


def rle_scan_aggregate_batched(planes, constant: int, op: str,
                               code_bits: int,
                               block_rows: int | None = None, mode=None):
    """All RLE chunks of a column in ONE launch.

    planes: sequence of (values, lengths) run-plane pairs, one per chunk
    (ragged run counts allowed). Returns int32[n_chunks, 5] — one
    [sum_lo, sum_hi, count, min, max] row per chunk, bit-identical to
    calling `rle_scan_aggregate` per chunk: ragged chunks are padded to
    the widest with zero-length runs, which select nothing.
    """
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    r = dispatch.resolve(mode)
    dispatch.count_launch("scan_compressed")
    n_chunks = len(planes)
    if n_chunks == 0:
        return jnp.zeros((0, 5), jnp.int32)

    def to2d(x):
        x = jnp.asarray(x, jnp.int32)
        return jnp.pad(x, (0, (-x.shape[0]) % LANES)).reshape(-1, LANES)

    pairs = [(to2d(v), to2d(l)) for v, l in planes]
    rows = max(max(v.shape[0] for v, _ in pairs), 1)

    def lift(x):
        return jnp.pad(x, ((0, rows - x.shape[0]), (0, 0)))

    v3 = jnp.stack([lift(v) for v, _ in pairs])
    l3 = jnp.stack([lift(l) for _, l in pairs])
    if not r.use_pallas:
        return ref.rle_scan_aggregate_batched_ref(v3, l3, constant, op,
                                                  code_bits)
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("scan_compressed",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    return K.rle_scan_aggregate_batched_packed(
        v3, l3, constant=int(constant), op=op, code_bits=code_bits,
        block_rows=br, interpret=r.interpret)


def _example(rng):
    n = 2000                           # non-pow2: exercises lane padding
    values = jnp.asarray(rng.integers(0, 128, n), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, 9, n), jnp.int32)
    return (values, lengths, 64, "lt", 8), {}


dispatch.register(
    "scan_compressed", fn=rle_scan_aggregate,
    ref=ref.rle_scan_aggregate_ref,
    tunables={"block_rows": (64, 256, 1024, 4096)},
    example=_example)
