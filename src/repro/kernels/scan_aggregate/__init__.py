"""Fused predicate scan + masked aggregate over bit-packed columns."""
