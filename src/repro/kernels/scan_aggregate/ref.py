"""Pure-jnp oracle for the fused scan+aggregate: scan -> valid-mask -> agg."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.aggregate.ref import aggregate_ref
from repro.kernels.scan_filter.ref import scan_ref


def scan_aggregate_ref(pred_words, agg_words, valid_words, constant: int,
                       op: str, code_bits: int):
    """Predicate scan over pred_words, validity-masked, aggregated over
    agg_words. valid_words is a packed delimiter-bit mask with bits set only
    for real (non-padding) rows, so tail/shard padding never matches."""
    mask = scan_ref(pred_words, constant, op, code_bits)
    mask = mask & jnp.asarray(valid_words, jnp.uint32)
    return aggregate_ref(agg_words, mask, code_bits)
