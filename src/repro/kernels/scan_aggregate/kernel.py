"""Fused BitWeaving scan + masked aggregate Pallas TPU kernel.

"Processing Data Where It Makes Sense" applied inside one chip: the scan's
packed predicate mask never round-trips through HBM. Per grid step a
(block_rows, 128) tile of the predicate column is compared against the
constant with the scan kernel's VPU bit-tricks (GE/EQ primitives, optional
complement for the composed lt/le/ne forms), ANDed with the validity mask
(tail/shard padding rows carry zero delimiter bits), and immediately
reduced against the aggregate column's tile into VMEM scratch accumulators.

Streams 3 inputs and writes 4 scalars, vs 4 streamed tiles + a full mask
write for the scan->aggregate pipeline — at the paper's ~1 B/instr scan
regime that is a 40% traffic cut for the dominant single-predicate query.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES
from repro.kernels.scan_filter.ref import field_masks


def _fused_kernel(p_ref, a_ref, v_ref, o_ref, acc, *, op: str,
                  const_packed, delim, low, invert: bool, code_bits: int,
                  vmax: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _():
        acc[0, 0] = jnp.int32(0)      # sum_lo (16-bit plane, denormalized)
        acc[0, 1] = jnp.int32(0)      # sum_hi
        acc[0, 2] = jnp.int32(0)      # count
        acc[0, 3] = jnp.int32(vmax)   # min
        acc[0, 4] = jnp.int32(0)      # max

    x = p_ref[...]
    h = jnp.uint32(delim)
    if op == "ge":
        m = ((x | h) - jnp.uint32(const_packed)) & h
    elif op == "eq":
        z = x ^ jnp.uint32(const_packed)
        m = (~((z | h) - jnp.uint32(low))) & h
    else:
        raise ValueError(op)
    if invert:
        m = ~m & h
    m = m & v_ref[...]

    a = a_ref[...]
    c = 32 // code_bits
    value_mask = jnp.uint32((1 << (code_bits - 1)) - 1)
    s = jnp.int32(0)
    cnt = jnp.int32(0)
    mn = jnp.int32(vmax)
    mx = jnp.int32(0)
    for f in range(c):                       # static unroll over fields
        vals = ((a >> jnp.uint32(f * code_bits)) & value_mask).astype(
            jnp.int32)
        bit = ((m >> jnp.uint32(f * code_bits + code_bits - 1))
               & jnp.uint32(1)).astype(jnp.int32)
        sel = bit == 1
        s += jnp.sum(vals * bit)
        cnt += jnp.sum(bit)
        mn = jnp.minimum(mn, jnp.min(jnp.where(sel, vals, vmax)))
        mx = jnp.maximum(mx, jnp.max(jnp.where(sel, vals, 0)))

    # s is exact (ops.py bounds block_rows); split so the running sum
    # never wraps int32 (see aggregate/kernel.py)
    acc[0, 0] += s & 0xFFFF
    acc[0, 1] += s >> 16
    acc[0, 2] += cnt
    acc[0, 3] = jnp.minimum(acc[0, 3], mn)
    acc[0, 4] = jnp.maximum(acc[0, 4], mx)

    @pl.when(i == n - 1)
    def _():
        lo = acc[0, 0]
        o_ref[0, 0] = lo & 0xFFFF             # normalized planes
        o_ref[0, 1] = acc[0, 1] + (lo >> 16)
        o_ref[0, 2] = acc[0, 2]
        o_ref[0, 3] = acc[0, 3]
        o_ref[0, 4] = acc[0, 4]


def _fused_batched_kernel(const_ref, flag_ref, p_ref, a_ref, v_ref, o_ref,
                          acc, *, delim, low, code_bits: int, vmax: int):
    """Batched variant: grid (n_chunks, inner), one (1, 5) partial row per
    chunk. The per-chunk predicate rides in as data — scalar-prefetched
    planes of packed constants and flag words (bit0 = eq primitive,
    bit1 = invert) indexed by the chunk grid coordinate — so chunks whose
    FOR frames translated the constant differently still share one launch.
    Inner steps iterate fastest: reset at inner 0, normalized writeback at
    the last inner step, bit-identical per chunk to `_fused_kernel`."""
    c_id = pl.program_id(0)
    i = pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when(i == 0)
    def _():
        acc[0, 0] = jnp.int32(0)      # sum_lo (16-bit plane, denormalized)
        acc[0, 1] = jnp.int32(0)      # sum_hi
        acc[0, 2] = jnp.int32(0)      # count
        acc[0, 3] = jnp.int32(vmax)   # min
        acc[0, 4] = jnp.int32(0)      # max

    x = p_ref[0]
    h = jnp.uint32(delim)
    # packed constants keep delimiter bits 0, so int32 -> uint32 is safe
    cst = const_ref[c_id].astype(jnp.uint32)
    flags = flag_ref[c_id]
    m_ge = ((x | h) - cst) & h
    m_eq = (~(((x ^ cst) | h) - jnp.uint32(low))) & h
    m = jnp.where((flags & 1) == 1, m_eq, m_ge)
    m = jnp.where((flags & 2) == 2, m ^ h, m)   # m subset-of h: ^h == ~m&h
    m = m & v_ref[0]

    a = a_ref[0]
    c = 32 // code_bits
    value_mask = jnp.uint32((1 << (code_bits - 1)) - 1)
    s = jnp.int32(0)
    cnt = jnp.int32(0)
    mn = jnp.int32(vmax)
    mx = jnp.int32(0)
    for f in range(c):                       # static unroll over fields
        vals = ((a >> jnp.uint32(f * code_bits)) & value_mask).astype(
            jnp.int32)
        bit = ((m >> jnp.uint32(f * code_bits + code_bits - 1))
               & jnp.uint32(1)).astype(jnp.int32)
        sel = bit == 1
        s += jnp.sum(vals * bit)
        cnt += jnp.sum(bit)
        mn = jnp.minimum(mn, jnp.min(jnp.where(sel, vals, vmax)))
        mx = jnp.maximum(mx, jnp.max(jnp.where(sel, vals, 0)))

    acc[0, 0] += s & 0xFFFF
    acc[0, 1] += s >> 16
    acc[0, 2] += cnt
    acc[0, 3] = jnp.minimum(acc[0, 3], mn)
    acc[0, 4] = jnp.maximum(acc[0, 4], mx)

    @pl.when(i == ni - 1)
    def _():
        lo = acc[0, 0]
        o_ref[0, 0] = lo & 0xFFFF             # normalized planes
        o_ref[0, 1] = acc[0, 1] + (lo >> 16)
        o_ref[0, 2] = acc[0, 2]
        o_ref[0, 3] = acc[0, 3]
        o_ref[0, 4] = acc[0, 4]


@functools.partial(jax.jit,
                   static_argnames=("code_bits", "block_rows", "interpret"))
def scan_aggregate_batched_packed(consts, flags, pred3d, agg3d, valid3d, *,
                                  code_bits: int,
                                  block_rows: int = DEFAULT_BLOCK_ROWS,
                                  interpret: bool = True):
    """All chunks of one (pred, agg) column pair in ONE launch.

    consts/flags: (n_chunks,) int32 scalar planes from
    scan_filter.ops.packed_triples (per-chunk packed constant + eq/invert
    flags), scalar-prefetched so the grid's chunk coordinate selects each
    tile's predicate without re-specializing the kernel.
    pred3d/agg3d/valid3d: (n_chunks, rows, 128) packed word planes.
    Returns int32[n_chunks, 5]; each row is bit-identical to the per-chunk
    `scan_aggregate_packed` at that chunk's (constant, op, invert)."""
    n_chunks, rows = pred3d.shape[0], pred3d.shape[1]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        pred3d = jnp.pad(pred3d, ((0, 0), (0, pad), (0, 0)))
        agg3d = jnp.pad(agg3d, ((0, 0), (0, pad), (0, 0)))
        valid3d = jnp.pad(valid3d, ((0, 0), (0, pad), (0, 0)))
        rows += pad
    delim, low, value = field_masks(code_bits)
    kernel = functools.partial(_fused_batched_kernel, delim=int(delim),
                               low=int(low), code_bits=code_bits,
                               vmax=int(value))
    spec = pl.BlockSpec((1, block_rows, LANES), lambda c, i, *_: (c, i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_chunks, rows // block_rows),
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, 5), lambda c, i, *_: (c, 0)),
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_chunks, 5), jnp.int32),
        interpret=interpret,
    )(consts, flags, pred3d, agg3d, valid3d)


@functools.partial(jax.jit,
                   static_argnames=("constant", "op", "invert", "code_bits",
                                    "block_rows", "interpret"))
def scan_aggregate_packed(pred2d, agg2d, valid2d, *, constant: int, op: str,
                          invert: bool, code_bits: int,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True):
    """(rows, 128) packed predicate/aggregate/validity words -> int32[1, 5]
    = [sum_lo, sum_hi, count, min, max] (sum = sum_hi * 65536 + sum_lo).
    `op` is a kernel primitive (ge | eq); the six public predicates are
    composed in ops.py via (op, constant, invert).

    Rows are zero-padded to the block multiple; padded validity words carry
    zero delimiter bits so padding contributes to no accumulator."""
    rows = pred2d.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        pred2d = jnp.pad(pred2d, ((0, pad), (0, 0)))
        agg2d = jnp.pad(agg2d, ((0, pad), (0, 0)))
        valid2d = jnp.pad(valid2d, ((0, pad), (0, 0)))
        rows += pad
    delim, low, value = field_masks(code_bits)
    vmax = int(value)
    c = 32 // code_bits
    const_packed = 0
    for i in range(c):
        const_packed |= (int(constant) & vmax) << (i * code_bits)
    kernel = functools.partial(_fused_kernel, op=op,
                               const_packed=const_packed, delim=int(delim),
                               low=int(low), invert=invert,
                               code_bits=code_bits, vmax=vmax)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, 5), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 5), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
        interpret=interpret,
    )(pred2d, agg2d, valid2d)
