"""Public fused scan+aggregate API, dispatched through
repro.kernels.dispatch.

The full predicate set {lt, le, gt, ge, eq, ne} is composed from the
kernel's {ge, eq} primitives plus an in-kernel complement, mirroring
scan_filter's composition rules; the two degenerate compositions (gt at the
payload max, le at/above it) short-circuit to the empty-selection identity
and a plain validity-mask aggregate respectively.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.aggregate import ref as agg_ref
from repro.kernels.scan_aggregate import kernel as K
from repro.kernels.scan_aggregate import ref
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES
from repro.kernels.scan_filter.ref import OPS


def scan_aggregate(pred_words, agg_words, valid_words, constant: int,
                   op: str, code_bits: int, block_rows: int | None = None,
                   mode=None) -> dict:
    """Fused SELECT agg(agg_col) WHERE pred_col <op> constant over packed
    words of one shared code width ->
    dict(sum_lo, sum_hi, count, min, max); reassemble the exact sum with
    repro.kernels.aggregate.ops.finalize.

    valid_words is the packed delimiter-bit validity mask (bits set only
    for real rows); it cancels tail-of-word and shard padding.
    """
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of {OPS}")
    r = dispatch.resolve(mode)
    dispatch.count_launch("scan_aggregate")
    if not r.use_pallas:
        return ref.scan_aggregate_ref(pred_words, agg_words, valid_words,
                                      constant, op, code_bits)
    if pred_words.size == 0:          # zero-row grid is undefined
        return agg_ref.identity(code_bits)

    vmax = (1 << (code_bits - 1)) - 1
    c = int(constant)
    if op in ("ge", "eq"):
        prim, cc, inv = op, c, False
    elif op == "lt":
        prim, cc, inv = "ge", c, True
    elif op == "ne":
        prim, cc, inv = "eq", c, True
    elif op == "gt":
        if c >= vmax:                 # nothing exceeds the payload max
            return agg_ref.identity(code_bits)
        prim, cc, inv = "ge", c + 1, False
    else:  # le
        if c >= vmax:                 # everything valid matches
            return agg_ops.aggregate(agg_words, valid_words, code_bits,
                                     mode=mode)
        prim, cc, inv = "ge", c + 1, True

    def to2d(w):
        w = jnp.asarray(w, jnp.uint32)
        return jnp.pad(w, (0, (-w.shape[0]) % LANES)).reshape(-1, LANES)

    p2d, a2d, v2d = to2d(pred_words), to2d(agg_words), to2d(valid_words)
    rows = p2d.shape[0]
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("scan_aggregate",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    br = min(br, agg_ops.sum_bound_block_rows(code_bits))
    out = K.scan_aggregate_packed(p2d, a2d, v2d, constant=cc, op=prim,
                                  invert=inv, code_bits=code_bits,
                                  block_rows=br, interpret=r.interpret)
    return {"sum_lo": out[0, 0], "sum_hi": out[0, 1], "count": out[0, 2],
            "min": out[0, 3], "max": out[0, 4]}


def scan_aggregate_batched(pred3, agg3, valid3, triples, code_bits: int,
                           block_rows: int | None = None, mode=None):
    """All chunks of one (pred, agg) column pair in ONE launch.

    pred3/agg3/valid3: (n_chunks, n_words) packed word planes (every
    chunk already repacked to the shared `code_bits`). triples: per-chunk
    canonical (prim, constant, invert) from scan_filter.ops.canonical_pred
    — per-chunk FOR frames translate the constant differently, and the
    batched kernel carries that difference as scalar-prefetched data.
    Returns int32[n_chunks, 5]; each row is bit-identical to the
    per-chunk `scan_aggregate` composition for that chunk."""
    r = dispatch.resolve(mode)
    dispatch.count_launch("scan_aggregate")
    p = jnp.asarray(pred3, jnp.uint32)
    n_chunks = p.shape[0]
    if len(triples) != n_chunks:
        raise ValueError(f"{len(triples)} triples for {n_chunks} chunks")
    if n_chunks == 0 or p.shape[1] == 0:     # empty-selection identities
        vmax = (1 << (code_bits - 1)) - 1
        return jnp.tile(jnp.asarray([[0, 0, 0, vmax, 0]], jnp.int32),
                        (n_chunks, 1))
    if not r.use_pallas:
        consts, flags = scan_ops.packed_triples(triples, code_bits)
        return _fused_batched_ref(p, jnp.asarray(agg3, jnp.uint32),
                                  jnp.asarray(valid3, jnp.uint32),
                                  consts, flags, code_bits)

    consts, flags = scan_ops.packed_triples(triples, code_bits)
    p3 = agg_ops.to3d_words(p)
    a3 = agg_ops.to3d_words(agg3)
    v3 = agg_ops.to3d_words(valid3)
    rows = p3.shape[1]
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("scan_aggregate",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    br = min(br, agg_ops.sum_bound_block_rows(code_bits))
    return K.scan_aggregate_batched_packed(
        jnp.asarray(consts), jnp.asarray(flags), p3, a3, v3,
        code_bits=code_bits, block_rows=br, interpret=r.interpret)


@partial(jax.jit, static_argnums=5)
def _fused_batched_ref(p3, a3, v3, consts, flags, code_bits: int):
    """The whole ref fused path as one compiled call — mask planes and
    batched aggregate fuse, and the traced constants mean every query at
    a given plane shape reuses the same executable."""
    mask3 = scan_ops.mask_planes(p3, consts, flags, code_bits) & v3
    return agg_ref.aggregate_batched_ref(a3, mask3, code_bits)


def _example(rng):
    import numpy as np

    from repro.kernels.scan_filter import ref as scan_ref
    n = 5001                                  # exercises the tail validity
    pw = scan_ref.pack(rng.integers(0, 128, n), 8)
    aw = scan_ref.pack(rng.integers(0, 128, n), 8)
    valid = scan_ref.pack_mask(np.arange(pw.size * 4) < n, 8)
    return (jnp.asarray(pw), jnp.asarray(aw), jnp.asarray(valid),
            64, "lt", 8), {}


dispatch.register(
    "scan_aggregate", fn=scan_aggregate, ref=ref.scan_aggregate_ref,
    tunables={"block_rows": (64, 256, 1024, 4096, 16384)},
    example=_example)
