"""Pure-jnp oracle for the SSD intra-chunk kernel (Mamba-2 dual form).

One chunk of the state-space duality computation (arXiv:2405.21060 §6):
given per-step log-decays l = dt*A, inputs x, and B/C projections, the
chunk-local output is a masked, decay-weighted attention-like product plus
the inbound-state contribution:

  y[i] = C_i . ( sum_{j<=i} exp(cum_i - cum_j) dt_j B_j x_j^T
                 + exp(cum_i) H_in )
  H_out = exp(cum_last) H_in + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T

This mirrors repro.models.ssm._ssd_chunked for a single chunk and is the
ground truth for the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(x, dt, log_a, b, c, h_in):
    """x: (Q, H, P); dt: (Q, H) fp32; log_a: (Q, H) fp32 (= dt * A);
    b, c: (Q, N); h_in: (H, N, P) fp32. Returns (y (Q, H, P), h_out)."""
    q, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    cum = jnp.cumsum(log_a, axis=0)                       # (Q, H)

    seg = cum[:, None, :] - cum[None, :, :]               # (Q, Q, H)
    causal = jnp.tril(jnp.ones((q, q), bool))[:, :, None]
    decay = jnp.where(causal, jnp.exp(jnp.where(causal, seg, -jnp.inf)), 0.0)
    cb = jnp.einsum("in,jn->ij", c, b)                    # (Q, Q)
    att = cb[:, :, None] * decay * dt[None, :, :]         # (Q, Q, H)
    y_intra = jnp.einsum("ijh,jhp->ihp", att, xf)

    y_inter = jnp.einsum("ih,in,hnp->ihp", jnp.exp(cum), c, h_in)

    decay_to_end = jnp.exp(cum[-1][None] - cum)           # (Q, H)
    s_k = jnp.einsum("jh,jn,jhp->hnp", decay_to_end * dt, b, xf)
    h_out = h_in * jnp.exp(cum[-1])[:, None, None] + s_k
    return (y_intra + y_inter).astype(x.dtype), h_out
