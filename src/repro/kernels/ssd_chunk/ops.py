"""Public SSD-scan API: model-layout adapter over the chunk kernel,
dispatched through repro.kernels.dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.ssd_chunk import kernel as K
from repro.kernels.ssd_chunk import ref


def ssd(x, dt, a_log, b, c, chunk: int, use_kernel: bool = True, mode=None):
    """Model layout: x (B, S, H, P); dt (B, S, H) fp32 post-softplus;
    a_log (H,); b/c (B, S, N) (groups=1, broadcast over heads).
    Returns (y (B, S, H, P), final_state (B, H, N, P))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    la = dt * (-jnp.exp(a_log))                     # (B, S, H)

    def to_bh(t, feat):
        # (B, S, H?, F) -> (B*H, NC, Q, F)
        if t.ndim == 3 and t.shape[-1] == h:        # per-head scalar
            t = jnp.moveaxis(t, -1, 1)[..., None]   # (B, H, S, 1)
        elif t.ndim == 3:                            # shared (B, S, N)
            t = jnp.broadcast_to(t[:, None], (bsz, h, s, t.shape[-1]))
        else:                                        # (B, S, H, P)
            t = jnp.moveaxis(t, 2, 1)
        return t.reshape(bsz * h, nc, chunk, -1)

    r = dispatch.resolve(mode, use_kernel=use_kernel)
    if not r.use_pallas:
        ys, hs = [], []
        for bi in range(bsz):
            h_state = jnp.zeros((h, n, p), jnp.float32)
            y_rows = []
            for ci in range(nc):
                sl = slice(ci * chunk, (ci + 1) * chunk)
                y_c, h_state = ref.ssd_chunk_ref(
                    x[bi, sl], dt[bi, sl], la[bi, sl], b[bi, sl], c[bi, sl],
                    h_state)
                y_rows.append(y_c)
            ys.append(jnp.concatenate(y_rows, axis=0))
            hs.append(h_state)
        return jnp.stack(ys), jnp.stack(hs)

    y, hout = K.ssd_scan(to_bh(x, p), to_bh(dt, 1), to_bh(la, 1),
                         to_bh(b, n), to_bh(c, n),
                         interpret=r.interpret)
    y = y.reshape(bsz, h, s, p)
    return jnp.moveaxis(y, 1, 2), hout.reshape(bsz, h, n, p)


def _example(rng):
    key = jax.random.PRNGKey(int(rng.integers(1 << 30)))
    ks = jax.random.split(key, 5)
    bsz, s, h, p, n, chunk = 2, 64, 2, 16, 8, 16
    x = jax.random.normal(ks[0], (bsz, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h), jnp.float32))
    a_log = jax.random.normal(ks[2], (h,), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (bsz, s, n), jnp.float32)
    c = jax.random.normal(ks[4], (bsz, s, n), jnp.float32)
    return (x, dt, a_log, b, c, 16), {}


def _ssd_ref(x, dt, a_log, b, c, chunk, **kw):
    return ssd(x, dt, a_log, b, c, chunk, use_kernel=False)


dispatch.register("ssd_chunk", fn=ssd, ref=_ssd_ref, tunables={},
                  example=_example)
