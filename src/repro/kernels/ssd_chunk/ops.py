"""Public SSD-scan API: model-layout adapter over the chunk kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk import kernel as K
from repro.kernels.ssd_chunk import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd(x, dt, a_log, b, c, chunk: int, use_kernel: bool = True):
    """Model layout: x (B, S, H, P); dt (B, S, H) fp32 post-softplus;
    a_log (H,); b/c (B, S, N) (groups=1, broadcast over heads).
    Returns (y (B, S, H, P), final_state (B, H, N, P))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    la = dt * (-jnp.exp(a_log))                     # (B, S, H)

    def to_bh(t, feat):
        # (B, S, H?, F) -> (B*H, NC, Q, F)
        if t.ndim == 3 and t.shape[-1] == h:        # per-head scalar
            t = jnp.moveaxis(t, -1, 1)[..., None]   # (B, H, S, 1)
        elif t.ndim == 3:                            # shared (B, S, N)
            t = jnp.broadcast_to(t[:, None], (bsz, h, s, t.shape[-1]))
        else:                                        # (B, S, H, P)
            t = jnp.moveaxis(t, 2, 1)
        return t.reshape(bsz * h, nc, chunk, -1)

    if not use_kernel:
        ys, hs = [], []
        for bi in range(bsz):
            y_rows, h_rows = [], []
            h_state = jnp.zeros((h, n, p), jnp.float32)
            for ci in range(nc):
                sl = slice(ci * chunk, (ci + 1) * chunk)
                y_c, h_state = ref.ssd_chunk_ref(
                    x[bi, sl], dt[bi, sl], la[bi, sl], b[bi, sl], c[bi, sl],
                    h_state)
                y_rows.append(y_c)
            ys.append(jnp.concatenate(y_rows, axis=0))
            hs.append(h_state)
        return jnp.stack(ys), jnp.stack(hs)

    y, hout = K.ssd_scan(to_bh(x, p), to_bh(dt, 1), to_bh(la, 1),
                         to_bh(b, n), to_bh(c, n),
                         interpret=_interpret())
    y = y.reshape(bsz, h, s, p)
    return jnp.moveaxis(y, 1, 2), hout.reshape(bsz, h, n, p)
