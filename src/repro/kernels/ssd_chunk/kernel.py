"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (GPU reference: Triton kernels in
state-spaces/mamba): the sequential chunk recurrence runs as a grid over
(batch*heads, n_chunks) with the inter-chunk state carried in VMEM scratch
across the sequential chunk dimension — one kernel launch computes intra-
chunk dual-form matmuls (MXU) AND the state recurrence, so the state never
round-trips to HBM between chunks.

Layout: head-major (B*H, NC, Q, ...) so each grid row owns one head's
whole sequence; Q (chunk len) and P (head dim) are the MXU-aligned dims.
Per-head state (N, P) = (128, 64) fits VMEM trivially.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, y_ref, hout_ref,
                h_scr, *, q: int, n: int, p: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0]                            # (Q, 1) fp32
    la = la_ref[0, 0]                            # (Q, 1) fp32
    b = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    cum = jnp.cumsum(la, axis=0)                 # (Q, 1)
    # intra-chunk: att[i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j, j<=i
    seg = cum - cum.reshape(1, q)                # (Q, Q)
    causal = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    seg = jnp.where(causal, seg, NEG_INF)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * jnp.exp(seg) * dt.reshape(1, q)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inbound state contribution: y += exp(cum) * (C @ H_in)
    ch = jax.lax.dot_general(c, h_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = y + jnp.exp(cum) * ch

    # state update: H_out = exp(cum_last) H_in + B^T (decay_to_end*dt*x)
    d2e = jnp.exp(cum[q - 1, 0] - cum)           # (Q, 1)
    bw = b * (d2e * dt)                          # (Q, N) weighted
    s_k = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    h_scr[...] = h_scr[...] * jnp.exp(cum[q - 1, 0]) + s_k

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _():
        hout_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, dt, log_a, b, c, *, interpret: bool = True):
    """x: (BH, NC, Q, P); dt/log_a: (BH, NC, Q, 1) fp32;
    b/c: (BH, NC, Q, N). Returns (y (BH, NC, Q, P), h_out (BH, N, P))."""
    bh, nc, q, p = x.shape
    n = b.shape[-1]
    kernel = functools.partial(_ssd_kernel, q=q, n=n, p=p, nc=nc)
    grid = (bh, nc)
    spec = lambda last: pl.BlockSpec((1, 1, q, last),
                                     lambda i, j: (i, j, 0, 0))
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec(p), spec(1), spec(1), spec(n), spec(n)],
        out_specs=[spec(p),
                   pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, nc, q, p), x.dtype),
                   jax.ShapeDtypeStruct((bh, n, p), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, log_a, b, c)
    return y, hout
