"""Pallas TPU kernels (validated in interpret mode against ref.py oracles):

- scan_filter:       BitWeaving-H predicate scan (the paper's workload)
- aggregate:         fused masked aggregate (scan+aggregate query)
- flash_attention:   blockwise online-softmax attention w/ causal skip
- decode_attention:  split-K one-token decode over the ring KV cache
- ssd_chunk:         Mamba-2 SSD chunk scan with VMEM-carried state

Each package: kernel.py (pallas_call + BlockSpec), ops.py (public jit'd
wrapper + jnp fallback), ref.py (pure-jnp oracle).
"""
