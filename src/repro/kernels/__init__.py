"""Pallas TPU kernels (validated in interpret mode against ref.py oracles):

- scan_filter:       BitWeaving-H predicate scan (the paper's workload)
- aggregate:         fused masked aggregate (scan+aggregate query)
- flash_attention:   blockwise online-softmax attention w/ causal skip
- decode_attention:  split-K one-token decode over the ring KV cache
                     (kernel-native (B, KVH, S, D) layout — the models'
                     cache is stored this way, so decode is zero-copy)
- ssd_chunk:         Mamba-2 SSD chunk scan with VMEM-carried state

Each package: kernel.py (pallas_call + BlockSpec), ops.py (public jit'd
wrapper), ref.py (pure-jnp oracle).

Dispatch architecture (dispatch.py): every ops.py routes through one
KernelMode switch — PALLAS (the kernel, interpret mode off-TPU), XLA_REF
(the oracle; differentiable), AUTO (kernel + autotuned block sizes) — and
registers itself in a KernelOp registry carrying its oracle, tunable
block-size grid, and an example-input factory, so tests and tools can
enumerate and parity-check every family generically. The legacy
`use_kernel=False` flag maps to XLA_REF.

Autotuning (tune.py): ops consult a JSON on-disk cache (keyed by
op | backend | shape) for block sizes instead of hardcoding DEFAULT_*
constants; `tune.autotune` runs the timed sweep that populates it (wired
into benchmarks/kernels_bench.py, trajectory in BENCH_kernels.json).
"""
