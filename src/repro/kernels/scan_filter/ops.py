"""Public scan-filter API: all six predicates composed from the kernel's
{ge, eq} primitives, dispatched through repro.kernels.dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.scan_filter import kernel as K
from repro.kernels.scan_filter import ref
from repro.kernels.scan_filter.ref import OPS, field_masks


def _to_2d(words):
    n = words.shape[0]
    pad = (-n) % K.LANES
    w = jnp.pad(words, (0, pad))
    return w.reshape(-1, K.LANES), n


def _block_rows(rows: int, code_bits: int, tuned: bool) -> int:
    default = min(K.DEFAULT_BLOCK_ROWS, rows)
    if not tuned:
        return default
    got = tune.best_params("scan_filter",
                           tune.shape_key(rows=rows, bits=code_bits),
                           {"block_rows": default})["block_rows"]
    return max(1, min(int(got), rows))


def scan_filter(words, constant: int, op: str, code_bits: int,
                block_rows: int | None = None, use_kernel: bool = True,
                mode=None):
    """words: (n_words,) uint32 packed codes -> (n_words,) packed mask.

    Composition rules (payload max = 2^(bits-1) - 1):
      lt = ~ge(C);  le = lt(C+1) | all-if-C==max;  gt = ge(C+1, 0-if-max);
      ne = ~eq.
    """
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    r = dispatch.resolve(mode, use_kernel=use_kernel)
    if not r.use_pallas:
        return ref.scan_ref(words, constant, op, code_bits)
    if words.shape[0] == 0:           # zero-row grid is undefined
        return jnp.zeros((0,), jnp.uint32)

    delim, _, value = field_masks(code_bits)
    vmax = int(value)
    w2d, n = _to_2d(jnp.asarray(words, jnp.uint32))
    br = block_rows or _block_rows(w2d.shape[0], code_bits, r.tuned)
    run = lambda c, o: K.scan_packed(w2d, c, op=o, code_bits=code_bits,
                                     block_rows=br, interpret=r.interpret)
    dm = jnp.uint32(delim)
    c = int(constant)
    if op == "ge":
        out = run(c, "ge")
    elif op == "lt":
        out = ~run(c, "ge") & dm
    elif op == "gt":
        out = run(c + 1, "ge") if c < vmax else jnp.zeros_like(w2d)
    elif op == "le":
        out = (~run(c + 1, "ge") & dm if c < vmax
               else jnp.full_like(w2d, dm))
    elif op == "eq":
        out = run(c, "eq")
    else:  # ne
        out = ~run(c, "eq") & dm

    return out.reshape(-1)[:n]


def _example(rng):
    codes = rng.integers(0, 128, 4096)
    return (jnp.asarray(ref.pack(codes, 8)), 64, "lt", 8), {}


dispatch.register(
    "scan_filter", fn=scan_filter, ref=ref.scan_ref,
    tunables={"block_rows": (64, 256, 1024, 4096, 16384, 65536)},
    example=_example)
