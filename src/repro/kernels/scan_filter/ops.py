"""Public scan-filter API: all six predicates composed from the kernel's
{ge, eq} primitives, dispatched through repro.kernels.dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.scan_filter import kernel as K
from repro.kernels.scan_filter import ref
from repro.kernels.scan_filter.ref import OPS, field_masks


def _to_2d(words):
    n = words.shape[0]
    pad = (-n) % K.LANES
    w = jnp.pad(words, (0, pad))
    return w.reshape(-1, K.LANES), n


def _block_rows(rows: int, code_bits: int, tuned: bool) -> int:
    default = min(K.DEFAULT_BLOCK_ROWS, rows)
    if not tuned:
        return default
    got = tune.best_params("scan_filter",
                           tune.shape_key(rows=rows, bits=code_bits),
                           {"block_rows": default})["block_rows"]
    return max(1, min(int(got), rows))


def scan_filter(words, constant: int, op: str, code_bits: int,
                block_rows: int | None = None, use_kernel: bool = True,
                mode=None):
    """words: (n_words,) uint32 packed codes -> (n_words,) packed mask.

    Composition rules (payload max = 2^(bits-1) - 1):
      lt = ~ge(C);  le = lt(C+1) | all-if-C==max;  gt = ge(C+1, 0-if-max);
      ne = ~eq.
    """
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    r = dispatch.resolve(mode, use_kernel=use_kernel)
    dispatch.count_launch("scan_filter")
    if not r.use_pallas:
        return ref.scan_ref(words, constant, op, code_bits)
    if words.shape[0] == 0:           # zero-row grid is undefined
        return jnp.zeros((0,), jnp.uint32)

    delim, _, value = field_masks(code_bits)
    vmax = int(value)
    w2d, n = _to_2d(jnp.asarray(words, jnp.uint32))
    br = block_rows or _block_rows(w2d.shape[0], code_bits, r.tuned)
    run = lambda c, o: K.scan_packed(w2d, c, op=o, code_bits=code_bits,
                                     block_rows=br, interpret=r.interpret)
    dm = jnp.uint32(delim)
    c = int(constant)
    if op == "ge":
        out = run(c, "ge")
    elif op == "lt":
        out = ~run(c, "ge") & dm
    elif op == "gt":
        out = run(c + 1, "ge") if c < vmax else jnp.zeros_like(w2d)
    elif op == "le":
        out = (~run(c + 1, "ge") & dm if c < vmax
               else jnp.full_like(w2d, dm))
    elif op == "eq":
        out = run(c, "eq")
    else:  # ne
        out = ~run(c, "eq") & dm

    return out.reshape(-1)[:n]


# --------------------------------------------------------------------------
# batched (multi-chunk) path
# --------------------------------------------------------------------------
# One column's chunks differ only by their translated constant (each FOR
# chunk subtracts its own base), so a batched launch carries the per-chunk
# predicate as data, not code: canonical (prim, constant, invert) triples
# packed into per-chunk scalar planes (the SMEM scalar-prefetch idiom).

def canonical_pred(op: str, constant: int, code_bits: int):
    """Reduce any of the six predicates at any integer constant to the
    kernel-primitive triple (prim in {ge, eq}, constant in [0, vmax],
    invert) with tautologies folded: (ge, 0, False) selects every valid
    row, (ge, 0, True) selects none. Mirrors scan_filter's composition
    rules exactly (payload codes are unsigned, <= vmax)."""
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    vmax = (1 << (code_bits - 1)) - 1
    c = int(constant)
    all_, none = ("ge", 0, False), ("ge", 0, True)
    if op == "ge":
        return all_ if c <= 0 else (none if c > vmax else ("ge", c, False))
    if op == "gt":
        return all_ if c < 0 else (none if c >= vmax else ("ge", c + 1,
                                                           False))
    if op == "lt":
        return none if c <= 0 else (all_ if c > vmax else ("ge", c, True))
    if op == "le":
        return none if c < 0 else (all_ if c >= vmax else ("ge", c + 1,
                                                           True))
    if op == "eq":
        return none if not 0 <= c <= vmax else ("eq", c, False)
    return all_ if not 0 <= c <= vmax else ("eq", c, True)   # ne


def packed_triples(triples, code_bits: int):
    """Canonical triples -> (consts, flags) int32 numpy planes for a
    batched launch: consts[k] is chunk k's constant replicated into every
    field of a packed word; flags bit0 = eq-primitive, bit1 = invert."""
    import numpy as np
    _, _, value = field_masks(code_bits)
    vmax = int(value)
    n_fields = 32 // code_bits
    consts = np.zeros(len(triples), np.int32)
    flags = np.zeros(len(triples), np.int32)
    for k, (prim, c, inv) in enumerate(triples):
        pc = 0
        for f in range(n_fields):
            pc |= (int(c) & vmax) << (f * code_bits)
        consts[k] = pc                 # delimiter bits stay 0: int32-safe
        flags[k] = (1 if prim == "eq" else 0) | (2 if inv else 0)
    return consts, flags


@partial(jax.jit, static_argnums=3)
def mask_planes(words3, consts, flags, code_bits: int):
    """Compiled core of the batched mask: per-chunk constants and flags
    enter as *traced* planes, so one compilation serves every predicate
    constant at a given (n_chunks, n_words, code_bits) — a warm trace
    replay never retraces, whatever the query mix."""
    delim, low, _ = field_masks(code_bits)
    x = jnp.asarray(words3, jnp.uint32)
    h = jnp.uint32(delim)
    C = jnp.asarray(consts).astype(jnp.uint32)[:, None]
    m_ge = ((x | h) - C) & h
    m_eq = (~(((x ^ C) | h) - jnp.uint32(low))) & h
    is_eq = (jnp.asarray(flags) & 1) == 1
    inv = (jnp.asarray(flags) & 2) == 2
    m = jnp.where(is_eq[:, None], m_eq, m_ge)
    return jnp.where(inv[:, None], m ^ h, m)  # m subset-of h: ^h == ~m & h


def mask_batched(words3, triples, code_bits: int):
    """Pure mask math for the batched scan: (n_chunks, n_words) packed
    codes + per-chunk canonical triples -> (n_chunks, n_words) packed
    masks, one compiled elementwise expression (the kernel's GE/EQ
    bit-tricks with the constant broadcast per chunk). No launch is
    counted here — callers that expose it as a dispatch wrap it."""
    consts, flags = packed_triples(triples, code_bits)
    return mask_planes(jnp.asarray(words3, jnp.uint32), consts, flags,
                       code_bits)


def scan_filter_batched(words3, triples, code_bits: int, mode=None):
    """(n_chunks, n_words) packed codes + per-chunk canonical triples ->
    (n_chunks, n_words) packed masks in ONE dispatch.

    The per-word math is elementwise (no accumulator), so PALLAS and
    XLA_REF share the jnp form — the Pallas win lives in the
    fused/aggregate stages that consume the mask.
    """
    dispatch.resolve(mode)            # validates the mode string
    dispatch.count_launch("scan_filter")
    return mask_batched(words3, triples, code_bits)


def _example(rng):
    codes = rng.integers(0, 128, 4096)
    return (jnp.asarray(ref.pack(codes, 8)), 64, "lt", 8), {}


dispatch.register(
    "scan_filter", fn=scan_filter, ref=ref.scan_ref,
    tunables={"block_rows": (64, 256, 1024, 4096, 16384, 65536)},
    example=_example)
