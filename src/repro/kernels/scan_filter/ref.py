"""Pure-jnp oracle for the BitWeaving-H predicate scan.

Layout: `code_bits`-wide codes packed little-endian into int32 words, one
delimiter (MSB of each field) kept 0 in the data. codes_per_word =
32 // code_bits. A scan produces a packed mask word per data word with the
delimiter bit of each matching field set.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

OPS = ("lt", "le", "gt", "ge", "eq", "ne")


def codes_per_word(code_bits: int) -> int:
    return 32 // code_bits


def field_masks(code_bits: int):
    """(delimiter_mask, low_mask, value_mask) as uint32 scalars."""
    c = codes_per_word(code_bits)
    delim = 0
    low = 0
    for i in range(c):
        delim |= 1 << (i * code_bits + code_bits - 1)
        low |= 1 << (i * code_bits)
    value = (1 << (code_bits - 1)) - 1   # payload bits per field
    return np.uint32(delim), np.uint32(low), np.uint32(value)


def pack(codes, code_bits: int):
    """codes: (N,) ints in [0, 2^(bits-1)) -> packed uint32 words
    (N padded to a multiple of codes_per_word)."""
    codes = np.asarray(codes, np.uint32)
    c = codes_per_word(code_bits)
    n = len(codes)
    pad = (-n) % c
    codes = np.pad(codes, (0, pad))
    codes = codes.reshape(-1, c)
    out = np.zeros(len(codes), np.uint32)
    for i in range(c):
        out |= codes[:, i] << np.uint32(i * code_bits)
    return out


def unpack(words, code_bits: int):
    words = jnp.asarray(words, jnp.uint32)
    c = codes_per_word(code_bits)
    shifts = jnp.arange(c, dtype=jnp.uint32) * code_bits
    vals = (words[:, None] >> shifts[None, :]) & jnp.uint32(
        (1 << code_bits) - 1)
    return vals.reshape(-1)


def pack_mask(sel, code_bits: int):
    """Boolean per-code selection -> packed delimiter-bit mask words
    (inverse of unpack_mask; selection padded to a word multiple with
    False). Used to build validity masks that cancel tail/shard padding."""
    sel = np.asarray(sel, bool)
    c = codes_per_word(code_bits)
    pad = (-len(sel)) % c
    sel = np.pad(sel, (0, pad)).reshape(-1, c)
    out = np.zeros(len(sel), np.uint32)
    for i in range(c):
        out |= sel[:, i].astype(np.uint32) << np.uint32(
            i * code_bits + code_bits - 1)
    return out


def unpack_mask(mask_words, code_bits: int):
    """Packed delimiter-bit mask -> boolean per code."""
    c = codes_per_word(code_bits)
    words = jnp.asarray(mask_words, jnp.uint32)
    shifts = (jnp.arange(c, dtype=jnp.uint32) * code_bits + code_bits - 1)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(-1).astype(bool)


def scan_ref(words, constant: int, op: str, code_bits: int):
    """Oracle: unpack -> compare -> repack delimiter-bit mask."""
    if op not in OPS:
        raise ValueError(f"unknown predicate op {op!r}; expected one of "
                         f"{OPS}")
    vals = unpack(words, code_bits)
    fn = {"lt": jnp.less, "le": jnp.less_equal, "gt": jnp.greater,
          "ge": jnp.greater_equal, "eq": jnp.equal,
          "ne": jnp.not_equal}[op]
    hits = fn(vals, jnp.uint32(constant))
    c = codes_per_word(code_bits)
    hits = hits.reshape(-1, c)
    shifts = (jnp.arange(c, dtype=jnp.uint32) * code_bits + code_bits - 1)
    return jnp.bitwise_or.reduce(
        jnp.where(hits, jnp.uint32(1) << shifts[None, :], jnp.uint32(0)),
        axis=1)
