"""BitWeaving-H predicate scan as a Pallas TPU kernel.

TPU adaptation of Li & Patel (SIGMOD'13): codes are packed `codes_per_word`
to an int32 lane with a per-field delimiter MSB kept 0 in the data; a whole
word of codes is compared against a constant with three VPU integer ops
(no per-code unpacking, no warp primitives needed):

  GE:  ((X | H) - C) & H          — the borrow clears the delimiter
  EQ:  ~((X^C | H) - L) & H       — zero-test via low-bit borrow

The grid streams (block_rows, 128)-word VMEM tiles from HBM; arithmetic
intensity is ~3 int-ops per 4 bytes, i.e. the paper's bandwidth-bound scan
regime (this kernel is what `core_perf` measures for the analytic model).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.scan_filter.ref import field_masks

LANES = 128
DEFAULT_BLOCK_ROWS = 256


def _scan_kernel(x_ref, o_ref, *, op: str, const_packed, delim, low):
    x = x_ref[...]
    h = jnp.uint32(delim)
    if op == "ge":
        o_ref[...] = ((x | h) - jnp.uint32(const_packed)) & h
    elif op == "eq":
        z = x ^ jnp.uint32(const_packed)
        o_ref[...] = (~((z | h) - jnp.uint32(low))) & h
    else:
        raise ValueError(op)


@functools.partial(jax.jit,
                   static_argnames=("constant", "op", "code_bits",
                                    "block_rows", "interpret"))
def scan_packed(words2d, constant: int, *, op: str, code_bits: int,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """words2d: (rows, 128) uint32 packed codes. Returns packed delimiter
    mask words of the same shape. `op` is a kernel primitive: ge | eq.

    Arbitrary row counts are supported: rows are zero-padded up to the next
    block multiple and the pad is sliced off the output."""
    rows = words2d.shape[0]
    assert words2d.shape[1] == LANES, words2d.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        words2d = jnp.pad(words2d, ((0, pad), (0, 0)))
    grid_rows = rows + pad
    delim, low, value = field_masks(code_bits)
    c = 32 // code_bits
    const_packed = 0
    for i in range(c):
        const_packed |= (int(constant) & int(value)) << (i * code_bits)

    kernel = functools.partial(_scan_kernel, op=op,
                               const_packed=const_packed,
                               delim=int(delim), low=int(low))
    out = pl.pallas_call(
        kernel,
        grid=(grid_rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid_rows, LANES), jnp.uint32),
        interpret=interpret,
    )(words2d)
    return out[:rows] if pad else out
