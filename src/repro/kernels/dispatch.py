"""Unified kernel dispatch: one mode switch + registry for all families.

Every kernel family used to carry its own copy-pasted `_interpret()` probe
and `use_kernel` flag; this module centralizes that decision behind
`KernelMode` (the mamba-jax interface idiom) and keeps a registry of the
public ops so tests/tools can enumerate and parity-check every family
without knowing the packages:

- PALLAS:  always run the Pallas kernel (interpret mode off-TPU, compiled
           on TPU).
- XLA_REF: the pure-jnp oracle (ref.py) — differentiable, any backend.
- AUTO:    Pallas with autotuned block sizes (repro.kernels.tune); today
           resolves to PALLAS everywhere, and is the hook where future
           shape-based fallbacks live.

Ops accept `mode=` (str or KernelMode) plus the legacy `use_kernel=` bool
(False => XLA_REF) so existing call sites keep working.
"""
from __future__ import annotations

import enum
import importlib
from dataclasses import dataclass
from typing import Any, Callable, Mapping

import jax


class KernelMode(enum.Enum):
    PALLAS = "pallas"
    XLA_REF = "xla_ref"
    AUTO = "auto"


@dataclass(frozen=True)
class Resolved:
    """A concrete dispatch decision for one call."""
    use_pallas: bool
    interpret: bool
    tuned: bool        # consult the tune cache for block sizes


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(mode: KernelMode | str | None = None, *,
            use_kernel: bool = True) -> Resolved:
    """Collapse (mode, legacy use_kernel) into a Resolved decision."""
    if not use_kernel:
        mode = KernelMode.XLA_REF
    mode = KernelMode(mode) if mode is not None else KernelMode.AUTO
    if mode is KernelMode.XLA_REF:
        return Resolved(use_pallas=False, interpret=False, tuned=False)
    return Resolved(use_pallas=True, interpret=not on_tpu(),
                    tuned=mode is KernelMode.AUTO)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelOp:
    """One registered kernel family.

    fn/ref share the public signature; `fn` additionally accepts `mode=`.
    `example(rng)` returns (args, kwargs) exercising the op for parity and
    autotune sweeps. `tunables` maps block-size kwarg -> candidate values.
    """
    name: str
    fn: Callable
    ref: Callable
    tunables: Mapping[str, tuple]
    example: Callable[[Any], tuple]


_REGISTRY: dict[str, KernelOp] = {}

_OP_MODULES = ("scan_filter", "aggregate", "scan_aggregate",
               "scan_compressed", "group_aggregate", "flash_attention",
               "decode_attention", "ssd_chunk")


def register(name: str, *, fn, ref, tunables=None, example=None) -> KernelOp:
    op = KernelOp(name=name, fn=fn, ref=ref,
                  tunables=dict(tunables or {}), example=example)
    _REGISTRY[name] = op
    return op


def ensure_registered() -> None:
    """Import every kernel family so module-level register() calls ran."""
    for mod in _OP_MODULES:
        importlib.import_module(f"repro.kernels.{mod}.ops")


def get(name: str) -> KernelOp:
    ensure_registered()
    return _REGISTRY[name]


def registered() -> dict[str, KernelOp]:
    ensure_registered()
    return dict(_REGISTRY)


# --------------------------------------------------------------------------
# launch accounting
# --------------------------------------------------------------------------
# Per-family dispatch counters so tests and benchmarks can assert that
# batched execution really collapses N per-chunk launches into ~1 per
# (column, encoding) group. A "launch" is one host->device dispatch of a
# family's public op — Pallas kernel and XLA_REF oracle alike (the cost
# being measured is the per-call round trip, which both pay).
#
# The counters themselves live in repro.obs.metrics now: increments land
# in every active MetricsRegistry scope (an engine wrapping execution in
# its own scope sees only its own launches), and these four functions are
# backward-compatible shims over the always-active *default* scope — the
# exact semantics the old module-global dict had.

from repro.obs import metrics as _metrics  # noqa: E402  (import cycle:
#   obs.metrics is stdlib-only, safe below the jax import)


def count_launch(name: str, n: int = 1) -> None:
    """Record `n` dispatches for kernel family `name` (in every active
    metrics scope)."""
    _metrics.count_launch(name, n)


def record_batch(name: str, width: int, n_chunks: int) -> None:
    """Record one *batched* dispatch of family `name` covering `n_chunks`
    chunks at unified payload width `width` — the width-group detail the
    trace's launch spans carry. Does not add to launch_counts();
    count_launch still owns the dispatch count."""
    _metrics.record_batch(name, width, n_chunks)


def launch_counts() -> dict[str, int]:
    """Snapshot of per-family launch counts since the last reset (the
    default scope — process-global, as before)."""
    return _metrics.default_registry().launch_counts()


def total_launches() -> int:
    return _metrics.default_registry().total_launches()


def reset_launch_counts() -> None:
    """Reset the default scope's launch counters. Engine-scoped
    registries are unaffected — reset your own scope directly."""
    _metrics.default_registry().reset_launches()
