"""Pure-jnp oracle for grouped aggregation over int32 code planes.

The grouped analogue of aggregate/ref.py: per group the sum leaves as two
normalized 16-bit planes (sum_hi << 16 | sum_lo) plus a count, stacked as
an int32 `(n_groups, 3)` accumulator plane — exact for any input the
kernels accept, psum/all-gather safe across shards, reassembled host-side
by `ops.finalize_grouped`.

Exactness staging mirrors aggregate/ref.split_sum: rows are reduced in
<= _STAGE-element segments (each segment partial < 2^27, int32-exact for
any code width), then the staged partials are split 16/16 and summed —
so the oracle stays bit-exact even when one shard holds far more than
2^16 rows of a 16-bit column, matching the kernels' per-tile split.

`group_keys` must be sorted ascending (the dense domain is an arange and
join build keys are sorted before dispatch); the oracle maps codes to
group slots with a searchsorted instead of materializing the
(groups x rows) compare plane the kernel builds tile by tile.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_STAGE = 4096        # segment partials stay < 2^27: exact in int32


def _staged_group_sums(idx, vals, sel, n_groups: int):
    """Segment-reduce (values, selected) into per-group (sum_lo, sum_hi,
    count) planes, staging the sums so no int32 partial ever wraps.

    idx:  (n,) int32 group slot per element (n_groups = out-of-domain)
    vals: (n,) int32 non-negative codes < 2^16
    sel:  (n,) bool
    """
    n = idx.shape[0]
    pad = (-n) % _STAGE
    if pad:
        idx = jnp.pad(idx, (0, pad), constant_values=n_groups)
        vals = jnp.pad(vals, (0, pad))
        sel = jnp.pad(sel, (0, pad))
        n += pad
    n_stages = n // _STAGE
    # one flat segment id per (stage, group); the +1 slot absorbs
    # out-of-domain codes and padding
    stage = jnp.repeat(jnp.arange(n_stages, dtype=jnp.int32), _STAGE)
    seg = stage * (n_groups + 1) + idx
    v = jnp.where(sel, vals, 0)
    c = sel.astype(jnp.int32)
    part = jax.ops.segment_sum(v, seg, num_segments=n_stages * (n_groups + 1))
    cnt = jax.ops.segment_sum(c, seg, num_segments=n_stages * (n_groups + 1))
    part = part.reshape(n_stages, n_groups + 1)[:, :n_groups]
    cnt = cnt.reshape(n_stages, n_groups + 1)[:, :n_groups]
    lo = jnp.sum(part & 0xFFFF, axis=0)
    hi = jnp.sum(part >> 16, axis=0)
    return jnp.stack([lo & 0xFFFF, hi + (lo >> 16), jnp.sum(cnt, axis=0)],
                     axis=1)


def _slots(keys, group_keys):
    """Map codes to sorted-group-key slots; non-members -> n_groups."""
    g = group_keys.shape[0]
    idx = jnp.searchsorted(group_keys, keys).astype(jnp.int32)
    hit = group_keys[jnp.clip(idx, 0, g - 1)] == keys
    return jnp.where(hit, idx, g)


def group_sum_count_ref(keys, vals, sel, group_keys):
    """(rows, LANES) int32 key/value/select planes + sorted (G,) group
    keys -> int32 (G, 3) of [sum_lo, sum_hi, count] rows."""
    k = jnp.asarray(keys, jnp.int32).reshape(-1)
    v = jnp.asarray(vals, jnp.int32).reshape(-1)
    s = jnp.asarray(sel, jnp.int32).reshape(-1) > 0
    gk = jnp.asarray(group_keys, jnp.int32)
    return _staged_group_sums(_slots(k, gk), v, s, gk.shape[0])


@jax.jit
def group_sum_count_batched_ref(keys3, vals3, sel3, group_keys):
    """Batched oracle: (n_chunks, rows, LANES) planes -> (n_chunks, G, 3),
    one accumulator plane per chunk, bit-identical to per-chunk calls.
    Jitted: the eager vmap would re-trace its segment_sums every call,
    which dominates any grouped query that dispatches through it."""
    k = jnp.asarray(keys3, jnp.int32)
    v = jnp.asarray(vals3, jnp.int32)
    s = jnp.asarray(sel3, jnp.int32)
    gk = jnp.asarray(group_keys, jnp.int32)
    fn = jax.vmap(lambda kc, vc, sc: _staged_group_sums(
        _slots(kc.reshape(-1), gk), vc.reshape(-1),
        sc.reshape(-1) > 0, gk.shape[0]))
    return fn(k, v, s)


def _rle_one(vals, lens, group_keys, pred):
    g = group_keys.shape[0]
    v = jnp.asarray(vals, jnp.int32).reshape(-1)
    l = jnp.asarray(lens, jnp.int32).reshape(-1)
    live = l > 0
    if pred is not None:
        prim, const, invert = pred
        cmp = (v >= const) if prim == "ge" else (v == const)
        live = live & (cmp ^ invert)
    idx = _slots(v, group_keys)
    idx = jnp.where(live, idx, g)
    # run sums: a run of length n contributes n * value; n * v < 2^31
    # per run and per-chunk totals stay < 2^31 (MAX_CHUNK_ROWS * vmax)
    s = jax.ops.segment_sum(l * v, idx, num_segments=g + 1)[:g]
    c = jax.ops.segment_sum(l, idx, num_segments=g + 1)[:g]
    return jnp.stack([s & 0xFFFF, s >> 16, c], axis=1)


def rle_group_accumulate_ref(vals, lens, group_keys, pred=None):
    """RLE run planes -> (G, 3): run (v, n) contributes n to group v's
    count and n*v to its sum (the pre-grouped path's oracle). `pred` is an
    optional canonical (prim, const, invert) triple on the run value."""
    gk = jnp.asarray(group_keys, jnp.int32)
    return _rle_one(vals, lens, gk, pred)


@partial(jax.jit, static_argnames=("pred",))
def rle_group_accumulate_batched_ref(vals3, lens3, group_keys, pred=None):
    """(n_chunks, runs, LANES) run planes -> (n_chunks, G, 3). Jitted
    (pred static: a canonical triple or None) for the same reason as the
    dense batched oracle."""
    v = jnp.asarray(vals3, jnp.int32)
    l = jnp.asarray(lens3, jnp.int32)
    gk = jnp.asarray(group_keys, jnp.int32)
    return jax.vmap(lambda vc, lc: _rle_one(vc, lc, gk, pred))(v, l)
