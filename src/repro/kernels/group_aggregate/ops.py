"""Public grouped-aggregation API, dispatched through
repro.kernels.dispatch.

`group_sum_count[_batched]` is the dense-accumulator-plane strategy:
SELECT key, count(*), sum(val) GROUP BY key over int32 code planes, with
the group domain handed in explicitly (an arange when a FOR frame bounds
the key range, the sorted distinct build keys for a hash join).
`rle_group_accumulate[_batched]` is the fused pre-grouped strategy over
RLE run planes — a run of length n contributes n to one group's count and
n*value to its sum in registers, no scatter. The sort/hash fallback for
plain high-cardinality chunks lives host-side in repro.query.relational
(it is a numpy path, not a kernel).

All paths return int32 `(G, 3)` (or batched `(n_chunks, G, 3)`) planes of
normalized [sum_lo, sum_hi, count] rows; `finalize_grouped` reassembles
exact host ints including the FOR base fix-up sum += base * count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch, tune
from repro.kernels.group_aggregate import kernel as K
from repro.kernels.group_aggregate import ref
from repro.kernels.scan_filter.kernel import LANES

# dense strategy cutoff: above this many groups the accumulator plane
# (and its (group_block, block_rows, LANES) compare tiles) stops paying
# for itself and chunks fall back to the host sort/hash path
DENSE_MAX_GROUPS = 1024

# a (block_rows, LANES) tile of 16-bit codes must sum < 2^31 so the
# per-tile partial is exact before the 16/16 split (cf. aggregate/ops.py)
_MAX_BLOCK_ROWS = (2**31 - 1) // (LANES * ((1 << 15) - 1))


def _params(rows: int, groups: int, tuned: bool,
            block_rows: int | None, group_block: int | None):
    br, gb = block_rows, group_block
    defaults = {"block_rows": min(K.DEFAULT_BLOCK_ROWS, rows),
                "group_block": min(K.DEFAULT_GROUP_BLOCK, groups)}
    if (br is None or gb is None) and tuned:
        best = tune.best_params("group_aggregate",
                                tune.shape_key(rows=rows, groups=groups),
                                defaults)
        br = best["block_rows"] if br is None else br
        gb = best["group_block"] if gb is None else gb
    br = defaults["block_rows"] if br is None else br
    gb = defaults["group_block"] if gb is None else gb
    br = max(1, min(int(br), rows, _MAX_BLOCK_ROWS))
    gb = max(1, min(int(gb), groups))
    return br, gb


def _to_plane(x):
    x = jnp.asarray(x, jnp.int32).reshape(-1)
    return jnp.pad(x, (0, (-x.shape[0]) % LANES)).reshape(-1, LANES)


def lift_chunks(chunks):
    """Ragged per-chunk 1-D arrays -> one (n_chunks, rows, LANES) stack.

    Host inputs pad/stack in numpy and cross to the device once —
    O(n_chunks) un-jitted jnp dispatches would otherwise dominate every
    encoded grouped query. Traced inputs (the sharded per-shard closure)
    keep the jnp path."""
    if not any(isinstance(c, jax.core.Tracer) for c in chunks):
        arrs = [np.asarray(c, np.int32).reshape(-1) for c in chunks]
        rows = max(max((-(-a.size // LANES) for a in arrs), default=0), 1)
        out = np.zeros((len(arrs), rows * LANES), np.int32)
        for i, a in enumerate(arrs):
            out[i, : a.size] = a
        return jnp.asarray(out.reshape(len(arrs), rows, LANES))
    planes = [_to_plane(c) for c in chunks]
    rows = max(max((p.shape[0] for p in planes), default=0), 1)
    return jnp.stack([jnp.pad(p, ((0, rows - p.shape[0]), (0, 0)))
                      for p in planes])


def group_sum_count_batched(keys3, vals3, sel3, group_keys, *, mode=None,
                            block_rows: int | None = None,
                            group_block: int | None = None):
    """Dense grouped aggregate, all chunks in ONE launch.

    keys3/vals3/sel3: (n_chunks, rows, LANES) int32 code planes (padded
    rows carry sel=0); group_keys: sorted (G,) int32. Returns
    int32[n_chunks, G, 3] of normalized [sum_lo, sum_hi, count] rows.
    """
    r = dispatch.resolve(mode)
    dispatch.count_launch("group_aggregate")
    keys3 = jnp.asarray(keys3, jnp.int32)
    gk = jnp.asarray(group_keys, jnp.int32)
    n_chunks, rows = keys3.shape[0], keys3.shape[1]
    g = gk.shape[0]
    if n_chunks == 0 or rows == 0 or g == 0:
        return jnp.zeros((n_chunks, g, 3), jnp.int32)
    if not r.use_pallas:
        return ref.group_sum_count_batched_ref(keys3, vals3, sel3, gk)
    br, gb = _params(rows, g, r.tuned, block_rows, group_block)
    return K.group_sum_count_batched_planes(
        keys3, jnp.asarray(vals3, jnp.int32), jnp.asarray(sel3, jnp.int32),
        gk, block_rows=br, group_block=gb, interpret=r.interpret)


def group_sum_count(keys, vals, sel, group_keys, *, mode=None,
                    block_rows: int | None = None,
                    group_block: int | None = None):
    """One-chunk dense grouped aggregate over 1-D int32 code arrays ->
    int32[G, 3]; thin wrapper over the batched launch."""
    out = group_sum_count_batched(
        lift_chunks([keys]), lift_chunks([vals]), lift_chunks([sel]),
        group_keys, mode=mode,
        block_rows=block_rows, group_block=group_block)
    return out[0]


def rle_group_accumulate_batched(run_planes, group_keys, *, pred=None,
                                 mode=None, block_rows: int | None = None,
                                 group_block: int | None = None):
    """Fused pre-grouped accumulation over RLE runs, all chunks in ONE
    launch: run (v, n) adds n to group v's count and n*v to its sum —
    register accumulation only, no scatter.

    run_planes: sequence of (values, lengths) run-plane pairs, one per
    chunk (ragged run counts padded with zero-length runs, which are
    inert). `pred` is an optional canonical (prim, const, invert) triple
    evaluated on the run value in-kernel. Returns int32[n_chunks, G, 3].
    """
    r = dispatch.resolve(mode)
    dispatch.count_launch("group_aggregate_rle")
    gk = jnp.asarray(group_keys, jnp.int32)
    n_chunks, g = len(run_planes), gk.shape[0]
    if n_chunks == 0 or g == 0:
        return jnp.zeros((n_chunks, g, 3), jnp.int32)
    if pred is not None:
        pred = (str(pred[0]), int(pred[1]), bool(pred[2]))
    v3 = lift_chunks([v for v, _ in run_planes])
    l3 = lift_chunks([l for _, l in run_planes])
    if not r.use_pallas:
        return ref.rle_group_accumulate_batched_ref(v3, l3, gk, pred)
    br, gb = _params(v3.shape[1], g, r.tuned, block_rows, group_block)
    return K.rle_group_accumulate_batched_planes(
        v3, l3, gk, pred=pred, block_rows=br, group_block=gb,
        interpret=r.interpret)


def rle_group_accumulate(values, lengths, group_keys, *, pred=None,
                         mode=None, block_rows: int | None = None,
                         group_block: int | None = None):
    """One chunk of RLE runs -> int32[G, 3]."""
    out = rle_group_accumulate_batched([(values, lengths)], group_keys,
                                       pred=pred, mode=mode,
                                       block_rows=block_rows,
                                       group_block=group_block)
    return out[0]


def finalize_grouped(group_keys, plane, base: int = 0):
    """One (G, 3) accumulator plane -> exact host int64 (keys, sums,
    counts) with the FOR base fix-up: the kernel summed deltas, so the
    logical sum is delta_sum + base * count, exact in Python/host ints."""
    p = np.asarray(plane, np.int64)
    keys = np.asarray(group_keys, np.int64)
    counts = p[:, 2]
    sums = (p[:, 1] << 16) + p[:, 0] + int(base) * counts
    return keys, sums, counts


def _batched_ref(keys3, vals3, sel3, group_keys, *,
                 block_rows=None, group_block=None):
    return ref.group_sum_count_batched_ref(keys3, vals3, sel3, group_keys)


def _example(rng):
    n_chunks, rows = 3, 1000            # non-pow2: exercises lane padding
    keys = rng.integers(0, 7, (n_chunks, rows))
    vals = rng.integers(0, 128, (n_chunks, rows))
    sel = rng.integers(0, 2, (n_chunks, rows))
    gk = jnp.arange(7, dtype=jnp.int32)
    return ((lift_chunks(list(keys)), lift_chunks(list(vals)),
             lift_chunks(list(sel)), gk),
            {})


dispatch.register(
    "group_aggregate", fn=group_sum_count_batched, ref=_batched_ref,
    tunables={"block_rows": (64, 128, 256), "group_block": (4, 8, 16)},
    example=_example)
