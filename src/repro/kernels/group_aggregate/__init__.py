from repro.kernels.group_aggregate.ops import (DENSE_MAX_GROUPS,
                                               finalize_grouped,
                                               group_sum_count,
                                               group_sum_count_batched,
                                               rle_group_accumulate,
                                               rle_group_accumulate_batched)

__all__ = [
    "DENSE_MAX_GROUPS",
    "finalize_grouped",
    "group_sum_count",
    "group_sum_count_batched",
    "rle_group_accumulate",
    "rle_group_accumulate_batched",
]
