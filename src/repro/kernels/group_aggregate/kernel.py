"""Grouped aggregation Pallas TPU kernels: dense accumulator planes.

Two kernels, both writing int32 `(n_groups, 3)` accumulator planes of
[sum_lo, sum_hi, count] rows (the grouped analogue of aggregate/kernel.py's
5-scalar row):

- `_dense_*`: one pass over (rows, LANES) int32 key/value/select code
  planes. Per grid step a (group_block, block_rows, LANES) compare plane
  matches a block of group keys against the tile in VREGs and reduces
  into VMEM scratch — a dense accumulator plane instead of a hash table,
  viable because the store's FOR frames bound the key range.
- `_rle_*`: the fused pre-grouped path over RLE run planes: a run
  (value v, length n) contributes n to group v's count and n*v to its
  sum as ONE register accumulation — no scatter, no per-row traffic. An
  optional canonical predicate on the run value is evaluated in-kernel.

Exactness mirrors the aggregate family: ops.py bounds block_rows so each
tile partial stays < 2^31, every tile partial is split 16/16 into two
running planes, and the final grid step writes the normalized pair. Group
key blocks are padded with -1 (codes are unsigned, so the sentinel never
matches); padded rows/runs carry zero select/length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.scan_filter.kernel import LANES

DEFAULT_BLOCK_ROWS = 256
DEFAULT_GROUP_BLOCK = 8


def _accumulate(acc, ids, match, vals, weights=None):
    """Reduce one (block_rows, LANES) tile into the (group_block, 3)
    scratch: per group-id row, a masked (weighted) sum split 16/16 plus a
    (weighted) count."""
    m = match & (ids[:, None, None] >= 0)
    w = weights if weights is not None else jnp.int32(1)
    s = jnp.sum(jnp.where(m, vals[None] * w, 0), axis=(1, 2))
    c = jnp.sum(jnp.where(m, w, 0), axis=(1, 2))
    acc[:, 0] += s & 0xFFFF
    acc[:, 1] += s >> 16
    acc[:, 2] += c


def _writeback(o_ref, acc):
    lo = acc[:, 0]
    o_ref[0, :, 0] = lo & 0xFFFF          # normalized planes
    o_ref[0, :, 1] = acc[:, 1] + (lo >> 16)
    o_ref[0, :, 2] = acc[:, 2]


def _dense_batched_kernel(gk_ref, k_ref, v_ref, s_ref, o_ref, acc):
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _():
        acc[...] = jnp.zeros(acc.shape, jnp.int32)

    ids = gk_ref[0]                       # (group_block,)
    k = k_ref[0]
    sel = s_ref[0] > 0
    match = (k[None] == ids[:, None, None]) & sel[None]
    _accumulate(acc, ids, match, v_ref[0])

    @pl.when(i == ni - 1)
    def _():
        _writeback(o_ref, acc)


def _rle_batched_kernel(gk_ref, v_ref, l_ref, o_ref, acc, *, pred):
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _():
        acc[...] = jnp.zeros(acc.shape, jnp.int32)

    ids = gk_ref[0]
    v = v_ref[0]
    l = l_ref[0]
    live = l > 0
    if pred is not None:                  # static: baked into the trace
        prim, const, invert = pred
        cmp = (v >= const) if prim == "ge" else (v == const)
        live = live & (cmp ^ invert)
    match = (v[None] == ids[:, None, None]) & live[None]
    _accumulate(acc, ids, match, v, weights=l[None])

    @pl.when(i == ni - 1)
    def _():
        _writeback(o_ref, acc)


def _pad_planes(planes, block_rows):
    rows = planes[0].shape[-2]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        planes = [jnp.pad(p, ((0, 0), (0, pad), (0, 0))) for p in planes]
        rows += pad
    return planes, rows, block_rows


def _pad_groups(group_keys, group_block):
    g = group_keys.shape[0]
    group_block = min(group_block, max(g, 1))
    pad = (-g) % group_block
    gk = jnp.pad(jnp.asarray(group_keys, jnp.int32), (0, pad),
                 constant_values=-1)
    return gk.reshape(-1, group_block), g


def _launch(kernel, gk2, planes, rows, block_rows, interpret):
    n_chunks = planes[0].shape[0]
    gb = gk2.shape[1]
    spec = pl.BlockSpec((1, block_rows, LANES), lambda c, g, i: (c, i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(n_chunks, gk2.shape[0], rows // block_rows),
        in_specs=[pl.BlockSpec((1, gb), lambda c, g, i: (g, 0))]
        + [spec] * len(planes),
        out_specs=pl.BlockSpec((1, gb, 3), lambda c, g, i: (c, g, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, gk2.size, 3), jnp.int32),
        scratch_shapes=[pltpu.VMEM((gb, 3), jnp.int32)],
        interpret=interpret,
    )(gk2, *planes)
    return out


@functools.partial(jax.jit, static_argnames=("block_rows", "group_block",
                                             "interpret"))
def group_sum_count_batched_planes(keys3, vals3, sel3, group_keys, *,
                                   block_rows: int = DEFAULT_BLOCK_ROWS,
                                   group_block: int = DEFAULT_GROUP_BLOCK,
                                   interpret: bool = True):
    """(n_chunks, rows, LANES) int32 key/value/select planes + (G,) group
    keys -> int32[n_chunks, G, 3] accumulator planes, all chunks and all
    group blocks in ONE kernel launch."""
    planes = [jnp.asarray(p, jnp.int32) for p in (keys3, vals3, sel3)]
    planes, rows, block_rows = _pad_planes(planes, block_rows)
    gk2, g = _pad_groups(group_keys, group_block)
    out = _launch(_dense_batched_kernel, gk2, planes, rows, block_rows,
                  interpret)
    return out[:, :g]


@functools.partial(jax.jit, static_argnames=("pred", "block_rows",
                                             "group_block", "interpret"))
def rle_group_accumulate_batched_planes(vals3, lens3, group_keys, *,
                                        pred=None,
                                        block_rows: int = DEFAULT_BLOCK_ROWS,
                                        group_block: int = DEFAULT_GROUP_BLOCK,
                                        interpret: bool = True):
    """(n_chunks, runs, LANES) RLE value/length planes + (G,) group keys
    -> int32[n_chunks, G, 3]: the fused pre-grouped accumulation, one
    register update per (run, group block) with zero scatter traffic."""
    planes = [jnp.asarray(p, jnp.int32) for p in (vals3, lens3)]
    planes, runs, block_rows = _pad_planes(planes, block_rows)
    gk2, g = _pad_groups(group_keys, group_block)
    kernel = functools.partial(_rle_batched_kernel, pred=pred)
    out = _launch(kernel, gk2, planes, runs, block_rows, interpret)
    return out[:, :g]
