"""Public decode-attention API (inference-only; no vjp needed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import kernel as K
from repro.kernels.decode_attention import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     bk: int = K.DEFAULT_BK, use_kernel: bool = True):
    """q: (B, KVH, G, D); k/v: (B, S, KVH, D); q_pos (B,); kv_pos (B, S)."""
    if not use_kernel:
        return ref.decode_ref(q, k, v, q_pos, kv_pos, window=window)
    s = k.shape[1]
    bk_eff = min(bk, s)
    while s % bk_eff:
        bk_eff -= 1
    return K.decode_attention_fwd(q, k, v, q_pos, kv_pos, window=window,
                                  bk=bk_eff, interpret=_interpret())
