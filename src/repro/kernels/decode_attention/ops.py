"""Public decode-attention API (inference-only; no vjp needed), dispatched
through repro.kernels.dispatch. k/v arrive in the kernel-native
(B, KVH, S, D) cache layout — zero copies on the decode hot path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.decode_attention import kernel as K
from repro.kernels.decode_attention import ref


def decode_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                     bk: int | None = None, use_kernel: bool = True,
                     mode=None):
    """q: (B, KVH, G, D); k/v: (B, KVH, S, D); q_pos (B,); kv_pos (B, S)."""
    r = dispatch.resolve(mode, use_kernel=use_kernel)
    if not r.use_pallas:
        return ref.decode_ref(q, k, v, q_pos, kv_pos, window=window)
    s = k.shape[2]
    if bk is None:
        bk = K.DEFAULT_BK
        if r.tuned:
            bk = tune.best_params("decode_attention", tune.shape_key(s=s),
                                  {"bk": bk})["bk"]
    return K.decode_attention_fwd(q, k, v, q_pos, kv_pos, window=window,
                                  bk=tune.fit(s, bk), interpret=r.interpret)


def _example(rng):
    key = jax.random.PRNGKey(int(rng.integers(1 << 30)))
    kq, kk, kv_ = jax.random.split(key, 3)
    b, kvh, g, s, d = 2, 2, 2, 512, 64
    q = jax.random.normal(kq, (b, kvh, g, d), jnp.float32)
    k = jax.random.normal(kk, (b, kvh, s, d), jnp.float32)
    v = jax.random.normal(kv_, (b, kvh, s, d), jnp.float32)
    fill = int(0.75 * s)
    kv_pos = jnp.broadcast_to(
        jnp.where(jnp.arange(s) < fill, jnp.arange(s), 1 << 30)[None],
        (b, s))
    q_pos = jnp.full((b,), fill, jnp.int32)
    return (q, k, v, q_pos, kv_pos), {}


dispatch.register(
    "decode_attention", fn=decode_attention, ref=ref.decode_ref,
    tunables={"bk": (128, 256, 512, 1024, 2048)},
    example=_example)
