"""Pure-jnp oracle for split-K decode attention.

One query token per row against a ring-buffer KV cache in the kernel-native
(B, KVH, S, D) layout with a stored-pos plane (repro.models.attention cache
layout): slots whose pos violates causality (or the sliding window, or were
never written = +INF pos) are masked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, q_pos, kv_pos, *, window: int = 0):
    """q: (B, KVH, G, D); k/v: (B, KVH, S, D); q_pos: (B,);
    kv_pos: (B, S). Returns (B, KVH, G, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32) * d ** -0.5,
                   k.astype(jnp.float32))
    dp = q_pos[:, None] - kv_pos                     # (B, S)
    ok = dp >= 0
    if window:
        ok &= dp < window
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
