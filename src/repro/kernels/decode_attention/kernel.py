"""Split-K decode attention (FlashDecoding-style), Pallas TPU kernel.

This is the paper's scan operator reincarnated: a single query token
streams the whole KV cache at ~2 FLOP/byte — pure HBM bandwidth. The grid
splits the cache into (B, KVH, S/bk) blocks; each step reduces its block
into per-block partials (m, l, acc) in VMEM scratch carried across the
sequential S sweep, writing the normalized output on the last block.

The KV block is the ring-buffer layout of repro.models.attention: a stored
pos plane drives causal/window/empty-slot masking inside the kernel, so the
host never materializes a mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BK = 512


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   g: int, d: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                              # (G, D)
    k = k_ref[0]                                 # (bk, D)
    v = v_ref[0]
    kv_pos = pos_ref[0]                          # (1, bk) int32
    q_pos = qpos_ref[0]                          # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    dp = q_pos - kv_pos                          # (1, bk)
    ok = dp >= 0
    if window:
        ok &= dp < window
    s = jnp.where(ok, s, NEG_INF)                # (G, bk) via broadcast

    m_prev = m_scr[...]                          # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention_fwd(q, k, v, q_pos, kv_pos, *, window: int = 0,
                         bk: int = DEFAULT_BK, interpret: bool = True):
    """q: (B, KVH, G, D); k/v: (B, KVH, S, D) kernel-native ring cache;
    q_pos: (B,); kv_pos: (B, S) stored positions. Returns (B, KVH, G, D).

    The cache layout matches repro.models.attention storage exactly, so a
    decode step feeds the cache straight in: the only reshape below merges
    the two leading axes (a metadata-only view), never a transpose — the
    whole-cache `swapaxes` copy this kernel used to make every step is
    gone.
    """
    b, kvh, s, d = k.shape
    g = q.shape[2]
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)

    pos_b = jnp.broadcast_to(kv_pos[:, None, :], (b, 1, s))

    kernel = functools.partial(_decode_kernel, scale=d ** -0.5,
                               window=window, g=g, d=d)
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, s // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ki: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bi, hi, ki: (bi * pl.num_programs(1) + hi, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bi, hi, ki: (bi * pl.num_programs(1) + hi, ki, 0)),
            pl.BlockSpec((1, 1, bk), lambda bi, hi, ki: (bi, 0, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, q, k.reshape(b * kvh, s, d), v.reshape(b * kvh, s, d), pos_b)
    return out
