"""Public fused scan+aggregate API, dispatched through
repro.kernels.dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.aggregate import kernel as K
from repro.kernels.aggregate import ref
from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES


def aggregate(words, mask_words, code_bits: int, use_kernel: bool = True,
              block_rows: int | None = None, mode=None):
    """words/mask_words: (n_words,) uint32 -> dict(sum, count, min, max).

    Codes in padded tail words have mask delimiter bits 0 and are ignored.
    """
    r = dispatch.resolve(mode, use_kernel=use_kernel)
    if not r.use_pallas:
        return ref.aggregate_ref(words, mask_words, code_bits)
    w = jnp.asarray(words, jnp.uint32)
    m = jnp.asarray(mask_words, jnp.uint32)
    pad = (-w.shape[0]) % LANES
    w = jnp.pad(w, (0, pad)).reshape(-1, LANES)
    m = jnp.pad(m, (0, pad)).reshape(-1, LANES)
    rows = w.shape[0]
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("aggregate",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    out = K.aggregate_packed(w, m, code_bits=code_bits, block_rows=br,
                             interpret=r.interpret)
    return {"sum": out[0, 0], "count": out[0, 1],
            "min": out[0, 2], "max": out[0, 3]}


def _example(rng):
    from repro.kernels.scan_filter import ref as scan_ref
    codes = rng.integers(0, 128, 6000)
    packed = scan_ref.pack(codes, 8)
    mask = scan_ref.scan_ref(packed, 64, "lt", 8)
    return (jnp.asarray(packed), mask, 8), {}


dispatch.register(
    "aggregate", fn=aggregate, ref=ref.aggregate_ref,
    tunables={"block_rows": (64, 256, 1024, 4096, 16384)},
    example=_example)
