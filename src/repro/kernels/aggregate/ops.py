"""Public fused scan+aggregate API, dispatched through
repro.kernels.dispatch.

Aggregates carry the sum as two normalized 16-bit planes (sum_hi, sum_lo)
— exact in int32 where a single int32 sum wraps after ~65k selected rows
of a 16-bit column, and safe to psum across shards. `finalize` reassembles
the exact Python int host-side; `sum_bound_block_rows` bounds the tile so
per-tile partials stay exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.aggregate import kernel as K
from repro.kernels.aggregate import ref
from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES


def sum_bound_block_rows(code_bits: int) -> int:
    """Largest block_rows whose per-tile sum partial is int32-exact:
    block_rows * LANES words * codes/word * vmax < 2^31."""
    cpw = 32 // code_bits
    vmax = (1 << (code_bits - 1)) - 1
    return max(1, (2**31 - 1) // (LANES * cpw * vmax))


def finalize(d: dict) -> dict:
    """Device aggregate dict -> exact host ints, planes reassembled
    (the only step that may exceed int32, hence Python ints)."""
    return {"sum": (int(d["sum_hi"]) << 16) + int(d["sum_lo"]),
            "count": int(d["count"]),
            "min": int(d["min"]),
            "max": int(d["max"])}


def aggregate(words, mask_words, code_bits: int,
              block_rows: int | None = None, mode=None):
    """words/mask_words: (n_words,) uint32 ->
    dict(sum_lo, sum_hi, count, min, max) of int32 scalars.

    Codes in padded tail words have mask delimiter bits 0 and are ignored.
    """
    r = dispatch.resolve(mode)
    dispatch.count_launch("aggregate")
    if not r.use_pallas:
        return ref.aggregate_ref(words, mask_words, code_bits)
    if words.size == 0:              # zero-row grid is undefined
        return ref.identity(code_bits)
    w = jnp.asarray(words, jnp.uint32)
    m = jnp.asarray(mask_words, jnp.uint32)
    pad = (-w.shape[0]) % LANES
    w = jnp.pad(w, (0, pad)).reshape(-1, LANES)
    m = jnp.pad(m, (0, pad)).reshape(-1, LANES)
    rows = w.shape[0]
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("aggregate",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    br = min(br, sum_bound_block_rows(code_bits))
    out = K.aggregate_packed(w, m, code_bits=code_bits, block_rows=br,
                             interpret=r.interpret)
    return {"sum_lo": out[0, 0], "sum_hi": out[0, 1], "count": out[0, 2],
            "min": out[0, 3], "max": out[0, 4]}


def to3d_words(words3, lanes: int = LANES):
    """(n_chunks, n_words) packed planes -> (n_chunks, rows, lanes) kernel
    tiles (lane-padded with zero words, which no mask ever selects)."""
    w = jnp.asarray(words3, jnp.uint32)
    n_chunks, n_words = w.shape
    pad = (-n_words) % lanes
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    return w.reshape(n_chunks, -1, lanes)


def aggregate_batched(words3, mask3, code_bits: int,
                      block_rows: int | None = None, mode=None):
    """All chunks of one column in ONE launch: (n_chunks, n_words) packed
    words + packed masks -> int32[n_chunks, 5], each row bit-identical to
    the per-chunk `aggregate` at that chunk's words/mask."""
    r = dispatch.resolve(mode)
    dispatch.count_launch("aggregate")
    w = jnp.asarray(words3, jnp.uint32)
    if w.shape[0] == 0 or w.shape[1] == 0:   # empty-selection identities
        vmax = (1 << (code_bits - 1)) - 1
        return jnp.tile(jnp.asarray([[0, 0, 0, vmax, 0]], jnp.int32),
                        (w.shape[0], 1))
    if not r.use_pallas:
        return _batched_ref_jit(jnp.asarray(words3, jnp.uint32),
                                jnp.asarray(mask3, jnp.uint32), code_bits)
    w3 = to3d_words(words3)
    m3 = to3d_words(mask3)
    rows = w3.shape[1]
    br = block_rows
    if br is None:
        br = min(DEFAULT_BLOCK_ROWS, rows)
        if r.tuned:
            br = tune.best_params("aggregate",
                                  tune.shape_key(rows=rows, bits=code_bits),
                                  {"block_rows": br})["block_rows"]
            br = max(1, min(int(br), rows))
    br = min(br, sum_bound_block_rows(code_bits))
    return K.aggregate_batched_packed(w3, m3, code_bits=code_bits,
                                      block_rows=br, interpret=r.interpret)


# the ref oracle compiled once per plane shape: word planes and masks are
# traced, so a warm trace replay of any query mix never retraces
_batched_ref_jit = jax.jit(ref.aggregate_batched_ref, static_argnums=2)


def _example(rng):
    from repro.kernels.scan_filter import ref as scan_ref
    codes = rng.integers(0, 128, 6000)
    packed = scan_ref.pack(codes, 8)
    mask = scan_ref.scan_ref(packed, 64, "lt", 8)
    return (jnp.asarray(packed), mask, 8), {}


dispatch.register(
    "aggregate", fn=aggregate, ref=ref.aggregate_ref,
    tunables={"block_rows": (64, 256, 1024, 4096, 16384)},
    example=_example)
