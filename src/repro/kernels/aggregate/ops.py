"""Public fused scan+aggregate API with jnp fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aggregate import kernel as K
from repro.kernels.aggregate import ref
from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def aggregate(words, mask_words, code_bits: int, use_kernel: bool = True,
              block_rows: int | None = None):
    """words/mask_words: (n_words,) uint32 -> dict(sum, count, min, max).

    Codes in padded tail words have mask delimiter bits 0 and are ignored.
    """
    if not use_kernel:
        return ref.aggregate_ref(words, mask_words, code_bits)
    w = jnp.asarray(words, jnp.uint32)
    m = jnp.asarray(mask_words, jnp.uint32)
    pad = (-w.shape[0]) % LANES
    w = jnp.pad(w, (0, pad)).reshape(-1, LANES)
    m = jnp.pad(m, (0, pad)).reshape(-1, LANES)
    rows = w.shape[0]
    br = block_rows or min(DEFAULT_BLOCK_ROWS, rows)
    while rows % br:
        br -= 1
    out = K.aggregate_packed(w, m, code_bits=code_bits, block_rows=br,
                             interpret=_interpret())
    return {"sum": out[0, 0], "count": out[0, 1],
            "min": out[0, 2], "max": out[0, 3]}
