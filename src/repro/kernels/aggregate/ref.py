"""Pure-jnp oracle for the fused masked aggregate over packed columns."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.scan_filter.ref import unpack, unpack_mask


def aggregate_ref(words, mask_words, code_bits: int):
    """Returns dict(sum, count, min, max) over codes whose delimiter bit is
    set in mask_words. Empty selection: sum=0, count=0, min=vmax, max=0."""
    vals = unpack(words, code_bits).astype(jnp.int32)
    sel = unpack_mask(mask_words, code_bits)
    vmax = jnp.int32((1 << (code_bits - 1)) - 1)
    return {
        "sum": jnp.sum(jnp.where(sel, vals, 0)),
        "count": jnp.sum(sel.astype(jnp.int32)),
        "min": jnp.min(jnp.where(sel, vals, vmax)),
        "max": jnp.max(jnp.where(sel, vals, 0)),
    }
