"""Pure-jnp oracle for the fused masked aggregate over packed columns.

The sum is returned as two normalized 16-bit planes (sum_hi << 16 | sum_lo)
instead of one int32: a 16-bit column overflows int32 after only ~65k
selected rows, and neither TPUs nor default jax carry int64. The split is
int32-exact for any column up to 2^27 codes per device, survives a psum
across shards unchanged, and `ops.finalize` reassembles the exact Python
int host-side.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.scan_filter.ref import unpack, unpack_mask

_CHUNK = 4096        # partials stay < 2^27: exact in int32 for any width


def split_sum(vals):
    """Exact sum of non-negative int32 codes (< 2^16 each) as normalized
    16-bit planes (lo, hi): sum == hi * 65536 + lo, both int32-exact."""
    n = vals.shape[0]
    v = jnp.pad(vals, (0, (-n) % _CHUNK)).reshape(-1, _CHUNK)
    part = jnp.sum(v, axis=1)                   # < CHUNK * 2^16 = 2^27
    lo = jnp.sum(part & 0xFFFF)                 # < n/CHUNK * 2^16
    hi = jnp.sum(part >> 16)
    return lo & 0xFFFF, hi + (lo >> 16)


def identity(code_bits: int) -> dict:
    """The empty-selection aggregate: what every path returns for zero
    selected (or zero existing) rows."""
    vmax = (1 << (code_bits - 1)) - 1
    return {"sum_lo": jnp.int32(0), "sum_hi": jnp.int32(0),
            "count": jnp.int32(0), "min": jnp.int32(vmax),
            "max": jnp.int32(0)}


def aggregate_batched_ref(words3, mask3, code_bits: int):
    """Vectorized oracle for the batched masked aggregate:
    (n_chunks, n_words) packed codes + packed masks -> int32[n_chunks, 5]
    of [sum_lo, sum_hi, count, min, max] rows in a single jnp dispatch.

    Exact without split_sum's staging: each chunk holds at most
    MAX_CHUNK_ROWS (65536) codes < 2^15, so the per-chunk int32 sum stays
    below 2^31; the planes are normalized (lo < 2^16), which makes them
    bit-identical to every other aggregate path's output."""
    w = jnp.asarray(words3, jnp.uint32)
    m = jnp.asarray(mask3, jnp.uint32)
    c = 32 // code_bits
    vshifts = jnp.arange(c, dtype=jnp.uint32) * code_bits
    mshifts = vshifts + code_bits - 1
    vals = ((w[:, :, None] >> vshifts) & jnp.uint32(
        (1 << code_bits) - 1)).astype(jnp.int32)
    sel = ((m[:, :, None] >> mshifts) & jnp.uint32(1)).astype(bool)
    vmax = jnp.int32((1 << (code_bits - 1)) - 1)
    ax = (1, 2)
    s = jnp.sum(jnp.where(sel, vals, 0), axis=ax)
    return jnp.stack([
        s & 0xFFFF,
        s >> 16,
        jnp.sum(sel.astype(jnp.int32), axis=ax),
        jnp.min(jnp.where(sel, vals, vmax), axis=ax),
        jnp.max(jnp.where(sel, vals, 0), axis=ax),
    ], axis=1)


def aggregate_ref(words, mask_words, code_bits: int):
    """Returns dict(sum_lo, sum_hi, count, min, max) over codes whose
    delimiter bit is set in mask_words. Empty selection: sums/count/max 0,
    min=vmax."""
    if words.size == 0:              # empty column: jnp.min would reject it
        return identity(code_bits)
    vals = unpack(words, code_bits).astype(jnp.int32)
    sel = unpack_mask(mask_words, code_bits)
    vmax = jnp.int32((1 << (code_bits - 1)) - 1)
    lo, hi = split_sum(jnp.where(sel, vals, 0))
    return {
        "sum_lo": lo,
        "sum_hi": hi,
        "count": jnp.sum(sel.astype(jnp.int32)),
        "min": jnp.min(jnp.where(sel, vals, vmax)),
        "max": jnp.max(jnp.where(sel, vals, 0)),
    }
