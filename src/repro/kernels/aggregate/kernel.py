"""Fused masked aggregate (sum/count/min/max) Pallas TPU kernel.

One pass over the packed column + packed predicate mask (the scan kernel's
output): per grid step a (block_rows, 128) word tile is unpacked field-wise
in VREGs (static shift loop, no gather), masked, and reduced into VMEM
scratch accumulators; the final grid step writes the 5 scalars. With the
scan kernel this forms the paper's scan+aggregate query plan executing at
HBM bandwidth (arithmetic intensity ~= 2 int-ops/byte).

The sum leaves the kernel as two normalized 16-bit planes (sum_hi, sum_lo):
int32 wraps after ~65k selected rows of a 16-bit column and TPUs have no
int64, so each tile's (exact, block-size-bounded) int32 partial is split
16/16 into two accumulators, normalized once at the end. See
aggregate/ref.py for the bounds; ops.py clamps block_rows so a tile partial
can never wrap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.scan_filter.kernel import DEFAULT_BLOCK_ROWS, LANES


def _agg_kernel(x_ref, m_ref, o_ref, acc, *, code_bits: int, vmax: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _():
        acc[0, 0] = jnp.int32(0)      # sum_lo (16-bit plane, denormalized)
        acc[0, 1] = jnp.int32(0)      # sum_hi
        acc[0, 2] = jnp.int32(0)      # count
        acc[0, 3] = jnp.int32(vmax)   # min
        acc[0, 4] = jnp.int32(0)      # max

    x = x_ref[...]
    m = m_ref[...]
    c = 32 // code_bits
    value_mask = jnp.uint32((1 << (code_bits - 1)) - 1)

    s = jnp.int32(0)
    cnt = jnp.int32(0)
    mn = jnp.int32(vmax)
    mx = jnp.int32(0)
    for f in range(c):                       # static unroll over fields
        vals = ((x >> jnp.uint32(f * code_bits)) & value_mask).astype(
            jnp.int32)
        bit = ((m >> jnp.uint32(f * code_bits + code_bits - 1))
               & jnp.uint32(1)).astype(jnp.int32)
        sel = bit == 1
        s += jnp.sum(vals * bit)
        cnt += jnp.sum(bit)
        mn = jnp.minimum(mn, jnp.min(jnp.where(sel, vals, vmax)))
        mx = jnp.maximum(mx, jnp.max(jnp.where(sel, vals, 0)))

    # s is exact (ops.py bounds block_rows); split it so the running sum
    # never wraps: each plane grows < 2^16 per tile
    acc[0, 0] += s & 0xFFFF
    acc[0, 1] += s >> 16
    acc[0, 2] += cnt
    acc[0, 3] = jnp.minimum(acc[0, 3], mn)
    acc[0, 4] = jnp.maximum(acc[0, 4], mx)

    @pl.when(i == n - 1)
    def _():
        lo = acc[0, 0]
        o_ref[0, 0] = lo & 0xFFFF             # normalized planes
        o_ref[0, 1] = acc[0, 1] + (lo >> 16)
        o_ref[0, 2] = acc[0, 2]
        o_ref[0, 3] = acc[0, 3]
        o_ref[0, 4] = acc[0, 4]


def _agg_batched_kernel(x_ref, m_ref, o_ref, acc, *, code_bits: int,
                        vmax: int):
    """Batched variant: grid (n_chunks, inner), one (1, 5) partial row per
    chunk. Inner steps iterate fastest, so the accumulator resets at inner
    step 0 and writes back normalized at the last inner step — each row is
    bit-identical to the per-chunk `_agg_kernel`."""
    i = pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when(i == 0)
    def _():
        acc[0, 0] = jnp.int32(0)      # sum_lo (16-bit plane, denormalized)
        acc[0, 1] = jnp.int32(0)      # sum_hi
        acc[0, 2] = jnp.int32(0)      # count
        acc[0, 3] = jnp.int32(vmax)   # min
        acc[0, 4] = jnp.int32(0)      # max

    x = x_ref[0]
    m = m_ref[0]
    c = 32 // code_bits
    value_mask = jnp.uint32((1 << (code_bits - 1)) - 1)

    s = jnp.int32(0)
    cnt = jnp.int32(0)
    mn = jnp.int32(vmax)
    mx = jnp.int32(0)
    for f in range(c):                       # static unroll over fields
        vals = ((x >> jnp.uint32(f * code_bits)) & value_mask).astype(
            jnp.int32)
        bit = ((m >> jnp.uint32(f * code_bits + code_bits - 1))
               & jnp.uint32(1)).astype(jnp.int32)
        sel = bit == 1
        s += jnp.sum(vals * bit)
        cnt += jnp.sum(bit)
        mn = jnp.minimum(mn, jnp.min(jnp.where(sel, vals, vmax)))
        mx = jnp.maximum(mx, jnp.max(jnp.where(sel, vals, 0)))

    acc[0, 0] += s & 0xFFFF
    acc[0, 1] += s >> 16
    acc[0, 2] += cnt
    acc[0, 3] = jnp.minimum(acc[0, 3], mn)
    acc[0, 4] = jnp.maximum(acc[0, 4], mx)

    @pl.when(i == ni - 1)
    def _():
        lo = acc[0, 0]
        o_ref[0, 0] = lo & 0xFFFF             # normalized planes
        o_ref[0, 1] = acc[0, 1] + (lo >> 16)
        o_ref[0, 2] = acc[0, 2]
        o_ref[0, 3] = acc[0, 3]
        o_ref[0, 4] = acc[0, 4]


@functools.partial(jax.jit,
                   static_argnames=("code_bits", "block_rows", "interpret"))
def aggregate_batched_packed(words3d, mask3d, *, code_bits: int,
                             block_rows: int = DEFAULT_BLOCK_ROWS,
                             interpret: bool = True):
    """(n_chunks, rows, 128) packed words + packed masks ->
    int32[n_chunks, 5], one [sum_lo, sum_hi, count, min, max] row per
    chunk, all chunks in ONE kernel launch. Padded words carry zero mask
    delimiter bits and contribute nothing."""
    n_chunks, rows = words3d.shape[0], words3d.shape[1]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        words3d = jnp.pad(words3d, ((0, 0), (0, pad), (0, 0)))
        mask3d = jnp.pad(mask3d, ((0, 0), (0, pad), (0, 0)))
        rows += pad
    vmax = (1 << (code_bits - 1)) - 1
    kernel = functools.partial(_agg_batched_kernel, code_bits=code_bits,
                               vmax=vmax)
    spec = pl.BlockSpec((1, block_rows, LANES), lambda c, i: (c, i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n_chunks, rows // block_rows),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 5), lambda c, i: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 5), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
        interpret=interpret,
    )(words3d, mask3d)


@functools.partial(jax.jit,
                   static_argnames=("code_bits", "block_rows", "interpret"))
def aggregate_packed(words2d, mask2d, *, code_bits: int,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = True):
    """(rows, 128) packed words + packed mask -> int32[1, 5] =
    [sum_lo, sum_hi, count, min, max] (sum = sum_hi * 65536 + sum_lo).

    Rows are zero-padded to the block multiple; padded words carry zero
    mask delimiter bits so they contribute nothing to any accumulator."""
    rows = words2d.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        words2d = jnp.pad(words2d, ((0, pad), (0, 0)))
        mask2d = jnp.pad(mask2d, ((0, pad), (0, 0)))
        rows += pad
    vmax = (1 << (code_bits - 1)) - 1
    kernel = functools.partial(_agg_kernel, code_bits=code_bits, vmax=vmax)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 5), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 5), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
        interpret=interpret,
    )(words2d, mask2d)
