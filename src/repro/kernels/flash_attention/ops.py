"""Public flash attention API, dispatched through repro.kernels.dispatch.

- `flash5(q5, k, v, window)` — kernel-native layout, custom_vjp: the
  forward runs the Pallas kernel (autotuned bq/bk), the backward
  differentiates the jnp reference (correct gradients, kernel-speed
  forward).
- `flash_attention` (models layout) — adapter used by
  repro.models.attention when attn_impl == "flash": accepts the model's
  (B, S, KV, G, H) q and (B, T, KV, H) k/v with explicit positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tune
from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _blocks(sq: int, skv: int, tuned: bool) -> dict:
    params = {"bq": min(K.DEFAULT_BQ, sq), "bk": min(K.DEFAULT_BK, skv)}
    if tuned:
        params = tune.best_params("flash_attention",
                                  tune.shape_key(sq=sq, skv=skv), params)
    return {"bq": tune.fit(sq, params["bq"]), "bk": tune.fit(skv, params["bk"])}


def _forward(q, k, v, window, mode):
    r = dispatch.resolve(mode)
    if not r.use_pallas:
        return ref.attention_ref(q, k, v, window=window)
    return K.flash_attention_fwd(q, k, v, window=window,
                                 interpret=r.interpret,
                                 **_blocks(q.shape[3], k.shape[2], r.tuned))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash5(q, k, v, window: int = 0, mode=None):
    return _forward(q, k, v, window, mode)


def _fwd(q, k, v, window, mode):
    return flash5(q, k, v, window, mode), (q, k, v)


def _bwd(window, mode, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(
        q_, k_, v_, window=window), q, k, v)
    return vjp(g)


flash5.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, q_pos, kv_pos, *, window: int = 0, mode=None):
    """Model-layout adapter: q (B,Sq,KV,G,H), k/v (B,Skv,KV,H)."""
    q5 = jnp.moveaxis(q, 1, 3)          # (B,KV,G,Sq,H)
    k4 = jnp.moveaxis(k, 1, 2)          # (B,KV,Skv,H)
    v4 = jnp.moveaxis(v, 1, 2)
    r = dispatch.resolve(mode)
    if not r.use_pallas:
        o5 = ref.attention_ref(q5, k4, v4, window=window)
    else:
        o5 = flash5(q5, k4, v4, window, mode)
    return jnp.moveaxis(o5, 3, 1)       # back to (B,Sq,KV,G,H)


def _example(rng):
    key = jax.random.PRNGKey(int(rng.integers(1 << 30)))
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 2, 2, 256, 64), jnp.float32)
    k = jax.random.normal(kk, (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(kv_, (1, 2, 256, 64), jnp.float32)
    return (q, k, v), {}


def _flash5_mode(q, k, v, *, mode=None):
    return _forward(q, k, v, 0, mode)


dispatch.register(
    "flash_attention", fn=_flash5_mode, ref=ref.attention_ref,
    tunables={"bq": (64, 128, 256), "bk": (64, 128, 256, 512)},
    example=_example)
