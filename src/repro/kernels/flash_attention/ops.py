"""Public flash attention API.

- `flash_attention(q5, k, v, ...)` — kernel-native layout, custom_vjp: the
  forward runs the Pallas kernel, the backward differentiates the jnp
  reference (correct gradients, kernel-speed forward).
- `flash_attention` (models layout) — adapter used by
  repro.models.attention when attn_impl == "flash": accepts the model's
  (B, S, KV, G, H) q and (B, T, KV, H) k/v with explicit positions; falls
  back to the blockwise path when positions are not plain aranges.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash5(q, k, v, window: int = 0):
    return K.flash_attention_fwd(q, k, v, window=window,
                                 interpret=_interpret())


def _fwd(q, k, v, window):
    return flash5(q, k, v, window), (q, k, v)


def _bwd(window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(
        q_, k_, v_, window=window), q, k, v)
    return vjp(g)


flash5.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, q_pos, kv_pos, *, window: int = 0):
    """Model-layout adapter: q (B,Sq,KV,G,H), k/v (B,Skv,KV,H)."""
    b, sq, kvh, g, h = q.shape
    skv = k.shape[1]
    q5 = jnp.moveaxis(q, 1, 3)          # (B,KV,G,Sq,H)
    k4 = jnp.moveaxis(k, 1, 2)          # (B,KV,Skv,H)
    v4 = jnp.moveaxis(v, 1, 2)
    o5 = flash5(q5, k4, v4, window)
    return jnp.moveaxis(o5, 3, 1)       # back to (B,Sq,KV,G,H)
