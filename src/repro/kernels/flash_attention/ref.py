"""Pure-jnp oracle for the flash attention kernel.

Layout (kernel-native): q (B, KVH, G, Sq, D), k/v (B, KVH, Skv, D).
Positions are arange (prefill semantics); mask is causal with optional
sliding window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, window: int = 0):
    b, kvh, g, sq, d = q.shape
    skv = k.shape[2]
    scale = d ** -0.5
    s = jnp.einsum("bkgqd,bktd->bkgqt", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)   # suffix alignment
    kv_pos = jnp.arange(skv)[None, :]
    dpos = q_pos - kv_pos
    ok = dpos >= 0
    if window:
        ok &= dpos < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,bktd->bkgqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
