"""Blockwise online-softmax (flash) attention, Pallas TPU kernel.

Grid (B*KVH, G, Sq/bq, Skv/bk) with the KV block innermost: m/l/acc live in
VMEM scratch across the KV sweep, so HBM traffic is one Q read, one O write
and (Skv/bk) K/V block streams — never the (Sq, Skv) score matrix. Block
shapes are MXU-aligned (128 x head_dim). Causal + sliding-window masks are
applied via block-start iotas; fully-masked blocks short-circuit on the
m-update (no special control flow needed for correctness).

Forward-only: training uses the differentiable blockwise JAX path
(repro.models.attention._blockwise); ops.py wires a custom_vjp whose
backward is the jnp reference, so the kernel is safe under jax.grad.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, window: int, sq: int, skv: int,
                  bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal/window block skip: blocks fully outside the mask contribute
    # nothing — predicate out their MXU work entirely (the ~2x causal
    # saving the naive path can't express; EXPERIMENTS.md §Perf).
    q_lo = qi * bq + (skv - sq)            # smallest q position in block
    q_hi = q_lo + bq - 1
    kv_lo = ki * bk
    kv_hi = kv_lo + bk - 1
    reachable = kv_lo <= q_hi              # some kv <= some q (causal)
    if window:
        reachable &= kv_hi > q_lo - window  # not entirely window-evicted

    @pl.when(reachable)
    def _():
        q = q_ref[0, 0]                                  # (bq, d)
        k = k_ref[0]                                     # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kv_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        d = q_pos - kv_pos
        ok = d >= 0
        if window:
            ok &= d < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * alpha
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, window: int = 0,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = True):
    """q: (B, KVH, G, Sq, D); k/v: (B, KVH, Skv, D) -> (B, KVH, G, Sq, D).

    Positions are arange with suffix alignment (q rows are the last Sq of
    the Skv context) — prefill semantics.
    """
    b, kvh, g, sq, d = q.shape
    skv = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)

    kernel = functools.partial(_flash_kernel, scale=d ** -0.5, window=window,
                               sq=sq, skv=skv, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(b * kvh, g, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda h, gi, qi, ki: (h, gi, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda h, gi, qi, ki: (h, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda h, gi, qi, ki: (h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda h, gi, qi, ki: (h, gi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * kvh, g, sq, d),
      k.reshape(b * kvh, skv, d),
      v.reshape(b * kvh, skv, d)).reshape(b, kvh, g, sq, d)
