"""The paper's three provisioning regimes (§5.1-§5.3).

Each returns a `ClusterDesign`; the claims in the paper's figures fall out of
the designs' derived properties (see tests/test_paper_claims.py).
"""
from __future__ import annotations

import math

from repro.core.model import (ClusterDesign, Workload, capacity_chips,
                              cores_for_throughput)
from repro.core.systems import SystemSpec


def provision_capacity(system: SystemSpec, workload: Workload,
                       capacity: float | None = None) -> ClusterDesign:
    """§5.3: size the cluster to hold `capacity` (default: the database).

    Chips run every core their memory bandwidth can feed (Eq. 4/5 at full
    tilt) — the query is raced to completion.
    """
    wl = workload if capacity is None else Workload(capacity,
                                                    workload.bytes_accessed / capacity)
    chips = capacity_chips(system, wl)
    return ClusterDesign(system, wl, chips, system.saturating_cores)


def provision_performance(system: SystemSpec, workload: Workload,
                          sla: float) -> ClusterDesign:
    """§5.1: size the cluster to answer a query within `sla` seconds.

    The cluster must (a) hold the database and (b) supply
    bytes_accessed / sla of aggregate throughput; whichever needs more chips
    wins. Memory over-provisioning (paper Fig. 3, right) is the byproduct of
    (b) > (a) for low-bandwidth-ratio systems.
    """
    required_bw = workload.bytes_accessed / sla
    chips_bw = math.ceil(required_bw / system.chip_peak_perf)
    chips = max(chips_bw, capacity_chips(system, workload))
    cores = cores_for_throughput(system, required_bw, chips)
    return ClusterDesign(system, workload, chips, cores)


def provision_power(system: SystemSpec, workload: Workload,
                    budget: float) -> ClusterDesign:
    """§5.2: deploy as much cluster as `budget` watts allows.

    Blades are first assumed fully populated (all cores); if even the
    capacity-required blades' memory+overhead cannot fit the budget with one
    core per chip, cores per chip are cut (the paper's 50 kW die-stacked
    cluster runs 1 core/chip).
    """
    full_cores = system.max_chip_cores
    chip_full_power = (system.modules_per_chip * system.module_power
                       + full_cores * system.core_power)
    blade_full_power = (system.blade_chips * chip_full_power
                        + system.blade_overhead)
    cap_chips = capacity_chips(system, workload)
    cap_blades = math.ceil(cap_chips / system.blade_chips)

    blades_affordable = int(budget // blade_full_power)
    if blades_affordable >= cap_blades:
        # budget allows >= the capacity-required cluster, fully populated;
        # extra blades add bandwidth (and over-provisioned capacity).
        blades = max(1, blades_affordable)
        chips = blades * system.blade_chips
        return ClusterDesign(system, workload, chips, full_cores)

    # Budget can't fully populate the capacity-required cluster: keep the
    # capacity (the workload must fit) and spend what's left on cores.
    chips = cap_chips
    fixed = (chips * system.modules_per_chip * system.module_power
             + cap_blades * system.blade_overhead)
    remaining = budget - fixed
    cores = int(remaining // (system.core_power * chips))
    cores = max(1, min(full_cores, cores))
    return ClusterDesign(system, workload, chips, cores)


def power_crossover_sla(system_a: SystemSpec, system_b: SystemSpec,
                        workload: Workload, lo: float = 1e-3,
                        hi: float = 10.0, steps: int = 4000) -> float | None:
    """SLA at which performance-provisioned power of a and b cross
    (paper §5.1: ~60 ms for traditional vs die-stacked; ~170 ms at 50%
    accessed; ~800 ms with 8x-denser die-stacks).

    Scans log-spaced SLAs and returns the first sign change (None if the
    curves never cross in [lo, hi]).
    """
    prev = None
    prev_t = None
    for i in range(steps):
        t = lo * (hi / lo) ** (i / (steps - 1))
        diff = (provision_performance(system_a, workload, t).power
                - provision_performance(system_b, workload, t).power)
        if prev is not None and diff == 0:
            return t
        if prev is not None and (diff < 0) != (prev < 0):
            # linear interpolation in log-t between the two samples
            return math.sqrt(t * prev_t)
        prev, prev_t = diff, t
    return None
