"""The paper's analytical model: Equations 1-10 (Lowe-Power et al., BPOE'16).

A `ClusterDesign` is a fully-specified cluster: a system architecture, a
workload, a number of compute chips, and cores enabled per chip. All of the
paper's outputs (response time, power, energy, capacity, over-provisioning)
are derived properties. The three provisioning regimes in
`repro.core.provisioning` construct `ClusterDesign`s under different
constraints.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.systems import SystemSpec, TiB


@dataclass(frozen=True)
class Workload:
    """Workload-dependent model inputs (paper §4)."""

    db_size: float = 16 * TiB       # bytes that must reside in memory
    percent_accessed: float = 0.20  # fraction touched per query (complexity)

    @property
    def bytes_accessed(self) -> float:
        return self.db_size * self.percent_accessed


@dataclass(frozen=True)
class ClusterDesign:
    system: SystemSpec
    workload: Workload
    compute_chips: int
    cores_per_chip: int

    def __post_init__(self):
        if self.compute_chips < 1:
            raise ValueError("cluster needs at least one chip")
        if not 1 <= self.cores_per_chip <= self.system.max_chip_cores:
            raise ValueError(
                f"cores_per_chip {self.cores_per_chip} outside "
                f"[1, {self.system.max_chip_cores}]")

    # --- structure --------------------------------------------------------
    @property
    def mem_modules(self) -> int:
        """Eq. 1 (applied to the deployed cluster)."""
        return self.compute_chips * self.system.modules_per_chip

    @property
    def blades(self) -> int:
        """Eq. 8."""
        return math.ceil(self.compute_chips / self.system.blade_chips)

    @property
    def memory_capacity(self) -> float:
        return self.mem_modules * self.system.module_capacity

    @property
    def overprovision_factor(self) -> float:
        """Deployed memory vs what the workload needs (paper §5.1)."""
        return self.memory_capacity / self.workload.db_size

    # --- performance ------------------------------------------------------
    @property
    def chip_perf(self) -> float:
        """Eq. 4 with the *enabled* cores."""
        return min(self.cores_per_chip * self.system.core_perf,
                   self.system.chip_bandwidth)

    @property
    def cluster_perf(self) -> float:
        return self.chip_perf * self.compute_chips

    @property
    def aggregate_bandwidth(self) -> float:
        """Raw memory bandwidth (paper §5.3 quotes this, not Eq. 4)."""
        return self.system.chip_bandwidth * self.compute_chips

    @property
    def response_time(self) -> float:
        """Eq. 9 (seconds per query)."""
        return self.workload.bytes_accessed / self.cluster_perf

    # --- power / energy ---------------------------------------------------
    @property
    def mem_power(self) -> float:
        """Eq. 6."""
        return self.mem_modules * self.system.module_power

    @property
    def compute_power(self) -> float:
        """Eq. 7."""
        return self.cores_per_chip * self.system.core_power * self.compute_chips

    @property
    def overhead_power(self) -> float:
        return self.blades * self.system.blade_overhead

    @property
    def power(self) -> float:
        """Eq. 10."""
        return self.mem_power + self.compute_power + self.overhead_power

    @property
    def energy_per_query(self) -> float:
        """Joules per query (paper Fig. 6a): power x response time."""
        return self.power * self.response_time

    # --- feasibility ------------------------------------------------------
    @property
    def holds_workload(self) -> bool:
        return self.memory_capacity >= self.workload.db_size

    def summary(self) -> dict:
        return {
            "system": self.system.name,
            "chips": self.compute_chips,
            "cores_per_chip": self.cores_per_chip,
            "blades": self.blades,
            "mem_modules": self.mem_modules,
            "capacity_TiB": self.memory_capacity / TiB,
            "overprovision_x": self.overprovision_factor,
            "agg_bandwidth_TBps": self.aggregate_bandwidth / 1e12,
            "cluster_perf_TBps": self.cluster_perf / 1e12,
            "response_time_ms": self.response_time * 1e3,
            "power_kW": self.power / 1e3,
            "mem_power_kW": self.mem_power / 1e3,
            "compute_power_kW": self.compute_power / 1e3,
            "overhead_power_kW": self.overhead_power / 1e3,
            "energy_per_query_J": self.energy_per_query,
        }


def capacity_chips(system: SystemSpec, workload: Workload) -> int:
    """Eqs. 1-2: chips needed just to hold the database in memory."""
    modules = math.ceil(workload.db_size / system.module_capacity)
    return max(1, math.ceil(modules / system.modules_per_chip))


def cores_for_throughput(system: SystemSpec, required_bw: float,
                         chips: int) -> int:
    """Eq. 5: cores per chip sized to the *required* per-chip throughput.

    This (not always-max cores) is what produces the paper's 60 ms power
    crossover: at relaxed SLAs the die-stacked system powers few cores.
    """
    per_chip = required_bw / chips
    return max(1, min(system.max_chip_cores,
                      math.ceil(per_chip / system.core_perf)))
