"""When-to-use advisor: the paper's provisioning model applied to TPU
clusters serving/training the assigned LM architectures (beyond-paper
contribution, DESIGN.md §2).

The mapping: LLM decode is the bandwidth-bound "query" — each generated
token touches the active parameters plus the KV cache (the modern `percent
accessed`), and the in-memory "database" is params + cache. A TPU chip is
the die-stacked node (HBM on compute); a DDR5 host is the traditional
server. The paper's Eqs. 1-10 then answer: how many chips for an SLA, what
does a power budget buy, what does capacity provisioning cost — with the
collective roofline term (which the paper ignored, §6.2) layered on top.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.configs.base import ArchConfig
from repro.core import traffic
from repro.core.model import ClusterDesign, Workload
from repro.core.provisioning import (provision_capacity,
                                     provision_performance, provision_power)
from repro.core.systems import GB, SystemSpec, TPU_V5E, as_paper_system

# a 2026 "traditional server" for the comparison set: dual-socket DDR5 host
DDR5_HOST = SystemSpec(
    name="ddr5-host",
    module_capacity=64 * 2**30,      # 64 GiB DIMM
    channel_bandwidth=38.4 * GB,     # DDR5-4800 channel
    memory_channels=8,
    channel_modules=2,
    module_power=10.0,
    blade_chips=2,
    core_perf=12 * GB,               # AVX-512 scan/decode throughput per core
    core_power=5.0,
    max_chip_cores=64,
    blade_overhead=200.0,
)


def lm_decode_workload(cfg: ArchConfig, batch: int, seq_len: int) -> Workload:
    """The paper's (db_size, percent_accessed) for one decode step."""
    params_bytes = 2.0 * cfg.param_count()
    cache_bytes = (traffic._kv_bytes_per_row(cfg, seq_len)
                   + traffic._state_bytes_per_row(cfg)) * batch
    db = params_bytes + cache_bytes
    touched = 2.0 * cfg.active_param_count() + cache_bytes
    return Workload(db_size=db, percent_accessed=min(touched / db, 1.0))


@dataclass(frozen=True)
class Advice:
    design: ClusterDesign
    constraint: str
    value: float

    def summary(self) -> dict:
        d = self.design.summary()
        d["constraint"] = f"{self.constraint}={self.value:g}"
        return d


def advise_decode_sla(cfg: ArchConfig, batch: int, seq_len: int,
                      sla_s: float, system: SystemSpec | None = None
                      ) -> Advice:
    """Chips needed so one batched decode step meets `sla_s` (per-token
    latency SLA)."""
    sys_ = system or as_paper_system(TPU_V5E)
    wl = lm_decode_workload(cfg, batch, seq_len)
    return Advice(provision_performance(sys_, wl, sla_s), "sla_s", sla_s)


def advise_power(cfg: ArchConfig, batch: int, seq_len: int, budget_w: float,
                 system: SystemSpec | None = None) -> Advice:
    sys_ = system or as_paper_system(TPU_V5E)
    wl = lm_decode_workload(cfg, batch, seq_len)
    return Advice(provision_power(sys_, wl, budget_w), "power_w", budget_w)


def advise_capacity(cfg: ArchConfig, batch: int, seq_len: int,
                    system: SystemSpec | None = None) -> Advice:
    sys_ = system or as_paper_system(TPU_V5E)
    wl = lm_decode_workload(cfg, batch, seq_len)
    return Advice(provision_capacity(sys_, wl), "capacity_b", wl.db_size)


def scan_workload(db_bytes: float, bytes_scanned: float) -> Workload:
    """The paper's (db_size, percent accessed) measured by the query engine
    rather than assumed: db = the table's packed footprint, percent = the
    fraction one query actually streams."""
    if db_bytes <= 0:
        raise ValueError(f"db_bytes={db_bytes} must be positive")
    return Workload(db_size=db_bytes,
                    percent_accessed=min(bytes_scanned / db_bytes, 1.0))


def calibrated_system(system: SystemSpec,
                      measured_chip_bps: float) -> SystemSpec:
    """Feed Eq. 4 with *attained* per-chip scan throughput: core_perf is
    rescaled so max_cores * core_perf equals the measured rate. Provisioning
    a cluster from this spec answers the paper's question for the system we
    actually built, not the datasheet."""
    if measured_chip_bps <= 0:
        raise ValueError(
            f"measured_chip_bps={measured_chip_bps} must be positive; run "
            f"at least one query before calibrating")
    return dataclasses.replace(
        system, name=f"{system.name}-measured",
        core_perf=measured_chip_bps / system.max_chip_cores)


def advise_scan_sla(db_bytes: float, bytes_per_query: float, sla_s: float,
                    system: SystemSpec | None = None,
                    measured_chip_bps: float | None = None) -> Advice:
    """Chips needed so one scan query meets `sla_s`, optionally from the
    query engine's measured per-chip throughput (the model-vs-measured
    loop)."""
    sys_ = system or as_paper_system(TPU_V5E)
    if measured_chip_bps is not None:
        sys_ = calibrated_system(sys_, measured_chip_bps)
    wl = scan_workload(db_bytes, bytes_per_query)
    return Advice(provision_performance(sys_, wl, sla_s), "sla_s", sla_s)


def when_to_use_tpu(cfg: ArchConfig, batch: int, seq_len: int,
                    slas=(0.005, 0.020, 0.100, 0.500)) -> list[dict]:
    """The paper's Fig. 3 question for 2026: at which per-token SLAs does
    the TPU (die-stacked) cluster use less power than a DDR5-host cluster
    for the same decode workload?"""
    tpu = as_paper_system(TPU_V5E)
    out = []
    for sla in slas:
        a = advise_decode_sla(cfg, batch, seq_len, sla, tpu)
        b = advise_decode_sla(cfg, batch, seq_len, sla, DDR5_HOST)
        out.append({
            "sla_ms": sla * 1e3,
            "tpu_chips": a.design.compute_chips,
            "tpu_power_kw": a.design.power / 1e3,
            "host_chips": b.design.compute_chips,
            "host_power_kw": b.design.power / 1e3,
            "host_overprovision_x": b.design.overprovision_factor,
            "tpu_wins_power": a.design.power < b.design.power,
        })
    return out
