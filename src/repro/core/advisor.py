"""When-to-use advisor: the paper's provisioning model applied to TPU
clusters serving/training the assigned LM architectures (beyond-paper
contribution, DESIGN.md §2).

The mapping: LLM decode is the bandwidth-bound "query" — each generated
token touches the active parameters plus the KV cache (the modern `percent
accessed`), and the in-memory "database" is params + cache. A TPU chip is
the die-stacked node (HBM on compute); a DDR5 host is the traditional
server. The paper's Eqs. 1-10 then answer: how many chips for an SLA, what
does a power budget buy, what does capacity provisioning cost — with the
collective roofline term (which the paper ignored, §6.2) layered on top.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import traffic
from repro.core.model import ClusterDesign, Workload
from repro.core.provisioning import (provision_capacity,
                                     provision_performance, provision_power)
from repro.core.systems import GB, SystemSpec, TPU_V5E, as_paper_system

# a 2026 "traditional server" for the comparison set: dual-socket DDR5 host
DDR5_HOST = SystemSpec(
    name="ddr5-host",
    module_capacity=64 * 2**30,      # 64 GiB DIMM
    channel_bandwidth=38.4 * GB,     # DDR5-4800 channel
    memory_channels=8,
    channel_modules=2,
    module_power=10.0,
    blade_chips=2,
    core_perf=12 * GB,               # AVX-512 scan/decode throughput per core
    core_power=5.0,
    max_chip_cores=64,
    blade_overhead=200.0,
)


def lm_decode_workload(cfg: ArchConfig, batch: int, seq_len: int) -> Workload:
    """The paper's (db_size, percent_accessed) for one decode step."""
    params_bytes = 2.0 * cfg.param_count()
    cache_bytes = (traffic._kv_bytes_per_row(cfg, seq_len)
                   + traffic._state_bytes_per_row(cfg)) * batch
    db = params_bytes + cache_bytes
    touched = 2.0 * cfg.active_param_count() + cache_bytes
    return Workload(db_size=db, percent_accessed=min(touched / db, 1.0))


@dataclass(frozen=True)
class Advice:
    design: ClusterDesign
    constraint: str
    value: float

    def summary(self) -> dict:
        d = self.design.summary()
        d["constraint"] = f"{self.constraint}={self.value:g}"
        return d


def advise_decode_sla(cfg: ArchConfig, batch: int, seq_len: int,
                      sla_s: float, system: SystemSpec | None = None
                      ) -> Advice:
    """Chips needed so one batched decode step meets `sla_s` (per-token
    latency SLA)."""
    sys_ = system or as_paper_system(TPU_V5E)
    wl = lm_decode_workload(cfg, batch, seq_len)
    return Advice(provision_performance(sys_, wl, sla_s), "sla_s", sla_s)


def advise_power(cfg: ArchConfig, batch: int, seq_len: int, budget_w: float,
                 system: SystemSpec | None = None) -> Advice:
    sys_ = system or as_paper_system(TPU_V5E)
    wl = lm_decode_workload(cfg, batch, seq_len)
    return Advice(provision_power(sys_, wl, budget_w), "power_w", budget_w)


def advise_capacity(cfg: ArchConfig, batch: int, seq_len: int,
                    system: SystemSpec | None = None) -> Advice:
    sys_ = system or as_paper_system(TPU_V5E)
    wl = lm_decode_workload(cfg, batch, seq_len)
    return Advice(provision_capacity(sys_, wl), "capacity_b", wl.db_size)


def scan_workload(db_bytes: float, bytes_scanned: float) -> Workload:
    """The paper's (db_size, percent accessed) measured by the query engine
    rather than assumed: db = the table's packed footprint, percent = the
    fraction one query actually streams."""
    if db_bytes <= 0:
        raise ValueError(f"db_bytes={db_bytes} must be positive")
    return Workload(db_size=db_bytes,
                    percent_accessed=min(bytes_scanned / db_bytes, 1.0))


def calibrated_system(system: SystemSpec,
                      measured_chip_bps: float) -> SystemSpec:
    """Feed Eq. 4 with *attained* per-chip scan throughput: core_perf is
    rescaled so max_cores * core_perf equals the measured rate. Provisioning
    a cluster from this spec answers the paper's question for the system we
    actually built, not the datasheet."""
    if not math.isfinite(measured_chip_bps) or measured_chip_bps <= 0:
        raise ValueError(
            f"measured_chip_bps={measured_chip_bps} is a degenerate "
            f"calibration (must be a finite positive rate); run at least "
            f"one query before calibrating")
    return dataclasses.replace(
        system, name=f"{system.name}-measured",
        core_perf=measured_chip_bps / system.max_chip_cores)


def advise_scan_sla(db_bytes: float, bytes_per_query: float, sla_s: float,
                    system: SystemSpec | None = None,
                    measured_chip_bps: float | None = None) -> Advice:
    """Chips needed so one scan query meets `sla_s`, optionally from the
    query engine's measured per-chip throughput (the model-vs-measured
    loop)."""
    sys_ = system or as_paper_system(TPU_V5E)
    if measured_chip_bps is not None:
        sys_ = calibrated_system(sys_, measured_chip_bps)
    wl = scan_workload(db_bytes, bytes_per_query)
    return Advice(provision_performance(sys_, wl, sla_s), "sla_s", sla_s)


def advise_tier_split(db_bytes: float, bytes_per_query: float, sla_s: float,
                      *, hit_curve, fast_gbps: float, capacity_gbps: float,
                      chips: int = 1, fractions=None,
                      fast_system: SystemSpec | None = None) -> dict:
    """The tiered form of the paper's question: how much die-stacked fast
    tier does this workload need to meet its SLA?

    Searches the fast-tier fraction of the database (`fractions`, default
    5%..100%): at each fraction f, `hit_curve(f)` — the fraction of scanned
    bytes the placement engine serves from the fast tier (measured stats,
    or repro.tier.trace.zipf_hit_curve analytically) — yields a blended
    rate (serve.sla.blended_bps), a per-query response time, and the chip
    count performance-provisioning would need at that rate. Every row —
    and the measured fast rate itself — is cross-checked against the
    Eq. 4 roofline of `fast_system`'s *datasheet* (default DIE_STACKED):
    an independent bound, so a mis-measured rate (wrong byte accounting, a
    broken blend) fails the check instead of defining it.

    Returns {"rows": [...], "best": minimal-feasible row or None,
    "roofline_gbps": ..., "fast_within_roofline": bool}.
    """
    from repro.core.systems import DIE_STACKED
    from repro.serve.sla import blended_bps

    if db_bytes <= 0 or bytes_per_query <= 0:
        raise ValueError(f"db_bytes={db_bytes} and bytes_per_query="
                         f"{bytes_per_query} must be positive")
    if sla_s <= 0:
        raise ValueError(f"sla_s={sla_s} must be positive")
    if fast_gbps <= 0 or capacity_gbps <= 0:
        raise ValueError(f"tier rates must be positive, got fast_gbps="
                         f"{fast_gbps} capacity_gbps={capacity_gbps}")
    if not callable(hit_curve):
        pts = sorted(hit_curve.items())     # measured {fraction: hit_rate}
        if not pts:
            raise ValueError("hit_curve dict is empty; measure at least "
                             "one (fast_fraction, hit_rate) point or pass "
                             "an analytic curve (trace.zipf_hit_curve)")
        if any(not 0.0 <= x <= 1.0 for x, _ in pts):
            raise ValueError(f"hit_curve fractions must be in [0, 1], "
                             f"got {[x for x, _ in pts]}")
        xs = [p[0] for p in pts]
        ys = [p[1] for p in pts]
        # a zero fast tier hits nothing by definition; beyond the last
        # measured point np.interp clamps to the measured value rather
        # than assuming a perfect 100% hit rate at full residency
        if xs[0] > 0.0:
            xs, ys = [0.0] + xs, [0.0] + ys
        hit_curve = lambda f, xs=xs, ys=ys: float(np.interp(f, xs, ys))
    # ascending order so "best" really is the minimal feasible fraction
    fractions = (sorted(fractions) if fractions is not None
                 else [i / 20 for i in range(1, 21)])

    # Eq. 4 of the datasheet fast system: min(compute, bandwidth) per
    # chip. Independent of the measured rates, so it can actually fail.
    fast_sys = fast_system or DIE_STACKED
    roofline_bps = fast_sys.chip_peak_perf * chips

    rows = []
    for f in fractions:
        h = min(max(float(hit_curve(f)), 0.0), 1.0)
        rate = blended_bps(fast_gbps * 1e9, capacity_gbps * 1e9, h) * chips
        rt = bytes_per_query / rate
        per_chip = rate / chips
        rows.append({
            "fast_fraction": round(float(f), 4),
            "fast_bytes": f * db_bytes,
            "hit_rate": h,
            "blended_gbps": rate / 1e9,
            "response_time_s": rt,
            "meets_sla": rt <= sla_s,
            "chips_for_sla": math.ceil(bytes_per_query
                                       / (sla_s * per_chip)),
            "within_roofline": rate <= roofline_bps * (1 + 1e-9),
        })
    best = next((r for r in rows if r["meets_sla"]), None)
    return {"sla_s": sla_s, "chips": chips, "rows": rows, "best": best,
            "roofline_gbps": roofline_bps / 1e9,
            "fast_within_roofline":
                fast_gbps * 1e9 * chips <= roofline_bps * (1 + 1e-9)}


def whatif_fast_fraction(attribution, *, db_bytes: float,
                         bytes_per_query: float, sla_s: float,
                         current_fraction: float, hit_curve,
                         fast_gbps: float, capacity_gbps: float,
                         chips: int = 1, fractions=None) -> dict:
    """What-if: convert critical-path attribution into the estimated gain
    from raising the fast-tier fraction.

    `attribution` is a repro.obs.critical_path.Attribution (or any object
    with `.seconds` — category -> total path seconds — and `.queries`).
    The read-bound categories (fast_read, capacity_read, stream_wait)
    are the seconds a bigger fast tier can move; queue, recovery, and
    throttle are carried over unchanged — the attribution *measured*
    that they are not read-rate-bound, which is exactly the information
    a blended-rate model alone cannot see.

    At each candidate fraction f the measured per-query read time is
    scaled by the analytic blended-time ratio
    `t_model(hit(f)) / t_model(hit(current_fraction))` — so overlap or
    layout effects baked into the measurement are preserved while the
    hit-rate improvement moves it. Every row's analytic response time is
    cross-checked against `advise_tier_split` (the tier decision
    surface, an independent pass through blended_bps + the Eq. 4
    roofline) to 1e-6 relative — a drifted formula raises instead of
    advising from it.
    """
    from repro.serve.sla import blended_bps

    seconds = dict(getattr(attribution, "seconds", attribution))
    queries = max(int(getattr(attribution, "queries", 0)) or 1, 1)
    if not 0.0 <= current_fraction <= 1.0:
        raise ValueError(f"current_fraction={current_fraction} must be "
                         f"in [0, 1]")
    read_cats = ("fast_read", "capacity_read", "stream_wait")
    read_s = sum(seconds.get(c, 0.0) for c in read_cats) / queries
    other_s = (sum(seconds.values()) / queries) - read_s
    if read_s <= 0:
        raise ValueError(
            "attribution has no read-bound path seconds (fast_read/"
            "capacity_read/stream_wait all zero); there is nothing a "
            "bigger fast tier could speed up")

    surface = advise_tier_split(
        db_bytes, bytes_per_query, sla_s, hit_curve=hit_curve,
        fast_gbps=fast_gbps, capacity_gbps=capacity_gbps, chips=chips,
        fractions=fractions)
    curve = (hit_curve if callable(hit_curve)
             else None)

    def model_t(h: float) -> float:
        rate = blended_bps(fast_gbps * 1e9, capacity_gbps * 1e9,
                           h) * chips
        return bytes_per_query / rate

    # current operating point: hit rate via the surface's own curve
    # handling (dict curves get the same interpolation the rows used)
    if curve is not None:
        h0 = min(max(float(curve(current_fraction)), 0.0), 1.0)
    else:
        xs = sorted(hit_curve)
        ys = [hit_curve[x] for x in xs]
        if xs and xs[0] > 0.0:
            xs, ys = [0.0] + xs, [0.0] + ys
        h0 = min(max(float(np.interp(current_fraction, xs, ys)), 0.0),
                 1.0)
    t0 = model_t(h0)

    rows = []
    for srow in surface["rows"]:
        h = srow["hit_rate"]
        t_model = model_t(h)
        # the cross-check: same number through the decision surface
        rel = abs(t_model - srow["response_time_s"]) \
            / max(srow["response_time_s"], 1e-30)
        if rel > 1e-6:
            raise ValueError(
                f"what-if response model disagrees with "
                f"advise_tier_split at fraction "
                f"{srow['fast_fraction']}: {t_model!r} vs "
                f"{srow['response_time_s']!r} (rel {rel:.3g})")
        est_read = read_s * (t_model / t0)
        est_resp = other_s + est_read
        rows.append({
            "fast_fraction": srow["fast_fraction"],
            "hit_rate": h,
            "est_read_s": est_read,
            "est_response_s": est_resp,
            "est_gain_s": read_s - est_read,
            "meets_sla": est_resp <= sla_s,
            "within_roofline": srow["within_roofline"],
        })
    best = next((r for r in rows if r["meets_sla"]), None)
    return {
        "sla_s": sla_s,
        "chips": chips,
        "current": {"fast_fraction": current_fraction, "hit_rate": h0,
                    "read_s": read_s, "other_s": other_s,
                    "response_s": read_s + other_s},
        "rows": rows,
        "best": best,
        "surface": surface,
    }


def advise_cost(db_bytes: float, bytes_per_query: float, sla_s: float,
                power_budget_w: float, *, skew: float | None = None,
                fast_gbps: float | None = None, sheet=None,
                compression_ratio: float = 1.0,
                measured_energy_j: float | None = None,
                measured_latency_s: float | None = None) -> dict:
    """The paper's full three-axis question: given an SLA, a power
    envelope, and a workload, which architecture is cheapest per query?

    Delegates to repro.energy.tco.cheapest_architecture (Table-1 systems
    performance-provisioned for the SLA, power-infeasible ones excluded,
    plus — with `skew` — a two-tier node at the zipf hit curve's blended
    rate; `fast_gbps` prices the fast tier from the measured autotune
    sweep; `compression_ratio` — e.g. a measured EncodedTable.ratio —
    shrinks both footprint and traffic, the repro.store axis). With
    `measured_energy_j`/`measured_latency_s` from a metered run
    (EnergyMeter + QueryEngine), the winner's $/query is re-priced at
    the *measured* operating point alongside the datasheet figure, the
    same model-vs-measured loop as model_check()/provision().
    """
    from repro.energy import tco

    cell = tco.cheapest_architecture(
        db_bytes, bytes_per_query, sla_s, power_budget_w, skew=skew,
        sheet=sheet or tco.DEFAULT_COSTS, fast_gbps=fast_gbps,
        compression_ratio=compression_ratio)
    if measured_energy_j is not None or measured_latency_s is not None:
        if measured_energy_j is None or measured_latency_s is None:
            raise ValueError(
                "measured re-pricing needs both measured_energy_j and "
                "measured_latency_s (one without the other mixes "
                "datasheet and metered terms in a single $/query)")
        win = next((c for c in cell["candidates"]
                    if c["name"] == cell["winner"]), None)
        if win is not None:
            cell["usd_per_query_measured"] = tco.usd_per_query(
                win["capex_usd"], measured_latency_s, measured_energy_j,
                sheet or tco.DEFAULT_COSTS)
    return cell


def when_to_use_tpu(cfg: ArchConfig, batch: int, seq_len: int,
                    slas=(0.005, 0.020, 0.100, 0.500)) -> list[dict]:
    """The paper's Fig. 3 question for 2026: at which per-token SLAs does
    the TPU (die-stacked) cluster use less power than a DDR5-host cluster
    for the same decode workload?"""
    tpu = as_paper_system(TPU_V5E)
    out = []
    for sla in slas:
        a = advise_decode_sla(cfg, batch, seq_len, sla, tpu)
        b = advise_decode_sla(cfg, batch, seq_len, sla, DDR5_HOST)
        out.append({
            "sla_ms": sla * 1e3,
            "tpu_chips": a.design.compute_chips,
            "tpu_power_kw": a.design.power / 1e3,
            "host_chips": b.design.compute_chips,
            "host_power_kw": b.design.power / 1e3,
            "host_overprovision_x": b.design.overprovision_factor,
            "tpu_wins_power": a.design.power < b.design.power,
        })
    return out
