"""Core contribution of the paper: bandwidth-capacity provisioning.

- `systems` / `model` / `provisioning`: the paper's analytical model (Eqs. 1-10)
  and its three provisioning regimes.
- `roofline` / `hlo`: the three-term roofline engine that generalizes the
  model to compiled JAX programs on TPU meshes.
- `advisor`: the paper's "when to use" question answered for TPU clusters.
"""
from repro.core.model import ClusterDesign, Workload, capacity_chips
from repro.core.provisioning import (power_crossover_sla, provision_capacity,
                                     provision_performance, provision_power)
from repro.core.systems import (BIG_MEMORY, DIE_STACKED, PAPER_SYSTEMS,
                                TRADITIONAL, TPU_V5E, SystemSpec, TPUSpec)

__all__ = [
    "ClusterDesign", "Workload", "capacity_chips",
    "provision_capacity", "provision_performance", "provision_power",
    "power_crossover_sla",
    "SystemSpec", "TPUSpec", "TRADITIONAL", "BIG_MEMORY", "DIE_STACKED",
    "PAPER_SYSTEMS", "TPU_V5E",
]
