"""Parse collective ops (+ per-device byte counts) out of compiled HLO text.

`cost_analysis()` does not report collective traffic, so the roofline's
third term comes from here: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute in the post-SPMD module, with operand bytes
and replica-group size, converted to per-link ring traffic.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8,
    "s64": 8, "u64": 8, "f64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True))
    + r")\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\(")

_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass(frozen=True)
class CollectiveOp:
    kind: str
    result_bytes: int      # per-device result bytes (sum over tuple parts)
    group_size: int
    line: str

    @property
    def ring_bytes(self) -> float:
        """Per-device bytes crossing links under ring algorithms."""
        g = max(self.group_size, 1)
        n = self.result_bytes
        if self.kind == "collective-permute":
            return float(n)            # point-to-point: no group scaling
        if g == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * n * (g - 1) / g
        if self.kind == "all-gather":
            return n * (g - 1) / g          # n = gathered (full) bytes
        if self.kind == "reduce-scatter":
            return n * (g - 1)              # n = scattered (small) bytes
        if self.kind == "all-to-all":
            return n * (g - 1) / g
        return float(n)                     # collective-permute


def _shape_bytes(expr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(expr):
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind, start = m.group(1), m.group(2)
        # result expression is everything between '=' and the op name
        head = line.split("=", 1)[1].split(kind)[0]
        nbytes = _shape_bytes(head)
        if start:
            nbytes //= 2   # async start carries (operand, result) tuple
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            ge = _GROUPS_EXPLICIT_RE.search(line)
            if ge:
                g = len([x for x in ge.group(1).split(",") if x.strip()])
        ops.append(CollectiveOp(kind, nbytes, g, line.strip()[:160]))
    return ops


def collective_summary(hlo_text: str) -> dict:
    ops = parse_collectives(hlo_text)
    by_kind: dict[str, dict] = {}
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "result_bytes": 0,
                                         "ring_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += op.result_bytes
        d["ring_bytes"] += op.ring_bytes
    return {
        "ops": by_kind,
        "total_count": len(ops),
        "total_result_bytes": sum(o.result_bytes for o in ops),
        "total_ring_bytes": sum(o.ring_bytes for o in ops),
    }
