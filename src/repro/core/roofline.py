"""Three-term roofline from compiled dry-run artifacts (DESIGN.md §5).

Terms are PER-CHIP seconds (cost_analysis on this JAX reports per-device
values for the SPMD-partitioned module — verified empirically):

  compute_s    = flops_per_device / peak_flops
  memory_s     = bytes_per_device / hbm_bw
  collective_s = ring_bytes_per_device / (links * link_bw)

Loop caveat: XLA's cost analysis counts while bodies once, so flops/bytes/
collectives are measured from two small *unrolled* probe compiles (p and 2p
layers) and extrapolated affinely to L layers — exact for homogeneous
stacks (cost is additive per layer). The full-scale scanned compile supplies
memory_analysis and must itself compile (the runnability deliverable).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

from repro.core.systems import TPU_V5E, TPUSpec


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    ring_bytes_per_device: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Full-overlap roofline step estimate (max of the three terms;
        achievable when compute, HBM streaming, and collectives pipeline —
        XLA's async collectives + double-buffered DMA on TPU)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serialized_s(self) -> float:
        """No-overlap lower-bound-of-badness (sum of terms)."""
        return self.compute_s + self.memory_s + self.collective_s

    def overlapped_step_s(self, efficiency: float = 1.0) -> float:
        """Step time at partial overlap: efficiency=1 -> max(terms),
        0 -> sum(terms)."""
        return (self.step_time_s * efficiency
                + self.serialized_s * (1.0 - efficiency))

    @property
    def bound_fraction(self) -> float:
        """Dominant term / sum — 1.0 means perfectly overlappable."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.step_time_s / s if s else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d["dominant"] = self.dominant
        d["step_time_s"] = self.step_time_s
        return d


def terms(flops_per_device: float, bytes_per_device: float,
          ring_bytes_per_device: float, tpu: TPUSpec = TPU_V5E,
          collective_links: int | None = None) -> RooflineTerms:
    links = collective_links if collective_links else 1
    return RooflineTerms(
        compute_s=flops_per_device / tpu.peak_flops_bf16,
        memory_s=bytes_per_device / tpu.hbm_bandwidth,
        collective_s=ring_bytes_per_device / (links * tpu.ici_link_bandwidth),
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        ring_bytes_per_device=ring_bytes_per_device,
    )


def extrapolate(cost_p: dict, cost_2p: dict, num_layers: int, p: int) -> dict:
    """Affine per-layer extrapolation: cost(L) = base + L * per_layer.

    cost_p / cost_2p measured at p and 2p unrolled layers.
    """
    out = {}
    for k in cost_p:
        per_layer = (cost_2p[k] - cost_p[k]) / p
        base = cost_p[k] - p * per_layer
        out[k] = base + num_layers * per_layer
    return out


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global, params-only convention):
    train 6*N*T, prefill 2*N*T, decode 2*N*T with T = tokens that step.
    N = active params for MoE."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        t = shape.tokens_per_step
        return 6.0 * n * t
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens_per_step
    return 2.0 * n * shape.global_batch        # decode: 1 token per row


def utilization(terms_: RooflineTerms, model_flops_global: float,
                chips: int, tpu: TPUSpec = TPU_V5E) -> dict:
    """Roofline fractions reported in EXPERIMENTS.md §Roofline."""
    useful_per_dev = model_flops_global / chips
    step = terms_.step_time_s
    mfu = (useful_per_dev / tpu.peak_flops_bf16) / step if step else 0.0
    hlo_ratio = (useful_per_dev / terms_.flops_per_device
                 if terms_.flops_per_device else 0.0)
    return {
        "model_flops_global": model_flops_global,
        "model_flops_per_device": useful_per_dev,
        "useful_vs_hlo_flops": hlo_ratio,
        "roofline_mfu": mfu,
        "dominant": terms_.dominant,
    }
