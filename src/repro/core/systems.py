"""System datasheets for the paper's analytical model (Table 1) plus TPU specs.

Unit conventions (recovered from the paper's numbers, see DESIGN.md §1):
capacities are *binary* (GiB/TiB), bandwidths are *decimal* (GB/s). Using
these conventions the paper's 256x / 60x capacity-provisioned speedups are
reproduced exactly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# --- unit constants -------------------------------------------------------
KB, MB, GB, TB, PB = 1e3, 1e6, 1e9, 1e12, 1e15          # decimal (bandwidth)
KiB, MiB, GiB, TiB, PiB = 2.0**10, 2.0**20, 2.0**30, 2.0**40, 2.0**50  # binary


@dataclass(frozen=True)
class SystemSpec:
    """One column of the paper's Table 1.

    A *module* is the minimum unit of memory that can be added: a DIMM
    (traditional), a buffer-on-board + its DIMMs (big-memory), or one HBM
    stack (die-stacked).
    """

    name: str
    module_capacity: float      # bytes per memory module (binary units)
    channel_bandwidth: float    # bytes/s per memory channel (decimal units)
    memory_channels: int        # channels per compute chip
    channel_modules: int        # modules per channel
    module_power: float         # W per module
    blade_chips: int            # compute chips per blade
    # shared inputs (Table 1, bottom)
    core_perf: float = 6 * GB   # bytes/s of scan throughput per core
    core_power: float = 3.0     # W per core
    max_chip_cores: int = 32    # cores per compute chip (max)
    blade_overhead: float = 100.0  # W of peripherals per blade (paper §6.1)

    # --- derived chip-level quantities (paper §3) -------------------------
    @property
    def modules_per_chip(self) -> int:
        return self.memory_channels * self.channel_modules

    @property
    def chip_capacity(self) -> float:
        """Bytes of memory attached to one compute chip."""
        return self.modules_per_chip * self.module_capacity

    @property
    def chip_bandwidth(self) -> float:
        """Eq. 3: peak memory bandwidth of one compute chip (bytes/s)."""
        return self.memory_channels * self.channel_bandwidth

    @property
    def chip_peak_perf(self) -> float:
        """Eq. 4: min(compute-limited, bandwidth-limited) chip throughput."""
        return min(self.core_perf * self.max_chip_cores, self.chip_bandwidth)

    @property
    def saturating_cores(self) -> int:
        """Eq. 5 at full tilt: cores needed to saturate the chip."""
        import math

        return min(self.max_chip_cores,
                   math.ceil(self.chip_bandwidth / self.core_perf))

    @property
    def bandwidth_capacity_ratio(self) -> float:
        """Fraction of attached memory one chip can stream per second (1/s).

        The paper's Figure 1 metric; uses raw channel bandwidth (not the
        compute-capped Eq. 4 rate), matching the 80x / 341x claims.
        """
        return self.chip_bandwidth / self.chip_capacity

    def with_density(self, factor: float) -> "SystemSpec":
        """Denser DRAM chips (paper §6.1): same bandwidth/power per module,
        `factor`x the capacity per module."""
        return dataclasses.replace(
            self, name=f"{self.name}-x{factor:g}density",
            module_capacity=self.module_capacity * factor)

    def with_compute_power(self, factor: float) -> "SystemSpec":
        """Scaled per-core power (paper §6.1 asks about 10x lower)."""
        return dataclasses.replace(
            self, name=f"{self.name}-x{factor:g}corepower",
            core_power=self.core_power * factor)


# --- the paper's three systems (Table 1) ----------------------------------

TRADITIONAL = SystemSpec(
    name="traditional",          # Dell PowerEdge R930-like, Xeon E7 v3
    module_capacity=32 * GiB,    # DDR4 DIMM
    channel_bandwidth=25.6 * GB,
    memory_channels=4,
    channel_modules=2,           # 2 DIMMs/channel for full DDR bandwidth
    module_power=8.0,
    blade_chips=4,
)

BIG_MEMORY = SystemSpec(
    name="big-memory",           # Oracle SPARC M7-like appliance
    module_capacity=512 * GiB,   # buffer-on-board + 8 DIMMs = one module
    channel_bandwidth=48 * GB,
    memory_channels=4,
    channel_modules=1,
    module_power=100.0,
    blade_chips=1,
)

DIE_STACKED = SystemSpec(
    name="die-stacked",          # HBM 2.0 stack on compute (nanostore-like)
    module_capacity=8 * GiB,     # 8-high stack of 8 Gbit chips
    channel_bandwidth=256 * GB,  # HBM 2.0 per stack
    memory_channels=1,
    channel_modules=1,
    module_power=10.0,
    blade_chips=9,
)

PAPER_SYSTEMS = (TRADITIONAL, BIG_MEMORY, DIE_STACKED)


# --- TPU adaptation (DESIGN.md §2): v5e as the 2026 die-stacked node -------

@dataclass(frozen=True)
class TPUSpec:
    """Datasheet constants used by the roofline engine and the advisor."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # FLOP/s per chip
    hbm_bandwidth: float = 819 * GB     # bytes/s per chip
    hbm_capacity: float = 16 * GiB      # bytes per chip
    ici_link_bandwidth: float = 50 * GB  # bytes/s per ICI link (one direction)
    ici_links: int = 4                  # 2D torus: +/-x, +/-y
    chip_power: float = 200.0           # W (typical board power per chip)
    chips_per_host: int = 4
    host_overhead_power: float = 250.0  # W per host (CPU, NIC, fans)

    @property
    def bandwidth_capacity_ratio(self) -> float:
        return self.hbm_bandwidth / self.hbm_capacity

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte at which compute and HBM terms balance."""
        return self.peak_flops_bf16 / self.hbm_bandwidth


TPU_V5E = TPUSpec()


def as_paper_system(tpu: TPUSpec = TPU_V5E) -> SystemSpec:
    """Express a TPU chip in the paper's Table-1 vocabulary so that the
    paper's provisioning machinery applies unchanged (DESIGN.md §2).

    One chip = one module = one "channel"; cores are modeled so that
    core_perf * max_cores ~= HBM bandwidth (decode is bandwidth-bound, the
    paper's Eq. 4 regime).
    """
    cores = 32
    return SystemSpec(
        name=f"{tpu.name}-as-paper",
        module_capacity=tpu.hbm_capacity,
        channel_bandwidth=tpu.hbm_bandwidth,
        memory_channels=1,
        channel_modules=1,
        module_power=tpu.chip_power * 0.25,   # HBM share of board power
        blade_chips=tpu.chips_per_host,
        core_perf=tpu.hbm_bandwidth / cores,
        core_power=tpu.chip_power * 0.75 / cores,
        max_chip_cores=cores,
        blade_overhead=tpu.host_overhead_power,
    )
