"""Analytic per-device HBM + interconnect traffic model (TPU-faithful).

Why this exists: the dry-run measures FLOPs/bytes from XLA:CPU cost
analysis, but the CPU backend converts every bf16 dot operand to f32 and
materializes layout copies a TPU would never issue, inflating byte counts
2-18x (measured; see EXPERIMENTS.md §Dry-run). Following the paper's own
methodology (a back-of-the-envelope bytes-accessed model, Eqs. 1-10), this
module derives the memory/collective roofline terms analytically from the
architecture + shape + sharding strategy; the HLO-measured numbers are
reported alongside as upper bounds.

Strategies (repro.dist.strategies): "megatron" (baseline TP+FSDP+DP),
"dp" (no TP), "cp" (context parallel), "2d" (decode 2D weight residency).

Conventions (documented in EXPERIMENTS.md):
- weights/activations bf16 (2 B), optimizer state + master fp32,
  logits read for CE in fp32.
- FSDP weight traffic: all-gather writes the gathered copy to HBM, matmuls
  read it back (per pass). Block remat adds one forward re-read+re-gather.
- flash/blockwise attention: no score materialization; K/V re-read once per
  1024-row query block (causal halves it).
- ACT_ALPHA: residual-stream read/write passes per layer that survive
  fusion (x-in, norm, mixer out, +res, ffn in/out ~= 6 each way).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeSpec

BF16 = 2
F32 = 4
ACT_ALPHA = 6.0
QBLOCK = 1024


@dataclass(frozen=True)
class MeshShape:
    chips: int
    tp: int          # |model|
    fsdp: int        # |data| (x |pod| when params use it)
    dp: int          # batch shards = chips / tp (pod x data)

    @classmethod
    def production(cls, multi_pod: bool):
        chips = 512 if multi_pod else 256
        tp = 16
        dp = chips // tp
        return cls(chips=chips, tp=tp, fsdp=dp, dp=dp)


@dataclass(frozen=True)
class Layout:
    """Strategy-resolved sharding factors."""
    tp: int            # weight TP shards (activation all-reduce group)
    fsdp: int          # weight FSDP shards (gather group)
    dp: int            # batch shards
    seq_shard: int     # sequence shards (context parallelism)
    regather_decode: bool  # weights re-gathered per decode step

    @property
    def token_shards(self) -> int:
        return self.dp * self.seq_shard


def layout_for(strategy: str, mesh: MeshShape) -> Layout:
    if strategy == "megatron":
        return Layout(tp=mesh.tp, fsdp=mesh.fsdp, dp=mesh.dp, seq_shard=1,
                      regather_decode=True)
    if strategy in ("dp", "dp_noremat"):
        return Layout(tp=1, fsdp=mesh.fsdp, dp=mesh.chips, seq_shard=1,
                      regather_decode=True)
    if strategy == "cp":
        return Layout(tp=1, fsdp=mesh.fsdp, dp=mesh.dp,
                      seq_shard=mesh.tp, regather_decode=True)
    if strategy in ("2d", "2d_splitcache"):
        # weights resident (fsdp x tp)-sharded; activations reduced instead
        return Layout(tp=mesh.tp, fsdp=mesh.fsdp, dp=mesh.dp, seq_shard=1,
                      regather_decode=False)
    raise ValueError(strategy)


def _attention_layers(cfg: ArchConfig) -> int:
    return sum(1 for i in range(cfg.num_layers)
               if cfg.pattern_at(i) in ("attn", "swa"))


def _state_bytes_per_row(cfg: ArchConfig) -> float:
    """Recurrent state bytes per batch row (SSM / RG-LRU archs)."""
    total = 0.0
    for i in range(cfg.num_layers):
        k = cfg.pattern_at(i)
        if k == "ssd":
            total += cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * F32
            total += (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * BF16
        elif k == "rglru":
            total += cfg.resolved_lru_width * (F32 + (cfg.ssm_conv - 1) * BF16)
    return total


def _kv_bytes_per_row(cfg: ArchConfig, seq_len: int) -> float:
    per_layer = 0.0
    for i in range(cfg.num_layers):
        k = cfg.pattern_at(i)
        if k == "attn":
            per_layer += seq_len * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * BF16
        elif k == "swa":
            win = min(cfg.window or seq_len, seq_len)
            per_layer += win * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * BF16
    return per_layer


def hbm_traffic(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshShape,
                strategy: str = "megatron") -> dict:
    """Per-device HBM bytes for one step. Returns breakdown + total."""
    lay = layout_for(strategy, mesh)
    n = cfg.param_count()
    w_gathered = BF16 * n / lay.tp           # weights a chip touches/pass
    d = cfg.d_model
    v = cfg.vocab_size
    vocab_shards = lay.tp
    out: dict[str, float] = {}

    if shape.kind == "train":
        tok_local = shape.tokens_per_step / lay.token_shards
        remat_passes = 1.0 if cfg.remat != "none" else 0.0
        # gather-write + read, for fwd / bwd(dL/dx) / remat re-forward
        out["weights"] = w_gathered * 2 * (2.0 + remat_passes)
        out["grads"] = w_gathered * 2            # write local, read for RS
        out["optimizer"] = (n / (lay.tp * lay.fsdp)) * (
            3 * F32 * 2          # m, v, master read+write
            + F32                # reduced grad shard read
            + BF16)              # bf16 param write
        out["activations"] = (cfg.num_layers * ACT_ALPHA * 2  # fwd+bwd
                              * tok_local * d * BF16)
        rows_local = shape.global_batch / lay.dp
        # blockwise attention: all K/V (<= window) re-read once per query
        # block (causal ~halves it); kv heads sharded tp-way; 3 passes
        out["attention_kv"] = (_kv_bytes_per_row(cfg, shape.seq_len)
                               * rows_local
                               * 0.5 * (shape.seq_len / QBLOCK)
                               / (lay.tp * lay.seq_shard) * 3)
        ce_bytes = BF16 + F32 if not cfg.fused_ce else BF16 * 0.25
        out["logits_ce"] = tok_local * (v / vocab_shards) * ce_bytes * 2
    elif shape.kind == "prefill":
        tok_local = shape.tokens_per_step / lay.token_shards
        rows_local = shape.global_batch / lay.dp
        out["weights"] = w_gathered * 2
        out["activations"] = cfg.num_layers * ACT_ALPHA * tok_local * d * BF16
        out["attention_kv"] = (_kv_bytes_per_row(cfg, shape.seq_len)
                               * rows_local
                               * 0.5 * (shape.seq_len / QBLOCK)
                               / (lay.tp * lay.seq_shard))
        out["cache_write"] = (_kv_bytes_per_row(cfg, shape.seq_len)
                              * shape.global_batch / mesh.chips)
        out["logits_ce"] = rows_local * (v / vocab_shards) * BF16
    else:  # decode
        b = shape.global_batch
        n_act = cfg.active_param_count()
        if lay.regather_decode:
            # ZeRO-inference: params re-gathered each step (write + read)
            out["weights"] = BF16 * n_act / lay.tp * 2
        else:
            # 2D-resident: each chip reads only its own shard
            out["weights"] = BF16 * n_act / (lay.tp * lay.fsdp)
        cache_global = (_kv_bytes_per_row(cfg, shape.seq_len)
                        + _state_bytes_per_row(cfg)) * b
        out["cache_read"] = cache_global / mesh.chips
        out["cache_write"] = (_kv_bytes_per_row(cfg, 1)
                              + _state_bytes_per_row(cfg)) * b / mesh.chips
        out["activations"] = (cfg.num_layers * ACT_ALPHA
                              * max(b / lay.dp, 1) * d * BF16)
        out["logits_ce"] = max(b / lay.dp, 1) * (v / vocab_shards) * F32

    out["total"] = sum(out.values())
    return out


def collective_traffic(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshShape,
                       strategy: str = "megatron") -> dict:
    """Per-device ring bytes crossing ICI links for one step (analytic)."""
    lay = layout_for(strategy, mesh)
    n = cfg.param_count()
    d = cfg.d_model
    rg_f = (lay.fsdp - 1) / lay.fsdp if lay.fsdp > 1 else 0.0
    rg_t = (lay.tp - 1) / lay.tp if lay.tp > 1 else 0.0
    # "dp"/"cp" replicate weights over the model axis -> grads also need an
    # all-reduce across it
    model_rep = mesh.tp if lay.tp == 1 and lay.seq_shard == 1 else 1
    rg_rep = (model_rep - 1) / model_rep if model_rep > 1 else 0.0
    cp = lay.seq_shard
    rg_cp = (cp - 1) / cp if cp > 1 else 0.0
    w_gathered = BF16 * n / lay.tp
    out: dict[str, float] = {}

    def ep_alltoall(tokens_local: float, passes: float) -> float:
        """MoE expert-parallel dispatch: each routed token copy crosses the
        expert-sharding axis there and back (all-to-all), per MoE layer.
        Applies only when experts are actually EP-sharded (E >= |model|
        under megatron rules; replicated experts under dp/cp dispatch
        locally)."""
        if not cfg.num_experts or lay.tp == 1 \
                or cfg.num_experts < mesh.tp:
            return 0.0
        per_layer = (2.0 * tokens_local * cfg.experts_per_token
                     * cfg.d_model * BF16 * rg_t)
        return cfg.num_layers * per_layer * passes

    if shape.kind == "train":
        tok_local = shape.tokens_per_step / lay.token_shards
        rows_local = shape.global_batch / lay.dp
        passes = 3.0 if cfg.remat != "none" else 2.0
        out["fsdp_allgather"] = w_gathered * rg_f * passes
        out["grad_reduce_scatter"] = w_gathered * rg_f
        out["grad_allreduce_rep"] = 2.0 * w_gathered * rg_rep
        # Megatron TP: 2 all-reduces per layer fwd, 2 bwd, on (tok, d) bf16
        out["tp_allreduce"] = (cfg.num_layers * 4
                               * 2.0 * tok_local * d * BF16 * rg_t)
        # CP: K/V all-gathered across seq shards, fwd + bwd
        out["cp_kv_allgather"] = (_kv_bytes_per_row(cfg, shape.seq_len)
                                  * rows_local * rg_cp * 3.0)
        out["ep_alltoall"] = ep_alltoall(tok_local, 3.0)
    elif shape.kind == "prefill":
        tok_local = shape.tokens_per_step / lay.token_shards
        rows_local = shape.global_batch / lay.dp
        out["fsdp_allgather"] = w_gathered * rg_f
        out["tp_allreduce"] = (cfg.num_layers * 2
                               * 2.0 * tok_local * d * BF16 * rg_t)
        out["cp_kv_allgather"] = (_kv_bytes_per_row(cfg, shape.seq_len)
                                  * rows_local * rg_cp)
        out["ep_alltoall"] = ep_alltoall(tok_local, 1.0)
    else:
        b = shape.global_batch
        n_act = cfg.active_param_count()
        rows_local = max(b / lay.dp, 1)
        if lay.regather_decode:
            out["fsdp_allgather"] = BF16 * n_act / lay.tp * rg_f
            out["tp_allreduce"] = (cfg.num_layers * 2
                                   * 2.0 * rows_local * d * BF16 * rg_t)
            out["ep_alltoall"] = ep_alltoall(rows_local, 1.0)
        else:
            # 2D: activations reduced over BOTH axes per layer (partial-sum
            # psum over fsdp + the usual tp all-reduce), weights stay put
            out["act_reduce_2d"] = (cfg.num_layers * 2 * 2.0 * rows_local
                                    * d * BF16 * (rg_t + rg_f))
        out["logits_gather"] = rows_local * cfg.vocab_size / lay.tp * F32 * rg_t

    out["total"] = sum(out.values())
    return out
