"""Vectorized + differentiable relaxation of the paper's model (jnp).

Two beyond-paper uses:
- `sweep_*`: evaluate whole SLA/power/capacity grids on-device in one call
  (the paper's figures as single vmapped expressions).
- `soft_*`: a smooth relaxation (ceil -> softplus-smoothed) that makes
  cluster design differentiable — `grad(power)(sla, density, core_power)`
  gives the sensitivity analysis of §6.1 analytically instead of by
  finite differencing the discrete model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.model import Workload
from repro.core.systems import SystemSpec


def _soft_ceil(x, tau: float = 0.05):
    """Smooth ceil: x + softplus-smoothed fractional correction."""
    frac = x - jnp.floor(x)
    return jnp.floor(x) + jax.nn.sigmoid((frac - 0.5) / tau)


def soft_performance_power(system: SystemSpec, workload: Workload, sla,
                           density: float = 1.0, core_power_scale: float = 1.0,
                           hard: bool = False):
    """Differentiable Eq. 10 under performance provisioning.

    sla may be a scalar or an array (vectorizes); density / core_power_scale
    are the §6.1 levers.
    """
    ceil = jnp.ceil if hard else _soft_ceil
    sla = jnp.asarray(sla, jnp.float32)
    required_bw = workload.bytes_accessed / sla
    chip_cap = system.chip_capacity * density
    cap_chips = ceil(workload.db_size / chip_cap)
    bw_chips = ceil(required_bw / system.chip_peak_perf)
    chips = jnp.maximum(cap_chips, bw_chips)
    cores = jnp.clip(ceil(required_bw / chips / system.core_perf),
                     1, system.max_chip_cores)
    blades = ceil(chips / system.blade_chips)
    mem_power = chips * system.modules_per_chip * system.module_power
    compute_power = chips * cores * system.core_power * core_power_scale
    return mem_power + compute_power + blades * system.blade_overhead


def sweep_performance(system: SystemSpec, workload: Workload, slas):
    """Power across an SLA grid (hard ceilings — matches the scalar model
    to within the soft/hard gap, asserted in tests)."""
    return soft_performance_power(system, workload, jnp.asarray(slas),
                                  hard=True)


def power_sensitivity(system: SystemSpec, workload: Workload, sla: float):
    """d power / d (log density, log core_power) at the operating point —
    the analytical version of the paper's §6.1 what-ifs."""

    def f(log_density, log_cps):
        return soft_performance_power(system, workload, sla,
                                      density=jnp.exp(log_density),
                                      core_power_scale=jnp.exp(log_cps))

    g = jax.grad(f, argnums=(0, 1))(0.0, 0.0)
    return {"d_power_d_log_density": float(g[0]),
            "d_power_d_log_core_power": float(g[1])}
