"""repro: bandwidth-provisioned multi-pod JAX framework (BPOE'16 reproduction)."""
__version__ = "0.1.0"
