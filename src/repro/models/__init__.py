"""Model zoo: functional layers, blocks, and the causal LM assembly."""
