"""Shared model building blocks (functional style, params = pytrees).

Every init function returns `(params, axes)` where `axes` is a pytree of the
same structure holding logical-axis-name tuples for each array. The sharding
layer (`repro.dist.sharding`) maps logical names -> mesh axes, so models never
mention the mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# parameter initialization
# --------------------------------------------------------------------------

def dense_init(key, shape, axes, dtype, scale: float | None = None):
    """Truncated-normal fan-in init; returns (array, logical axes)."""
    fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    return w.astype(dtype), axes


def zeros_init(shape, axes, dtype):
    return jnp.zeros(shape, dtype), axes


def ones_init(shape, axes, dtype):
    return jnp.ones(shape, dtype), axes


def axes_str(names) -> str:
    """Logical axes tuple -> a single string leaf ('embed heads'; '_' = None).

    Strings are pytree leaves, so axes trees mirror param trees exactly.
    """
    if isinstance(names, str):
        return names
    return " ".join(n if n else "_" for n in names) or "_scalar_"


def axes_names(s):
    """Inverse of axes_str -> list[str | None]."""
    if not isinstance(s, str):
        return list(s)
    if s == "_scalar_":
        return []
    return [None if n == "_" else n for n in s.split()]


def _is_param_axes_pair(x):
    return (isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype")
            and not hasattr(x[1], "dtype"))


def split_tree(params_and_axes):
    """{'w': (arr, ax), ...} (possibly nested) -> (params, axes) twin trees.

    Axes leaves are encoded as strings (see axes_str)."""
    params = jax.tree.map(lambda pa: pa[0], params_and_axes,
                          is_leaf=_is_param_axes_pair)
    axes = jax.tree.map(lambda pa: axes_str(pa[1]), params_and_axes,
                        is_leaf=_is_param_axes_pair)
    return params, axes


def map_axes_tree(axes_tree):
    """Tree whose leaves are tuples of names -> tree of axes_str leaves."""
    is_names = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(axes_str, axes_tree, is_leaf=is_names)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    """RMSNorm in fp32 accumulation, output in input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels):
    """Mean next-token CE; logits (B, S, V) any float dtype, labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_cross_entropy(x, head_w, labels, num_chunks: int = 8):
    """CE computed seq-chunk-wise so (B, S, V) logits never materialize.

    Beyond-paper memory optimization (§Perf): reduces the HBM term for large
    vocabularies by num_chunks.
    """
    b, s, _ = x.shape
    assert s % num_chunks == 0, (s, num_chunks)
    xs = x.reshape(b, num_chunks, s // num_chunks, x.shape[-1])
    ls = labels.reshape(b, num_chunks, s // num_chunks)

    def one(chunk):
        xc, lc = chunk
        logits = jnp.einsum("bsd,dv->bsv", xc, head_w)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    total = jax.lax.map(one, (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    return jnp.sum(total) / (b * s)
