"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Train/prefill uses the chunked dual form: block-diagonal (intra-chunk)
attention-like matmuls + a low-rank inter-chunk state recurrence; decode is
the O(1) recurrent update. Both share the same math as `repro.kernels.ssd`'s
reference and are cross-checked in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm, zeros_init


def _dims(cfg):
    din = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = din + 2 * n          # x, B, C go through the conv (groups=1)
    return din, n, h, conv_dim


def init(key, cfg, dtype):
    din, n, h, conv_dim = _dims(cfg)
    d_in_proj = 2 * din + 2 * n + h
    ki, kc, ka, ko = jax.random.split(key, 4)
    a_init = jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32))
    return {
        "in_proj": dense_init(ki, (cfg.d_model, d_in_proj),
                              ("embed", "ssm_proj"), dtype),
        "conv_w": dense_init(kc, (cfg.ssm_conv, conv_dim),
                             ("conv_k", "ssm_conv_dim"), dtype, scale=0.5),
        "A_log": (a_init, ("ssm_heads",)),
        "dt_bias": zeros_init((h,), ("ssm_heads",), jnp.float32),
        "D": (jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "gate_norm": zeros_init((din,), ("ssm_inner",), jnp.float32),
        "out_proj": dense_init(ko, (din, cfg.d_model),
                               ("ssm_inner", "embed"), dtype),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv. x: (B, S, C), w: (K, C). tail: (B, K-1, C)
    carried state for decode. Returns (y, new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(y), xp[:, -(k - 1):]


def _split_proj(zxbcdt, cfg):
    din, n, h, _ = _dims(cfg)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n:]
    return z, xbc, dt


def _ssd_chunked(xh, dt, a_log, bm, cm, cfg, init_state=None):
    """Chunked SSD scan.

    xh: (B, S, H, P) inputs; dt: (B, S, H) fp32 post-softplus;
    bm/cm: (B, S, N); returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    a = -jnp.exp(a_log)                                   # (H,) negative
    l = dt * a                                            # (B,S,H) log-decay

    def r(t, shape):  # reshape into chunks
        return t.reshape(b, nc, q, *shape)

    lc = r(l, (h,))                                       # (B,NC,Q,H)
    xc = r(xh, (h, p))
    dtc = r(dt, (h,))
    bc = r(bm, (n,))
    cc = r(cm, (n,))
    cum = jnp.cumsum(lc, axis=2)                          # (B,NC,Q,H)
    total = cum[:, :, -1]                                 # (B,NC,H)

    # --- intra-chunk (block-diagonal dual form) ---------------------------
    # att[b,k,h,i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j,   j <= i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,NC,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask the *argument* (not the result): exp of the masked upper triangle
    # overflows and inf * 0 would poison the gradient with NaNs.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bkin,bkjn->bkij", cc, bc)            # (B,NC,Q,Q)
    att = cb[..., None] * decay * dtc[:, :, None, :, :]   # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", att, xc.astype(jnp.float32))

    # --- chunk summary states --------------------------------------------
    # S_k[n,p] = sum_j exp(total - cum_j) * dt_j * B_j[n] * x_j[p]
    decay_to_end = jnp.exp(total[:, :, None] - cum)       # (B,NC,Q,H)
    sk = jnp.einsum("bkjh,bkjn,bkjhp->bkhnp",
                    decay_to_end * dtc, bc, xc.astype(jnp.float32))

    # --- inter-chunk recurrence (scan over chunks) ------------------------
    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inp):
        s_k, tot = inp                                    # (B,H,N,P),(B,H)
        out = state
        new = state * jnp.exp(tot)[..., None, None] + s_k
        return new, out

    states = (jnp.moveaxis(sk, 1, 0), jnp.moveaxis(total, 1, 0))
    final, prev_states = jax.lax.scan(step, h0, states)
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B,NC,H,N,P)

    # --- inter-chunk contribution ----------------------------------------
    y_inter = jnp.einsum("bkih,bkin,bkhnp->bkihp",
                         jnp.exp(cum), cc, prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(xh.dtype), final


def apply(params, x, cfg, state=None):
    """Full-sequence SSD block. x: (B, S, D). state: optional dict from a
    previous segment (chunk-streaming / decode handoff).
    Returns (out, new_state)."""
    din, n, h, _ = _dims(cfg)
    p = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    conv_tail = state["conv"] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], conv_tail)
    xs = xbc[..., :din]
    bm = xbc[..., din:din + n]
    cm = xbc[..., din + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xh = xs.reshape(*xs.shape[:-1], h, p)
    init_state = state["ssm"] if state is not None else None
    y, final = _ssd_chunked(xh, dt, params["A_log"], bm, cm, cfg, init_state)
    y = y + (params["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*x.shape[:-1], din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"ssm": final, "conv": new_tail}


def decode_step(params, x, cfg, state):
    """Single-token recurrent update. x: (B, 1, D)."""
    din, n, h, _ = _dims(cfg)
    p = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], state["conv"])
    xs, bm, cm = (xbc[..., :din], xbc[..., din:din + n], xbc[..., din + n:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = jnp.exp(dt * -jnp.exp(params["A_log"]))            # (B,H)
    xh = xs[:, 0].reshape(-1, h, p).astype(jnp.float32)    # (B,H,P)
    bx = jnp.einsum("bn,bhp->bhnp", bm[:, 0].astype(jnp.float32),
                    xh * dt[..., None])
    new = state["ssm"] * a[..., None, None] + bx
    y = jnp.einsum("bn,bhnp->bhp", cm[:, 0].astype(jnp.float32), new)
    y = y + params["D"][:, None] * xh
    y = y.reshape(-1, 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"ssm": new, "conv": new_tail}


def init_state(cfg, batch: int, dtype):
    din, n, h, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


STATE_AXES = {"ssm": ("batch", "ssm_heads", "ssm_state", "ssm_head_dim"),
              "conv": ("batch", "conv_k", "ssm_conv_dim")}
