"""Residual decoder blocks: norm -> mixer -> residual [-> norm -> ffn/moe].

Block kinds: "attn" (full causal), "swa" (sliding window), "ssd" (Mamba-2),
"rglru" (Griffin recurrent). SSD blocks have no separate FFN (the mixer is
the whole block, d_ff == 0 for pure-SSM archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.models import attention, mlp, moe, rglru, ssm
from repro.models.common import rms_norm, zeros_init


def has_ffn(cfg, kind: str) -> bool:
    return cfg.d_ff > 0 and kind != "ssd"


def block_init(key, cfg, kind: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": zeros_init((cfg.d_model,), ("embed",), jnp.float32)}
    if kind in ("attn", "swa"):
        p["mixer"] = attention.init(k1, cfg, dtype)
    elif kind == "ssd":
        p["mixer"] = ssm.init(k1, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru.init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if has_ffn(cfg, kind):
        p["norm2"] = zeros_init((cfg.d_model,), ("embed",), jnp.float32)
        if cfg.num_experts:
            p["moe"] = moe.init(k2, cfg, dtype)
        else:
            p["ffn"] = mlp.init(k2, cfg, dtype)
    return p


def block_apply(params, x, positions, cfg, kind: str, *,
                cache=None, cache_len=None, decode: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    window = cfg.window if kind == "swa" else 0
    if kind in ("attn", "swa"):
        out, new_cache = attention.attend(
            params["mixer"], h, positions, cfg, window=window,
            impl=getattr(cfg, "attn_impl", "auto"), kv_cache=cache)
    elif kind == "ssd":
        fn = ssm.decode_step if decode else ssm.apply
        out, new_cache = fn(params["mixer"], h, cfg, cache)
    elif kind == "rglru":
        fn = rglru.decode_step if decode else rglru.apply
        out, new_cache = fn(params["mixer"], h, cfg, cache)
    else:
        raise ValueError(kind)
    x = x + out
    x = logical_constraint(x, ("batch", "seq", "embed"))

    aux_loss = jnp.zeros((), jnp.float32)
    if has_ffn(cfg, kind):
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        if cfg.num_experts:
            out, aux = moe.apply(params["moe"], h, cfg)
            aux_loss = aux["aux_loss"]
        else:
            out = mlp.apply(params["ffn"], h)
        x = x + out
        x = logical_constraint(x, ("batch", "seq", "embed"))
    return x, new_cache, aux_loss


def block_cache_init(cfg, kind: str, batch: int, max_len: int, dtype):
    """Decode cache for one block. Attention caches are ring buffers of
    size min(window, max_len) with a stored-position plane for masking."""
    if kind in ("attn", "swa"):
        size = min(cfg.window, max_len) if kind == "swa" else max_len
        return attention.init_cache(cfg, batch, size, dtype)
    if kind == "ssd":
        return ssm.init_state(cfg, batch, dtype)
    if kind == "rglru":
        return rglru.init_state(cfg, batch, dtype)
    raise ValueError(kind)


def block_cache_axes(kind: str):
    if kind in ("attn", "swa"):
        ax = dict(attention.CACHE_AXES)
        ax["pos"] = ("batch", "kv_seq")
        return ax
    if kind == "ssd":
        return ssm.STATE_AXES
    if kind == "rglru":
        return rglru.STATE_AXES
    raise ValueError(kind)
