"""GQA attention: full/causal, sliding-window, and KV-cache decode paths.

Three implementations share one math definition (tests assert equivalence):
- "naive": materializes (B, H, S, T) scores — reference & small shapes.
- "blockwise": lax.scan over KV blocks with online softmax (flash-style in
  pure JAX) — the train/prefill default at large S.
- Pallas flash kernel (repro.kernels.flash_attention) — TPU-optimized path,
  selected via attn_impl="flash" (interpret mode off-TPU).

Decode caches are ring buffers {k, v, pos}: slot = position % size, with the
stored-position plane driving the causal/window mask (slots never written
hold pos = +INF_POS and are therefore masked). Full-attention caches size the
ring to max_len so nothing is ever evicted; sliding-window caches size it to
the window.

K/V are stored in the kernel-native (B, KVH, S, D) layout — exactly what
the split-K decode kernel streams — so a decode step hands the cache to the
kernel without any transpose/copy of the full ring (only the one new token
is transposed on write). The naive/blockwise reference paths transpose on
read; they exist for testing and tiny shapes, not the serving hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.models.common import apply_rope, dense_init

NEG_INF = -1e30
INF_POS = 1 << 30    # "never written" marker in the pos plane


def init(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (cfg.d_model, cfg.num_heads, hd),
                         ("embed", "heads", "head_dim"), dtype),
        "wk": dense_init(kk, (cfg.d_model, cfg.num_kv_heads, hd),
                         ("embed", "kv_heads", "head_dim"), dtype),
        "wv": dense_init(kv, (cfg.d_model, cfg.num_kv_heads, hd),
                         ("embed", "kv_heads", "head_dim"), dtype),
        "wo": dense_init(ko, (cfg.num_heads, hd, cfg.d_model),
                         ("heads", "head_dim", "embed"), dtype,
                         scale=1.0 / (hd * cfg.num_heads) ** 0.5),
    }


def _mask(q_pos, kv_pos, window: int):
    """(B, Sq, Skv) additive mask: causal, optionally sliding-window."""
    d = q_pos[:, :, None] - kv_pos[:, None, :]
    ok = d >= 0
    if window:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _gqa_scores(q, k):
    """q: (B,Sq,Kv,G,H), k: (B,Skv,Kv,H) -> (B,Kv,G,Sq,Skv) fp32 scores."""
    return jnp.einsum("bskgh,btkh->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def _naive(q, k, v, q_pos, kv_pos, window):
    scale = q.shape[-1] ** -0.5
    s = _gqa_scores(q * scale, k)
    s = s + _mask(q_pos, kv_pos, window)[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)


def _blockwise(q, k, v, q_pos, kv_pos, window, block_kv: int = 1024):
    """Online-softmax over KV blocks; O(Sq * block) live memory."""
    b, skv = k.shape[0], k.shape[1]
    block_kv = min(block_kv, skv)
    assert skv % block_kv == 0, (skv, block_kv)
    nblk = skv // block_kv
    scale = q.shape[-1] ** -0.5
    qs = q * scale

    kb = jnp.moveaxis(k.reshape(b, nblk, block_kv, *k.shape[2:]), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, block_kv, *v.shape[2:]), 1, 0)
    pb = jnp.moveaxis(kv_pos.reshape(b, nblk, block_kv), 1, 0)

    acc0 = jnp.zeros(q.shape, jnp.float32)
    m0 = jnp.full(q.shape[:-1], NEG_INF, jnp.float32)
    l0 = jnp.zeros(q.shape[:-1], jnp.float32)

    def step(carry, blk):
        acc, m, l = carry
        kc, vc, pc = blk
        s = _gqa_scores(qs, kc)                              # (B,Kv,G,Sq,Bk)
        s = s + _mask(q_pos, pc, window)[:, None, None]
        s = jnp.moveaxis(s, 3, 1)                            # (B,Sq,Kv,G,Bk)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bskgt,btkh->bskgh", p.astype(vc.dtype), vc)
        acc_new = acc * alpha[..., None] + upd.astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    (acc, _, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kb, vb, pb))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def _run(q, k, v, q_pos, kv_pos, window, impl):
    sq, skv = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "naive" if sq * skv <= 1024 * 1024 else "blockwise"
    if impl == "flash":
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, k, v, q_pos, kv_pos, window=window)
    if impl == "blockwise":
        return _blockwise(q, k, v, q_pos, kv_pos, window)
    return _naive(q, k, v, q_pos, kv_pos, window)


def attend(params, x, positions, cfg, *, window: int = 0, impl: str = "auto",
           kv_cache=None, cache_len=None):
    """Unified attention.

    - full/prefill: kv_cache None — self-attention over x; if x is a prefill
      segment, the produced K/V are written into a fresh cache by the caller
      via `fill_cache`. Returns (out, (k, v)).
    - decode: kv_cache = ring buffer dict; positions (B, Sq) absolute.
      Returns (out, new_cache).
    """
    b, sq, _ = x.shape
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    group = cfg.num_heads // kvh

    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(b, sq, kvh, group, hd)
    # context-parallel hooks: "cp_seq"/"kv_full" are absent from the default
    # rules (-> UNCONSTRAINED no-ops); the cp strategy defines them so q
    # stays seq-sharded while K/V replicate over the model axis — the
    # TP-equivalent for head counts indivisible by |model| (DESIGN.md §4).
    q = logical_constraint(q, (None, "cp_seq", None, None, None))
    k = logical_constraint(k, (None, "kv_full", None, None))
    v = logical_constraint(v, (None, "kv_full", None, None))

    if kv_cache is None or sq > 1:
        # train / prefill: attend over the segment's own K/V (head-sharded);
        # the cache (seq-sharded ring) is written out-of-band so no
        # head<->seq reshard lands in the attention hot path.
        o = _run(q, k, v, positions, positions, window, impl)
        out = jnp.einsum("bsnh,nhd->bsd",
                         o.reshape(b, sq, cfg.num_heads, hd).astype(x.dtype),
                         params["wo"])
        new_cache = (fill_cache(kv_cache, k, v, positions)
                     if kv_cache is not None else (k, v))
        return out, new_cache

    new_cache = fill_cache(kv_cache, k, v, positions)
    if impl == "flash":
        # one-token decode goes to the split-K Pallas kernel (ring-buffer
        # aware via the stored-pos plane); the cache is already in the
        # kernel's layout so nothing is transposed or copied here.
        from repro.kernels.decode_attention import ops as dec_ops
        o = dec_ops.decode_attention(
            q[:, 0], new_cache["k"], new_cache["v"], positions[:, 0],
            new_cache["pos"], window=window)[:, None]   # (B,1,KV,G,H)
        out = jnp.einsum("bsnh,nhd->bsd",
                         o.reshape(b, sq, cfg.num_heads, hd).astype(x.dtype),
                         params["wo"])
        return out, new_cache
    o = _run(q, jnp.swapaxes(new_cache["k"], 1, 2),
             jnp.swapaxes(new_cache["v"], 1, 2), positions, new_cache["pos"],
             window, impl)
    out = jnp.einsum("bsnh,nhd->bsd",
                     o.reshape(b, sq, cfg.num_heads, hd).astype(x.dtype),
                     params["wo"])
    return out, new_cache


def fill_cache(cache, k, v, positions):
    """Write K/V at ring slots position %% size (last-size slice if the
    segment is longer than the ring).

    k/v arrive in model layout (B, Sq, KVH, D) — only this new segment is
    transposed into the cache's kernel-native (B, KVH, S, D) layout; the
    resident ring is scattered into, never rewritten."""
    size = cache["k"].shape[2]
    if k.shape[1] > size:
        k, v, positions = k[:, -size:], v[:, -size:], positions[:, -size:]
    b, kvh = k.shape[0], k.shape[2]
    slots = positions % size                     # (B, Sq)
    bidx = jnp.arange(b)[:, None, None]          # (B, 1, 1)
    hidx = jnp.arange(kvh)[None, :, None]        # (1, KVH, 1)
    sidx = slots[:, None, :]                     # (B, 1, Sq)
    return {
        "k": cache["k"].at[bidx, hidx, sidx].set(jnp.swapaxes(k, 1, 2)),
        "v": cache["v"].at[bidx, hidx, sidx].set(jnp.swapaxes(v, 1, 2)),
        "pos": cache["pos"].at[jnp.arange(b)[:, None], slots].set(positions),
    }


def init_cache(cfg, batch: int, size: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.num_kv_heads, size, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((batch, size), INF_POS, jnp.int32)}


CACHE_AXES = {"k": ("batch", "kv_heads", "kv_seq", "head_dim"),
              "v": ("batch", "kv_heads", "kv_seq", "head_dim"),
              "pos": ("batch", "kv_seq")}
