"""Griffin recurrent block: gated branch x (conv -> RG-LRU) branch
(arXiv:2402.19427, RecurrentGemma).

The RG-LRU recurrence h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t) with
a_t = sigma(Lambda)^(c * r_t) is evaluated with jax.lax.associative_scan in
log-space for train/prefill and as an O(1) update for decode.

Deviation noted in DESIGN.md: the gate projections (W_r, W_i) are full dense
rather than RecurrentGemma's block-diagonal — same shapes/compute class.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, zeros_init

C_EXP = 8.0


def init(key, cfg, dtype):
    w = cfg.resolved_lru_width
    kx, kg, kr, ki, ka, kc, ko = jax.random.split(key, 7)
    # Lambda init so that a ~ U[0.9, 0.999]^(1/c) region (Griffin appendix)
    u = jax.random.uniform(ka, (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** 2 / (1 - u ** 2)) / 2.0
    return {
        "proj_x": dense_init(kx, (cfg.d_model, w), ("embed", "lru"), dtype),
        "proj_gate": dense_init(kg, (cfg.d_model, w), ("embed", "lru"), dtype),
        "w_r": dense_init(kr, (w, w), ("lru", "lru_gate"), dtype),
        "b_r": zeros_init((w,), ("lru_gate",), jnp.float32),
        "w_i": dense_init(ki, (w, w), ("lru", "lru_gate"), dtype),
        "b_i": zeros_init((w,), ("lru_gate",), jnp.float32),
        "lam": (lam, ("lru",)),
        "conv_w": dense_init(kc, (cfg.ssm_conv, w), ("conv_k", "lru"), dtype,
                             scale=0.5),
        "out_proj": dense_init(ko, (w, cfg.d_model), ("lru", "embed"), dtype),
    }


def _causal_conv(x, w, tail=None):
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return y, xp[:, -(k - 1):]


def _gates(params, x):
    """log_a (B,S,W) fp32, gated input (B,S,W) fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xf,
                                  params["w_r"].astype(jnp.float32))
                       + params["b_r"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xf,
                                  params["w_i"].astype(jnp.float32))
                       + params["b_i"])
    log_a = -C_EXP * r * jax.nn.softplus(params["lam"])   # log sigma(lam)^(c r)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * i * xf


def _scan(log_a, b, h0=None):
    """Associative scan of h_t = exp(log_a_t) h_{t-1} + b_t along axis 1."""
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(left, right):
        la, ba = left
        lb, bb = right
        return la + lb, ba * jnp.exp(lb) + bb

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def apply(params, x, cfg, state=None):
    """Griffin recurrent block. x: (B, S, D) -> (out, new_state)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["proj_gate"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["proj_x"])
    tail = state["conv"] if state is not None else None
    u, new_tail = _causal_conv(u, params["conv_w"], tail)
    log_a, b = _gates(params, u)
    h0 = state["h"] if state is not None else None
    h = _scan(log_a, b, h0)
    y = (h.astype(x.dtype)) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out_proj"])
    return out, {"h": h[:, -1], "conv": new_tail}


def decode_step(params, x, cfg, state):
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["proj_gate"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["proj_x"])
    u, new_tail = _causal_conv(u, params["conv_w"], state["conv"])
    log_a, b = _gates(params, u)
    h = jnp.exp(log_a[:, 0]) * state["h"] + b[:, 0]
    y = h[:, None].astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out_proj"])
    return out, {"h": h, "conv": new_tail}


def init_state(cfg, batch: int, dtype):
    w = cfg.resolved_lru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, w), dtype)}


STATE_AXES = {"h": ("batch", "lru"), "conv": ("batch", "conv_k", "lru")}
