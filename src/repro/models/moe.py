"""Top-k routed mixture-of-experts FFN (GShard-style fixed capacity).

Dispatch is index-based (gather -> expert GEMM -> scatter-add) rather than
one-hot-matmul based, so no (tokens, experts, capacity) dispatch tensor is
ever materialized; capacity overflow drops tokens (they pass through the
residual only), underflow pads with zero-weight slots.

Routing modes:
- softmax top-k with renormalization (Mixtral) + Switch-style aux loss.
- aux-loss-free: sigmoid scores + a selection-only bias updated outside the
  gradient from expert load (DeepSeek-V3 / Moonlight style) — see
  `bias_update` and its use in repro.train.step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, zeros_init


def init(key, cfg, dtype):
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(kr, (d, e), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": dense_init(kg, (e, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "w_up": dense_init(ku, (e, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "w_down": dense_init(kd, (e, f, d), ("experts", "expert_mlp", "embed"), dtype),
    }
    if cfg.aux_free_bias:
        p["router_bias"] = zeros_init((e,), ("experts",), jnp.float32)
    return p


def capacity(cfg, seq_len: int) -> int:
    c = math.ceil(seq_len * cfg.experts_per_token / cfg.num_experts
                  * cfg.moe_capacity_factor)
    return max(cfg.experts_per_token, min(c, seq_len))


def _route(params, x, cfg):
    """x: (S, D) -> top-k (idx (S,k), weights (S,k) fp32, probs (S,E))."""
    logits = jnp.einsum("sd,de->se", x.astype(jnp.float32), params["router"])
    k = cfg.experts_per_token
    if cfg.aux_free_bias:
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, :]
        _, idx = jax.lax.top_k(sel, k)
        w = jnp.take_along_axis(scores, idx, axis=1)
        w = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, 1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
    return idx, w, probs


def _dispatch_indices(idx, w, num_experts: int, cap: int):
    """Build (E, C) token indices + weights from per-token top-k choices.

    idx/w: (S, k). Returns token_for (E, C) int32 (0 where empty),
    weight_for (E, C) fp32 (0 where empty/dropped).
    """
    s, k = idx.shape
    flat_e = idx.reshape(-1)                       # (S*k,) expert ids
    flat_w = w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    # rank of each slot within its expert = #earlier slots with same expert
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (S*k, E)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                               flat_e[:, None], axis=1)[:, 0]
    keep = rank < cap
    dest = flat_e * cap + jnp.where(keep, rank, cap * num_experts)  # OOB drops
    token_for = jnp.zeros(num_experts * cap + 1, jnp.int32).at[dest].set(
        flat_t, mode="drop")[:-1].reshape(num_experts, cap)
    weight_for = jnp.zeros(num_experts * cap + 1, jnp.float32).at[dest].set(
        jnp.where(keep, flat_w, 0.0), mode="drop")[:-1].reshape(num_experts, cap)
    return token_for, weight_for


def _apply_row(params, x, cfg, cap):
    """x: (S, D) single batch row."""
    idx, w, probs = _route(params, x, cfg)
    token_for, weight_for = _dispatch_indices(idx, w, cfg.num_experts, cap)
    xe = x[token_for]                                        # (E, C, D) gather
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = y * weight_for[..., None].astype(y.dtype)
    out = jnp.zeros_like(x).at[token_for.reshape(-1)].add(
        y.reshape(-1, x.shape[-1]))
    # routing stats for aux loss / bias update
    load = jnp.mean(jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32),
                    axis=(0, 1))                             # fraction routed
    importance = jnp.mean(probs, axis=0)
    return out, (load, importance)


def apply(params, x, cfg):
    """x: (B, S, D) -> (out, aux) with aux = dict(load, importance, aux_loss)."""
    cap = capacity(cfg, x.shape[1])
    out, (load, imp) = jax.vmap(
        lambda row: _apply_row(params, row, cfg, cap))(x)
    load, imp = jnp.mean(load, 0), jnp.mean(imp, 0)
    # Switch-style load-balance loss: E * sum(load * importance)
    aux_loss = cfg.num_experts * jnp.sum(load * imp)
    return out, {"load": load, "importance": imp, "aux_loss": aux_loss}


def bias_update(router_bias, load, rate: float = 1e-3):
    """Aux-loss-free balancing: nudge selection bias against overloaded
    experts (applied outside the gradient, see repro.train.step)."""
    err = jnp.mean(load) - load           # positive for underloaded experts
    return router_bias + rate * jnp.sign(err)
