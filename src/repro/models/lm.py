"""Causal LM assembly: embeddings -> block stack (lax.scan) -> head.

Stacks are scanned over layers so HLO size is O(1 layer) even for
llama3-405b's 126 layers; patterned stacks (recurrentgemma's R,R,A cycle)
scan over pattern groups with an unrolled tail. Caches thread through the
scan as per-layer xs/ys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.models import blocks
from repro.models.common import (axes_str, dense_init, dtype_of,
                                 map_axes_tree, rms_norm, split_tree,
                                 zeros_init)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _prepend_axis(axes_tree, name: str):
    return jax.tree.map(
        lambda s: axes_str([name] + [n or "_" for n in
                                     (s.split() if s != "_scalar_" else [])]),
        axes_tree)


def _stacked_block_init(key, cfg, kind: str, n: int, dtype):
    """n same-kind blocks with stacked (n, ...) params. Returns (params, axes)."""
    keys = jax.random.split(key, n)
    captured = {}

    def params_only(k):
        p, a = split_tree(blocks.block_init(k, cfg, kind, dtype))
        captured["axes"] = a          # static; recorded during tracing
        return p

    jax.eval_shape(params_only, keys[0])
    stacked = jax.vmap(params_only)(keys)
    return stacked, _prepend_axis(captured["axes"], "layers")


def init(key, cfg):
    """Returns (params, axes) twin pytrees (axes leaves are strings)."""
    dtype = dtype_of(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    p = len(cfg.block_pattern)
    n_groups, tail = divmod(cfg.num_layers, p)

    pa = {}
    ax = {}
    if cfg.input_mode == "tokens":
        pa["embed"], ax["embed"] = dense_init(
            k_embed, (cfg.vocab_size, cfg.d_model), None, dtype, scale=0.02)
        ax["embed"] = axes_str(("vocab", "embed"))
    if not cfg.tie_embeddings:
        pa["lm_head"], ax["lm_head"] = dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), None, dtype)
        ax["lm_head"] = axes_str(("embed", "vocab"))
    pa["final_norm"], _ = zeros_init((cfg.d_model,), None, jnp.float32)
    ax["final_norm"] = axes_str(("embed",))

    bkeys = jax.random.split(k_blocks, p + max(tail, 1))
    groups, gaxes = [], []
    for i, kind in enumerate(cfg.block_pattern):
        g, a = _stacked_block_init(bkeys[i], cfg, kind, n_groups, dtype)
        groups.append(g)
        gaxes.append(a)
    pa["groups"], ax["groups"] = tuple(groups), tuple(gaxes)
    tails, taxes = [], []
    for j in range(tail):
        kind = cfg.block_pattern[j]
        t = blocks.block_init(bkeys[p + j], cfg, kind, dtype)
        tp, ta = split_tree(t)
        tails.append(tp)
        taxes.append(ta)
    pa["tail"], ax["tail"] = tuple(tails), tuple(taxes)
    return pa, ax


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int, dtype=None):
    """Decode caches mirroring the block structure. Returns (caches, axes)."""
    dtype = dtype or dtype_of(cfg.dtype)
    p = len(cfg.block_pattern)
    n_groups, tail = divmod(cfg.num_layers, p)

    def one(kind):
        c = blocks.block_cache_init(cfg, kind, batch, max_len, dtype)
        a = map_axes_tree(blocks.block_cache_axes(kind))
        return c, a

    groups, gaxes = [], []
    for kind in cfg.block_pattern:
        c, a = one(kind)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), c)
        groups.append(stacked)
        gaxes.append(_prepend_axis(a, "layers"))
    tails, taxes = [], []
    for j in range(tail):
        c, a = one(cfg.block_pattern[j])
        tails.append(c)
        taxes.append(a)
    return ({"groups": tuple(groups), "tail": tuple(tails)},
            {"groups": tuple(gaxes), "tail": tuple(taxes)})


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------

def _block_fn(cfg, kind, positions, decode):
    def f(x, bp, c):
        return blocks.block_apply(bp, x, positions, cfg, kind,
                                  cache=c, decode=decode)
    if cfg.remat != "none" and not decode:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        f = jax.checkpoint(f, policy=policy)
    return f


def _stack_apply(params, cfg, x, positions, caches, decode):
    p = len(cfg.block_pattern)
    fns = [_block_fn(cfg, k, positions, decode) for k in cfg.block_pattern]
    cg = caches["groups"] if caches else tuple([None] * p)
    n_groups = cfg.num_layers // p

    def body(carry, xs):
        x, aux = carry
        bps, cs = xs
        new_cs = []
        for i in range(p):
            x, nc, a = fns[i](x, bps[i], cs[i] if caches else None)
            new_cs.append(nc)
            aux = aux + a
        return (x, aux), tuple(new_cs) if caches else None

    if cfg.scan_layers and n_groups > 0:
        (x, aux), new_groups = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["groups"], cg))
    else:
        aux = jnp.zeros((), jnp.float32)
        new_parts = []
        for g in range(n_groups):
            take = jax.tree.map(lambda a: a[g], (params["groups"], cg))
            (x, aux), nc = body((x, aux), take)
            new_parts.append(nc)
        new_groups = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_parts)
                      if (caches and new_parts) else None)

    new_tail = []
    for j, tp in enumerate(params["tail"]):
        kind = cfg.block_pattern[j]
        c = caches["tail"][j] if caches else None
        x, nc, a = _block_fn(cfg, kind, positions, decode)(x, tp, c)
        new_tail.append(nc)
        aux = aux + a
    new_caches = ({"groups": new_groups, "tail": tuple(new_tail)}
                  if caches else None)
    return x, new_caches, aux


def head_logits(params, cfg, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def apply(params, cfg, inputs, positions, caches=None, decode=False,
          return_hidden=False):
    """inputs: (B, S) int tokens or (B, S, D) embeddings (per input_mode).

    Returns (logits_or_hidden, new_caches, aux_loss).
    """
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs]
    else:
        x = inputs.astype(dtype_of(cfg.dtype))
    x = logical_constraint(x, ("batch", "seq", "act_embed"))
    x, new_caches, aux = _stack_apply(params, cfg, x, positions, caches,
                                      decode)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, new_caches, aux
    return head_logits(params, cfg, x), new_caches, aux


def decode_step(params, cfg, inputs, cache_len, caches):
    """One-token decode. inputs: (B, 1) tokens or (B, 1, D) embeddings;
    cache_len: (B,) int32 tokens already in cache."""
    positions = cache_len[:, None].astype(jnp.int32)
    return apply(params, cfg, inputs, positions, caches=caches, decode=True)


def prefill(params, cfg, inputs, caches, return_hidden=False):
    """Full-segment prefill that fills decode caches."""
    b = inputs.shape[0]
    s = inputs.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return apply(params, cfg, inputs, positions, caches=caches, decode=False,
                 return_hidden=return_hidden)
