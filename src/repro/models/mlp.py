"""SwiGLU feed-forward block (LLaMA-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init(key, cfg, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(kg, (cfg.d_model, cfg.d_ff), ("embed", "mlp"), dtype),
        "w_up": dense_init(ku, (cfg.d_model, cfg.d_ff), ("embed", "mlp"), dtype),
        "w_down": dense_init(kd, (cfg.d_ff, cfg.d_model), ("mlp", "embed"), dtype),
    }


def apply(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
