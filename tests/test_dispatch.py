"""Dispatch + autotune layer tests.

- mode resolution: AUTO/PALLAS run interpret-mode Pallas off-TPU, XLA_REF
  (and the legacy use_kernel=False) run the jnp oracle.
- every registered kernel family stays bit/tolerance-parity with its
  ref.py oracle under every mode.
- the tune cache round-trips through JSON, is hit (no re-timing) on the
  second call, and feeds ops' block-size choices.
- the KV cache pytree is stored in the kernel-native layout so the decode
  step never transposes the ring (the zero-copy contract).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, tune

RNG_SEED = 1234


# --------------------------------------------------------------------------
# mode resolution
# --------------------------------------------------------------------------
def test_auto_resolves_to_interpret_pallas_off_tpu():
    r = dispatch.resolve("auto")
    if jax.default_backend() == "tpu":
        assert r.use_pallas and not r.interpret
    else:
        assert r.use_pallas and r.interpret
    assert r.tuned


def test_pallas_mode_is_untuned_pallas():
    r = dispatch.resolve(dispatch.KernelMode.PALLAS)
    assert r.use_pallas and not r.tuned


def test_xla_ref_and_legacy_use_kernel_flag():
    assert not dispatch.resolve("xla_ref").use_pallas
    assert not dispatch.resolve(None, use_kernel=False).use_pallas
    assert dispatch.resolve(None).use_pallas


def test_registry_has_all_families():
    assert set(dispatch.registered()) == {
        "scan_filter", "aggregate", "scan_aggregate", "scan_compressed",
        "group_aggregate", "flash_attention", "decode_attention",
        "ssd_chunk"}


# --------------------------------------------------------------------------
# parity: every registered op vs its oracle under all modes
# --------------------------------------------------------------------------
def _assert_close(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        g = np.asarray(g, np.float64)
        w = np.asarray(w, np.float64)
        if g.dtype.kind in "ui" and w.dtype.kind in "ui":
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", sorted(dispatch.registered()))
@pytest.mark.parametrize("mode", ["pallas", "xla_ref", "auto"])
def test_registered_op_parity(name, mode):
    op = dispatch.get(name)
    args, kwargs = op.example(np.random.default_rng(RNG_SEED))
    got = op.fn(*args, mode=mode, **kwargs)
    want = op.ref(*args, **kwargs)
    _assert_close(got, want)


# --------------------------------------------------------------------------
# tune cache
# --------------------------------------------------------------------------
def test_tune_cache_json_roundtrip_and_second_call_hit(tmp_path):
    tune.set_cache_path(tmp_path / "tune.json")
    try:
        calls = []

        def bench(params):
            calls.append(params["block_rows"])
            return {64: 0.9, 128: 0.1, 256: 0.5}[params["block_rows"]]

        # autotune times every candidate once (plus warmup) and persists
        entry = tune.autotune("fake_op", "rows=1024",
                              {"block_rows": (64, 128, 256)}, bench,
                              repeat=1)
        assert entry["params"]["block_rows"] in (64, 128, 256)
        assert len(entry["sweep"]) == 3
        n_first = len(calls)
        assert n_first == 6          # 3 candidates x (warm + 1 timed)

        # on-disk JSON, keyed by op|backend|shape
        raw = json.loads((tmp_path / "tune.json").read_text())
        key = f"fake_op|{jax.default_backend()}|rows=1024"
        assert raw[key]["params"] == entry["params"]

        # second call is a pure cache hit: no bench invocations
        again = tune.autotune("fake_op", "rows=1024",
                              {"block_rows": (64, 128, 256)}, bench)
        assert again["params"] == entry["params"]
        assert len(calls) == n_first

        # a fresh TuneCache instance reads the same file (JSON round-trip)
        tune.set_cache_path(tmp_path / "tune.json")
        assert tune.best_params("fake_op", "rows=1024",
                                {"block_rows": 999}) == entry["params"]
    finally:
        tune.set_cache_path(None)    # back to the default cache file


def test_ops_consult_tuned_block_sizes(tmp_path):
    """A cached winner changes the block size scan_filter actually uses."""
    from repro.kernels.scan_filter import kernel as K
    from repro.kernels.scan_filter import ops as scan_ops
    from repro.kernels.scan_filter import ref as scan_ref

    cache = tune.set_cache_path(tmp_path / "tune.json")
    try:
        codes = np.random.default_rng(0).integers(0, 128, 4096)
        packed = jnp.asarray(scan_ref.pack(codes, 8))
        rows = -(-packed.shape[0] // K.LANES)
        cache.store("scan_filter", tune.shape_key(rows=rows, bits=8),
                    {"params": {"block_rows": 4}, "us": 1.0})
        got = scan_ops.scan_filter(packed, 64, "lt", 8, mode="auto")
        want = scan_ref.scan_ref(packed, 64, "lt", 8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert scan_ops._block_rows(rows, 8, tuned=True) == 4
        # PALLAS mode ignores the tune cache
        assert scan_ops._block_rows(rows, 8, tuned=False) \
            == min(K.DEFAULT_BLOCK_ROWS, rows)
    finally:
        tune.set_cache_path(None)


def test_tune_fit_clamps_to_divisor():
    assert tune.fit(1024, 4096) == 1024
    assert tune.fit(96, 64) == 48
    assert tune.fit(7, 4) == 1


def test_corrupt_cache_file_is_a_miss_not_a_crash(tmp_path):
    """A truncated/garbage cache file (e.g. a killed bench run under the
    old non-atomic writer) must behave like an empty cache: best_params
    falls back to defaults, autotune re-sweeps and rewrites valid JSON."""
    path = tmp_path / "tune.json"
    path.write_text('{"scan_filter|cpu|rows=8": {"params": {"block')
    tune.set_cache_path(path)
    try:
        assert tune.best_params("scan_filter", "rows=8",
                                {"block_rows": 77}) == {"block_rows": 77}
        entry = tune.autotune("fake_op", "rows=8", {"block_rows": (4, 8)},
                              lambda p: None, repeat=1)
        assert entry["params"]["block_rows"] in (4, 8)
        raw = json.loads(path.read_text())      # valid JSON again
        assert f"fake_op|{jax.default_backend()}|rows=8" in raw
    finally:
        tune.set_cache_path(None)


def test_store_leaves_no_temp_files(tmp_path):
    """Atomic write discipline: after store() only the cache file remains
    (unique temp + os.replace, so concurrent writers can't interleave)."""
    cache = tune.set_cache_path(tmp_path / "tune.json")
    try:
        cache.store("op", "rows=1", {"params": {"b": 1}, "us": 1.0})
        assert [p.name for p in tmp_path.iterdir()] == ["tune.json"]
    finally:
        tune.set_cache_path(None)


def test_repro_tune_cache_env_override_roundtrip(tmp_path, monkeypatch):
    """REPRO_TUNE_CACHE redirects the cache file: entries stored under the
    override land at that path and are read back by a fresh cache object
    (the documented TPU-retune workflow)."""
    override = tmp_path / "elsewhere" / "tpu_tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(override))
    try:
        assert tune.cache_path() == override
        cache = tune.set_cache_path(None)       # re-resolve from the env
        assert cache.path == override
        cache.store("op", "rows=2", {"params": {"b": 2}, "us": 1.0})
        assert override.exists()
        fresh = tune.TuneCache()                # new object, same env
        assert fresh.lookup("op", "rows=2")["params"] == {"b": 2}
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        assert tune.cache_path() != override    # back to the default
    finally:
        tune.set_cache_path(None)


# --------------------------------------------------------------------------
# ragged shapes: the scan/aggregate kernels pad instead of asserting
# --------------------------------------------------------------------------
@pytest.mark.parametrize("rows", [1, 3, 37, 130])
def test_scan_packed_arbitrary_rows(rows):
    from repro.kernels.scan_filter import kernel as K
    from repro.kernels.scan_filter import ref as scan_ref

    codes = np.random.default_rng(rows).integers(0, 128, rows * 128 * 4)
    packed = scan_ref.pack(codes, 8)
    w2d = jnp.asarray(packed).reshape(rows, K.LANES)
    out = K.scan_packed(w2d, 64, op="ge", code_bits=8, block_rows=32,
                        interpret=True)
    assert out.shape == w2d.shape
    want = scan_ref.scan_ref(packed, 64, "ge", 8)
    np.testing.assert_array_equal(np.asarray(out).reshape(-1),
                                  np.asarray(want))


# --------------------------------------------------------------------------
# zero-copy decode contract
# --------------------------------------------------------------------------
def test_kv_cache_is_kernel_native_layout():
    """The ring cache pytree must already be in the decode kernel's
    (B, KVH, S, D) layout — no swapaxes/reshape on the decode hot path."""
    from repro.configs import get_config
    from repro.models import attention

    cfg = get_config("internlm2-1.8b").reduced(dtype="float32",
                                               num_layers=2)
    b, s = 3, 32
    cache = attention.init_cache(cfg, b, s, jnp.float32)
    hd = cfg.resolved_head_dim
    assert cache["k"].shape == (b, cfg.num_kv_heads, s, hd)
    assert cache["v"].shape == (b, cfg.num_kv_heads, s, hd)
    assert cache["pos"].shape == (b, s)
    assert attention.CACHE_AXES["k"] == ("batch", "kv_heads", "kv_seq",
                                         "head_dim")
    # and the kernel consumes it without transposing: the reshape in
    # decode_attention_fwd merges leading axes only (a view), asserted by
    # feeding the cache layout straight through the public op.
    from repro.kernels.decode_attention import ops as dec_ops
    from repro.kernels.decode_attention import ref as dec_ref
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, cfg.num_kv_heads,
                                cfg.num_heads // cfg.num_kv_heads, hd))
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q_pos = jnp.full((b,), s - 1, jnp.int32)
    k = jax.random.normal(key, cache["k"].shape)
    v = jax.random.normal(key, cache["v"].shape)
    got = dec_ops.decode_attention(q, k, v, q_pos, kv_pos)
    want = dec_ref.decode_ref(q, k, v, q_pos, kv_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_no_private_interpret_probes_remain():
    """Dispatch is the only module allowed to probe the backend."""
    import pathlib

    import repro.kernels as kernels_pkg
    root = pathlib.Path(kernels_pkg.__file__).parent
    offenders = [p for p in root.rglob("*.py")
                 if p.name != "dispatch.py" and "_interpret" in p.read_text()]
    assert offenders == [], offenders
