"""Data pipeline determinism + DB query correctness."""
import numpy as np

from repro.data import DataConfig, SyntheticLM
from repro.db import Predicate, Table, scan_aggregate_query
from repro.db.queries import bytes_scanned, scan_query
from repro.kernels.scan_filter.ref import unpack_mask


class TestPipeline:
    def test_restart_bitwise_reproducible(self):
        ds = SyntheticLM(DataConfig(seed=7, global_batch=4, seq_len=32))
        a = ds.batch(10)
        b = ds.batch(10)     # "restarted" pipeline at the same step
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
        assert not np.array_equal(ds.batch(11)["inputs"], a["inputs"])

    def test_labels_are_shifted_inputs(self):
        ds = SyntheticLM(DataConfig(global_batch=2, seq_len=16))
        b = ds.batch(0)
        np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_batch(self):
        ds = SyntheticLM(DataConfig(global_batch=8, seq_len=8))
        full = ds.batch(3)
        parts = [ds.local_batch(3, process_index=i, process_count=4)
                 for i in range(4)]
        got = np.concatenate([p["inputs"] for p in parts])
        np.testing.assert_array_equal(got, full["inputs"])

    def test_embeddings_mode(self):
        ds = SyntheticLM(DataConfig(global_batch=2, seq_len=8, embed_dim=16))
        b = ds.batch(0)
        assert b["inputs"].shape == (2, 8, 16)
        assert b["labels"].shape == (2, 8)

    def test_vocab_bound(self):
        ds = SyntheticLM(DataConfig(global_batch=4, seq_len=64,
                                    vocab_size=100))
        for s in range(3):
            assert ds.batch(s)["inputs"].max() < 100

    def test_prefetcher(self):
        from repro.data.pipeline import Prefetcher
        ds = SyntheticLM(DataConfig(global_batch=2, seq_len=8))
        pf = Prefetcher(ds, start_step=0, depth=2)
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        pf.close()
        assert (s0, s1) == (0, 1)
        np.testing.assert_array_equal(b0["inputs"], ds.local_batch(0)["inputs"])


class TestQueries:
    def setup_method(self):
        self.t = Table.synthetic("t", 10_000, {"a": 8, "b": 8, "c": 16},
                                 seed=3)
        self.av = self.t.columns["a"].decode()
        self.bv = self.t.columns["b"].decode()

    def test_single_predicate(self):
        mask = scan_query(self.t, [Predicate("a", "lt", 50)])
        sel = np.asarray(unpack_mask(mask, 8))[:self.t.num_rows]
        np.testing.assert_array_equal(sel, self.av < 50)

    def test_conjunction(self):
        r = scan_aggregate_query(
            self.t, [Predicate("a", "lt", 50), Predicate("b", "ge", 100)],
            agg_column="b")
        sel = (self.av < 50) & (self.bv >= 100)
        assert int(r["count"]) == int(sel.sum())
        assert int(r["sum"]) == int(self.bv[sel].sum())
        if sel.any():
            assert int(r["min"]) == int(self.bv[sel].min())
            assert int(r["max"]) == int(self.bv[sel].max())

    def test_bytes_scanned(self):
        n = bytes_scanned(self.t, [Predicate("a", "lt", 10)], "b")
        assert n == self.t.columns["a"].nbytes + self.t.columns["b"].nbytes

    def test_kernel_and_ref_paths_agree(self):
        for mode in ("pallas", "xla_ref", "auto"):
            r = scan_aggregate_query(self.t, [Predicate("a", "ge", 64)],
                                     "a", mode=mode)
            sel = self.av >= 64
            assert int(r["count"]) == int(sel.sum())
