"""Pallas kernel validation: interpret-mode sweeps vs pure-jnp oracles.

Every kernel is swept over shapes/dtypes and assert_allclose'd against its
ref.py (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.aggregate import ref as agg_ref
from repro.kernels.decode_attention import ops as dec_ops
from repro.kernels.decode_attention import ref as dec_ref
from repro.kernels.flash_attention import kernel as flash_kernel
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention import ref as flash_ref
from repro.kernels.scan_filter import ops as scan_ops
from repro.kernels.scan_filter import ref as scan_ref

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------------
# scan_filter
# --------------------------------------------------------------------------
@pytest.mark.parametrize("code_bits", [4, 8, 16])
@pytest.mark.parametrize("op", scan_ref.OPS)
def test_scan_filter_matches_ref(code_bits, op):
    vmax = (1 << (code_bits - 1)) - 1
    codes = RNG.integers(0, vmax + 1, 4096)
    packed = scan_ref.pack(codes, code_bits)
    for const in (0, 1, vmax // 3, vmax - 1, vmax):
        got = scan_ops.scan_filter(packed, const, op, code_bits)
        want = scan_ref.scan_ref(packed, const, op, code_bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{code_bits}b {op} c={const}")


@pytest.mark.parametrize("n", [128, 129, 1000, 8192])
def test_scan_filter_ragged_lengths(n):
    code_bits = 8
    codes = RNG.integers(0, 128, n)
    packed = scan_ref.pack(codes, code_bits)
    got = scan_ops.scan_filter(packed, 64, "lt", code_bits)
    want = scan_ref.scan_ref(packed, 64, "lt", code_bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scan_filter_semantics_vs_plain_numpy():
    code_bits = 8
    codes = RNG.integers(0, 128, 2048)
    packed = scan_ref.pack(codes, code_bits)
    mask = scan_ops.scan_filter(packed, 40, "lt", code_bits)
    sel = np.asarray(scan_ref.unpack_mask(mask, code_bits))[:len(codes)]
    np.testing.assert_array_equal(sel, codes < 40)


# --------------------------------------------------------------------------
# aggregate
# --------------------------------------------------------------------------
@pytest.mark.parametrize("code_bits", [4, 8, 16])
@pytest.mark.parametrize("selectivity", [0.0, 0.3, 1.0])
def test_aggregate_matches_ref(code_bits, selectivity):
    vmax = (1 << (code_bits - 1)) - 1
    codes = RNG.integers(0, vmax + 1, 6000)
    packed = scan_ref.pack(codes, code_bits)
    const = int(vmax * selectivity)
    mask = scan_ref.scan_ref(packed, const, "lt", code_bits)
    got = agg_ops.aggregate(packed, mask, code_bits)
    want = agg_ref.aggregate_ref(packed, mask, code_bits)
    for key in ("sum_lo", "sum_hi", "count", "min", "max"):
        assert int(got[key]) == int(want[key]), (key, code_bits, selectivity)
    # cross-check against plain numpy on the unpacked values
    sel = codes < const
    fin = agg_ops.finalize(got)
    assert fin["count"] == int(sel.sum())
    assert fin["sum"] == int(codes[sel].sum())


def test_aggregate_sum_exact_beyond_int32():
    """300k selected rows of a 16-bit column sum past 2^31; the 16-bit
    sum planes must stay exact where a single int32 accumulator wraps."""
    n = 300_000
    codes = RNG.integers(0, 1 << 15, n)
    packed = scan_ref.pack(codes, 16)
    mask = scan_ref.scan_ref(packed, 0, "ge", 16)    # select everything
    want = int(codes.astype(np.int64).sum())
    assert want > 2**31                              # the case that wrapped
    for mode in ("pallas", "xla_ref"):
        fin = agg_ops.finalize(agg_ops.aggregate(packed, mask, 16,
                                                 mode=mode))
        assert fin["sum"] == want, mode
        assert fin["count"] == n


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kvh,g,sq,skv,d", [
    (1, 1, 1, 128, 128, 128),
    (2, 2, 4, 128, 256, 128),     # GQA group 4, rectangular
    (1, 2, 1, 256, 256, 64),
    (2, 1, 2, 384, 384, 128),
])
def test_flash_matches_ref(dtype, b, kvh, g, sq, skv, d):
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, kvh, g, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, kvh, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv_, (b, kvh, skv, d), jnp.float32).astype(dtype)
    got = flash_kernel.flash_attention_fwd(q, k, v, interpret=True)
    want = flash_ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [32, 128, 1024])
def test_flash_sliding_window(window):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 2, 2, 256, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
    got = flash_kernel.flash_attention_fwd(q, k, v, window=window,
                                           interpret=True)
    want = flash_ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_shape_independence():
    """Different BlockSpec tilings must give the same answer."""
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 1, 2, 256, 128), jnp.float32)
    k = jax.random.normal(key, (1, 1, 256, 128), jnp.float32)
    v = jax.random.normal(key, (1, 1, 256, 128), jnp.float32)
    a = flash_kernel.flash_attention_fwd(q, k, v, bq=128, bk=128,
                                         interpret=True)
    b = flash_kernel.flash_attention_fwd(q, k, v, bq=64, bk=256,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_flash_gradients_flow():
    """custom_vjp: kernel forward + reference backward."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 1, 128, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1, 128, 64), jnp.float32)
    v = jax.random.normal(key, (1, 1, 128, 64), jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(flash_ops.flash5(q, k, v, 0) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(flash_ref.attention_ref(q, k, v) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kvh,g,s,d", [
    (2, 2, 2, 512, 128),
    (1, 1, 8, 1024, 64),
    (4, 2, 1, 2048, 128),
])
def test_decode_matches_ref(dtype, b, kvh, g, s, d):
    key = jax.random.PRNGKey(0)
    kq, kk, kv_, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, kvh, g, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, kvh, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv_, (b, kvh, s, d), jnp.float32).astype(dtype)
    fill = int(0.75 * s)
    kv_pos = jnp.where(jnp.arange(s)[None, :] < fill,
                       jnp.arange(s)[None, :], 1 << 30)
    kv_pos = jnp.broadcast_to(kv_pos, (b, s))
    q_pos = jnp.full((b,), fill, jnp.int32)
    got = dec_ops.decode_attention(q, k, v, q_pos, kv_pos)
    want = dec_ref.decode_ref(q, k, v, q_pos, kv_pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [64, 512])
def test_decode_sliding_window_ring(window):
    """Ring-buffer semantics: positions wrap, window masks stale slots."""
    b, kvh, g, s, d = 1, 1, 2, 256, 64
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (b, kvh, g, d), jnp.float32)
    k = jax.random.normal(key, (b, kvh, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kvh, s, d), jnp.float32)
    # cache holds positions 300-555 in ring layout (wrapped)
    abs_pos = jnp.arange(300, 300 + s)
    slots = abs_pos % s
    kv_pos = jnp.zeros((b, s), jnp.int32).at[0, slots].set(abs_pos)
    q_pos = jnp.full((b,), 556, jnp.int32)
    got = dec_ops.decode_attention(q, k, v, q_pos, kv_pos, window=window)
    want = dec_ref.decode_ref(q, k, v, q_pos, kv_pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_split_sizes_agree():
    b, kvh, g, s, d = 1, 2, 2, 1024, 128
    key = jax.random.PRNGKey(6)
    q = jax.random.normal(key, (b, kvh, g, d), jnp.float32)
    k = jax.random.normal(key, (b, kvh, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kvh, s, d), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q_pos = jnp.full((b,), s - 1, jnp.int32)
    a = dec_ops.decode_attention(q, k, v, q_pos, kv_pos, bk=256)
    c = dec_ops.decode_attention(q, k, v, q_pos, kv_pos, bk=1024)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=1e-5, atol=1e-5)
