"""Serving engine: continuous batching correctness.

The invariant: anything the engine generates (slots, refills, ring caches)
must equal naive one-request-at-a-time greedy decoding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.common import dtype_of
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced(dtype="float32", num_layers=2)
    params, _ = lm.init(KEY, cfg)
    return cfg, params


def naive_greedy(cfg, params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        x = jnp.asarray(toks, jnp.int32)[None]
        b, s = x.shape
        logits, _, _ = lm.prefill(params, cfg, x, caches=None)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_single_request_matches_naive(setup):
    cfg, params = setup
    prompt = np.array([5, 9, 2, 7], np.int32)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    [done] = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])
    assert done.generated == naive_greedy(cfg, params, prompt, 6)


def test_continuous_batching_matches_naive(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 3 + i),
                    max_new_tokens=4 + (i % 3))
            for i in range(5)]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    done = eng.run(list(reqs))
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    for r in done:
        want = naive_greedy(cfg, params, r.prompt, r.max_new_tokens)
        assert r.generated == want, (r.rid, r.generated, want)


def test_slot_reuse(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    done = eng.run([Request(rid=i, prompt=np.array([i + 1], np.int32),
                            max_new_tokens=2) for i in range(3)])
    assert len(done) == 3


def test_prefill_bucket_clamped_to_ring(setup):
    """A prompt whose pow2 bucket exceeds max_len must not wrap the ring
    (pad writes would evict real prompt K/V): bucket_len(40)=64 > 48."""
    cfg, params = setup
    prompt = (np.arange(1, 41, dtype=np.int32) % cfg.vocab_size)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    [done] = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    assert done.generated == naive_greedy(cfg, params, prompt, 4)
