"""Multi-device distribution tests.

jax locks the device count at first init, so these run in a child process
with XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests/multidevice_child.py)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

CHILD = Path(__file__).parent / "multidevice_child.py"


def run_child(which: str):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(CHILD), which],
                       capture_output=True, text=True, timeout=540, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "CHILD_DONE" in r.stdout
    return r.stdout


@pytest.mark.parametrize("which", ["pipeline", "pipeline2d", "compression",
                                   "ef", "train", "serve", "elastic",
                                   "query", "store", "resilience",
                                   "relational"])
def test_multidevice(which):
    out = run_child(which)
    assert "OK" in out
