"""Tiered-memory placement engine (repro.tier) + query-path integration.

The load-bearing guarantees:
- placement NEVER changes query answers — all three policies are bit-exact
  vs the flat-memory engine on the same trace (only latency accounting
  moves);
- adaptive policies (CACHE, MEMCACHE) strictly beat STATIC pinning's
  hit-rate on a zipfian(1.1) trace with the fast tier at 25% of the table;
- the fast-tier budget is a hard invariant;
- advise_tier_split is consistent with the Eq. 4 roofline;
- benchmarks/run.py --only tier appends a record to BENCH_tier.json.
"""
import json
import math
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.advisor import advise_tier_split
from repro.core.systems import DIE_STACKED, TRADITIONAL
from repro.db import Table
from repro.kernels import tune
from repro.query import Pred, Query, QueryEngine
from repro.serve.sla import VirtualClock, blended_bps
from repro.tier import (PlacementEngine, Policy, TieredBudget, TraceSpec,
                        make_trace, measured_fast_gbps, paper_tiers,
                        table1_bandwidth_ratio, tier_from_system,
                        zipf_hit_curve, zipf_weights)

N_COLS, N_ROWS = 16, 4096
FAST_FRACTION = 0.25
CHUNK_ROWS = 256


@pytest.fixture(scope="module")
def table():
    return Table.synthetic("tier", N_ROWS,
                           {f"c{i:02d}": 8 for i in range(N_COLS)}, seed=1)


@pytest.fixture(scope="module")
def tiers(table):
    return paper_tiers(table.nbytes * FAST_FRACTION, fast_gbps=10.0)


@pytest.fixture(scope="module")
def trace(table):
    return make_trace(table, TraceSpec(n_queries=120, skew=1.1, seed=3))


def run_trace(table, trace, policy, tiers):
    pe = PlacementEngine.for_table(table, tiers, policy,
                                   chunk_rows=CHUNK_ROWS)
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock())
    results = []
    for tq in trace:
        eng.submit(tq.query)
        results += eng.run()
        assert pe.budget.used <= pe.budget.fast_capacity + 1e-9
    return pe, eng, results


# --------------------------------------------------------------------------
# tiers: datasheet derivation + budget
# --------------------------------------------------------------------------
class TestTiers:
    def test_table1_bandwidth_ratio(self):
        # 256 GB/s HBM stack vs 4 x 25.6 GB/s DDR channels
        assert table1_bandwidth_ratio() == pytest.approx(2.5)

    def test_tier_from_system_die_stacked(self):
        t = tier_from_system(DIE_STACKED)
        assert t.bandwidth == DIE_STACKED.chip_bandwidth
        assert t.capacity == DIE_STACKED.chip_capacity
        assert t.energy_per_byte == pytest.approx(10.0 / (256 * 1e9))

    def test_paper_tiers_derates_capacity_by_ratio(self):
        p = paper_tiers(1 << 20, fast_gbps=10.0)
        assert p.fast.gbps == pytest.approx(10.0)
        assert p.capacity.gbps == pytest.approx(4.0)
        assert p.fast.capacity == 1 << 20

    def test_paper_tiers_datasheet_rates_without_measurement(self):
        p = paper_tiers(1 << 20)
        assert p.fast.bandwidth == DIE_STACKED.chip_bandwidth
        assert p.capacity.bandwidth == TRADITIONAL.chip_bandwidth

    def test_blended_is_harmonic(self):
        p = paper_tiers(1 << 20, fast_gbps=10.0)
        assert p.blended(1.0) == pytest.approx(10e9)
        assert p.blended(0.0) == pytest.approx(4e9)
        assert p.blended(0.5) == pytest.approx(1 / (.5 / 10e9 + .5 / 4e9))
        assert p.blended(0.5, chips=4) == pytest.approx(4 * p.blended(0.5))

    def test_service_time_adds_per_tier(self):
        p = paper_tiers(1 << 20, fast_gbps=10.0)
        assert p.service_s(10e9, 4e9) == pytest.approx(2.0)
        assert p.service_s(10e9, 4e9, chips=2) == pytest.approx(1.0)

    def test_as_system_is_eq4_bandwidth_bound(self):
        t = tier_from_system(DIE_STACKED)
        s = t.as_system()
        assert s.chip_peak_perf == pytest.approx(t.bandwidth)

    def test_budget_enforced(self):
        b = TieredBudget(100)
        b.alloc(60)
        assert not b.fits(50)
        with pytest.raises(ValueError, match="overflow"):
            b.alloc(50)
        b.free(30)
        b.alloc(50)
        assert b.remaining == pytest.approx(20)

    def test_budget_rejects_nonpositive_capacity(self):
        with pytest.raises(ValueError, match="positive"):
            TieredBudget(0)
        with pytest.raises(ValueError, match="positive"):
            paper_tiers(0)

    def test_blended_bps_guards_rates(self):
        with pytest.raises(ValueError, match="positive"):
            blended_bps(0.0, 4e9, 0.5)

    def test_measured_fast_gbps_reads_autotune_sweep(self, tmp_path):
        try:
            cache = tune.set_cache_path(tmp_path / "tune.json")
            assert measured_fast_gbps(default=7.5) == 7.5  # empty cache
            cache.store("scan_filter", "bits=8,rows=1024", {"us": 100.0})
            want = 1024 * 128 * 4 / 100e-6 / 1e9
            assert measured_fast_gbps() == pytest.approx(want)
            # the fused op streams three word planes (pred, agg, valid),
            # so the same us over the same rows is a 3x higher rate
            cache.store("scan_aggregate", "bits=8,rows=1024",
                        {"us": 100.0})
            assert measured_fast_gbps() == pytest.approx(3 * want)
        finally:
            tune.set_cache_path(None)


# --------------------------------------------------------------------------
# trace: seeded zipfian streams
# --------------------------------------------------------------------------
class TestTrace:
    def test_zipf_weights_normalized_decreasing(self):
        w = zipf_weights(16, 1.1)
        assert w.sum() == pytest.approx(1.0)
        assert (np.diff(w) < 0).all()

    def test_zipf_hit_curve_endpoints_and_monotone(self):
        hit = zipf_hit_curve(16, 1.1)
        assert hit(0.0) == 0.0 and hit(1.0) == 1.0
        xs = np.linspace(0, 1, 21)
        ys = [hit(x) for x in xs]
        assert (np.diff(ys) >= -1e-12).all()
        assert hit(0.25) > 0.25        # the head is hotter than uniform

    def test_trace_is_deterministic(self, table):
        spec = TraceSpec(n_queries=30, skew=1.1, seed=9)
        assert make_trace(table, spec) == make_trace(table, spec)

    def test_trace_on_two_column_table(self):
        """Regression: the documented minimum of 2 columns must not crash
        the rank draw (no compound predicates are possible there)."""
        t = Table.synthetic("two", 256, {"a": 8, "b": 8}, seed=0)
        trace = make_trace(t, TraceSpec(n_queries=20, seed=0))
        assert len(trace) == 20
        assert all(len(tq.query.aggregates) == 1 for tq in trace)

    def test_trace_queries_bind_to_table(self, table, trace):
        for tq in trace:
            assert tq.query.aggregates[0] in table.columns
            assert 0 <= tq.tenant < 4


# --------------------------------------------------------------------------
# placement: the acceptance guarantees
# --------------------------------------------------------------------------
class TestPlacement:
    def test_chunk_universe_covers_table(self, table, tiers):
        pe = PlacementEngine.for_table(table, tiers, Policy.STATIC,
                                       chunk_rows=CHUNK_ROWS)
        assert pe.total_bytes == table.nbytes
        per_col = {}
        for (c, _), i in pe.index.items():
            per_col[c] = per_col.get(c, 0) + int(pe.nbytes[i])
        assert per_col == {n: col.nbytes
                           for n, col in table.columns.items()}

    def test_static_is_pinned_once(self, table, trace, tiers):
        pe, _, _ = run_trace(table, trace[:20], Policy.STATIC, tiers)
        before = pe.in_fast.copy()
        pe.on_access({cid: int(pe.nbytes[i])
                      for cid, i in list(pe.index.items())[:8]})
        np.testing.assert_array_equal(before, pe.in_fast)

    def test_all_policies_bit_exact_vs_flat(self, table, trace, tiers):
        """Placement never changes answers, only latency."""
        flat = QueryEngine(table, mode="xla_ref")
        flat_aggs = []
        for tq in trace[:30]:
            flat.submit(tq.query)
            flat_aggs.append(flat.run()[0].aggregates)
        for policy in Policy:
            _, _, results = run_trace(table, trace[:30], policy, tiers)
            assert [r.aggregates for r in results] == flat_aggs, policy

    def test_adaptive_beats_static_hit_rate(self, table, trace, tiers):
        """zipf(1.1) trace, fast tier at 25%: CACHE and MEMCACHE strictly
        exceed STATIC's byte-weighted hit-rate."""
        hit = {p: run_trace(table, trace, p, tiers)[0].hit_rate
               for p in Policy}
        assert hit[Policy.CACHE] > hit[Policy.STATIC]
        assert hit[Policy.MEMCACHE] > hit[Policy.STATIC]

    def test_hot_columns_hint_orders_static_pinning(self, table, tiers):
        pe = PlacementEngine.for_table(table, tiers, Policy.STATIC,
                                       chunk_rows=CHUNK_ROWS,
                                       hot_columns=("c07", "c03"))
        pinned = {c for (c, _), i in pe.index.items() if pe.in_fast[i]}
        assert {"c07", "c03"} <= pinned

    def test_unknown_chunk_raises(self, table, tiers):
        pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                       chunk_rows=CHUNK_ROWS)
        with pytest.raises(ValueError, match="unknown chunk"):
            pe.on_access({("nope", 0): 4})

    def test_energy_ledger_bit_compatible_with_old_scalar(self, table,
                                                          trace, tiers):
        """Satellite regression: the meter replaced the energy_j_total
        scalar, but stats()["energy_j"] must stay bit-compatible — the
        old per-access accumulation reproduced exactly by the sum of the
        ledger's per-charge memory lines."""
        pe, _, _ = run_trace(table, trace[:30], Policy.CACHE, tiers)
        ledger = pe.meter.charges
        assert len(ledger) == 30            # one charge per query
        old_style = 0.0                     # the pre-meter accumulation
        for c in ledger:
            old_style += tiers.energy_j(c.fast_bytes, c.capacity_bytes)
        assert pe.stats()["energy_j"] == old_style          # bitwise
        assert pe.energy_j_total == sum(c.memory_j for c in ledger)
        m = pe.meter.summary()                # the canonical breakdown
        assert m["fast_j"] + m["capacity_j"] == \
            pytest.approx(pe.stats()["energy_j"])
        assert m["compute_j"] == 0.0          # no compute_w: memory only
        assert m["total_j"] == pytest.approx(pe.stats()["energy_j"])

    def test_sharded_chunk_accounting(self, table, tiers):
        """ShardedTable reports device-resident (padding-included) chunk
        bytes and runs the tiered path end-to-end."""
        from repro.launch.mesh import make_mesh
        from repro.query import ShardedTable
        st = ShardedTable.shard(table, make_mesh((1,), ("data",)))
        q = Query(Pred("c00", "lt", 64), aggregates=("c01",))
        chunks = st.chunk_bytes(q.plan(), q.aggregates, CHUNK_ROWS)
        assert sum(chunks.values()) == sum(
            int(st.slices[c].words.size) * 4 for c in ("c00", "c01"))
        pe = PlacementEngine.for_table(st, tiers, Policy.CACHE,
                                       chunk_rows=CHUNK_ROWS)
        eng = QueryEngine(st, mode="xla_ref", tiered=pe,
                          clock=VirtualClock())
        eng.submit(q)
        res = eng.run()[0]
        assert res.tier["fast_bytes"] + res.tier["capacity_bytes"] \
            == sum(chunks.values())


# --------------------------------------------------------------------------
# engine integration: tiered latency model + blended admission
# --------------------------------------------------------------------------
class TestTieredEngine:
    def test_latency_is_modeled_service(self, table, tiers):
        pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                       chunk_rows=CHUNK_ROWS)
        clk = VirtualClock()
        eng = QueryEngine(table, mode="xla_ref", tiered=pe, clock=clk)
        q = Query(Pred("c00", "lt", 64), aggregates=("c01",))
        eng.submit(q)
        res = eng.run()[0]
        # cold cache: every byte at the capacity tier's rate
        want = res.bytes_scanned / tiers.capacity.bandwidth
        assert res.tier["service_s"] == pytest.approx(want)
        assert res.latency_s == pytest.approx(want)
        assert clk() == pytest.approx(want)
        assert eng.summary()["tier"]["policy"] == "cache"

    def test_admission_uses_blended_rate(self, table, tiers):
        pe = PlacementEngine.for_table(table, tiers, Policy.STATIC,
                                       chunk_rows=CHUNK_ROWS)
        clk = VirtualClock()
        eng = QueryEngine(table, mode="xla_ref", tiered=pe, clock=clk)
        assert eng.measured_bps == pytest.approx(
            tiers.blended(pe.resident_fast_fraction))
        q = Query(Pred("c00", "lt", 64), aggregates=("c01",))
        est = eng.bytes_scanned(q) / eng.measured_bps
        assert eng.submit(q, deadline=clk() + est * 0.5) is None  # rejected
        assert eng.submit(q, deadline=clk() + est * 2.0) is not None

    def test_sharded_admission_and_charge_share_one_byte_basis(self):
        """Regression: with shard-alignment padding (mixed code widths
        force lcm-aligned rows), the admission estimate, bytes_scanned,
        and the modeled service charge must all use the same padded
        device-resident bytes — a logical-bytes estimate would admit
        queries the padded charge then deterministically misses."""
        from repro.launch.mesh import make_mesh
        from repro.query import ShardedTable, physical
        t = Table.synthetic("pad", 100, {"a": 16, "b": 2}, seed=0)
        st = ShardedTable.shard(t, make_mesh((1,), ("data",)))
        tiers = paper_tiers(st.nbytes // 4, fast_gbps=10.0)
        pe = PlacementEngine.for_table(st, tiers, Policy.STATIC,
                                       chunk_rows=16)
        clk = VirtualClock()
        eng = QueryEngine(st, mode="xla_ref", tiered=pe, clock=clk)
        q = Query(Pred("a", "lt", 64), aggregates=("b",))
        padded = sum(st.chunk_bytes(q.plan(), q.aggregates, 16).values())
        logical = physical.referenced_bytes(q.plan(), q.aggregates,
                                            t.columns)
        assert padded > logical          # the padding is real in this case
        est = padded / eng.measured_bps
        assert eng.submit(q, deadline=clk() + est * 1.05) is not None
        res = eng.run()[0]
        assert res.bytes_scanned == padded
        assert res.tier["fast_bytes"] + res.tier["capacity_bytes"] == padded
        assert res.met                   # admitted estimate was honest

    def test_tiered_requires_advanceable_clock(self, table, tiers):
        """Modeled service on a wall clock would price admission and
        deadlines on incommensurate time axes — rejected at construction."""
        pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                       chunk_rows=CHUNK_ROWS)
        with pytest.raises(ValueError, match="VirtualClock"):
            QueryEngine(table, tiered=pe)

    def test_chunk_accesses_requires_tiered(self, table):
        eng = QueryEngine(table)
        with pytest.raises(ValueError, match="tiered"):
            eng.chunk_accesses(Query(Pred("c00", "lt", 4),
                                     aggregates=("c00",)))


# --------------------------------------------------------------------------
# advisor: fast-tier fraction search vs the Eq. 4 roofline
# --------------------------------------------------------------------------
class TestAdviseTierSplit:
    def adv(self, sla_s=0.010, fast_gbps=10.0, capacity_gbps=4.0):
        return advise_tier_split(
            1 << 30, 1 << 24, sla_s, hit_curve=zipf_hit_curve(16, 1.1),
            fast_gbps=fast_gbps, capacity_gbps=capacity_gbps)

    def test_consistent_with_eq4_roofline(self):
        adv = self.adv()
        # roofline from the DIE_STACKED datasheet, Eq. 4: min(compute 6*32,
        # bandwidth 256) = 192 GB/s — independent of the measured rates
        assert adv["roofline_gbps"] == pytest.approx(192.0)
        assert adv["fast_within_roofline"]
        assert all(r["within_roofline"] for r in adv["rows"])
        assert all(r["blended_gbps"] <= adv["roofline_gbps"] * (1 + 1e-9)
                   for r in adv["rows"])
        full = adv["rows"][-1]
        assert full["fast_fraction"] == 1.0
        assert full["blended_gbps"] == pytest.approx(10.0)

    def test_roofline_flags_mismeasured_fast_rate(self):
        """A fast rate above what Eq. 4 says the die-stacked chip can
        sustain (e.g. broken byte accounting) fails the cross-check."""
        adv = self.adv(fast_gbps=500.0)
        assert not adv["fast_within_roofline"]
        assert not adv["rows"][-1]["within_roofline"]

    def test_blended_monotone_in_fraction(self):
        gbps = [r["blended_gbps"] for r in self.adv()["rows"]]
        assert (np.diff(gbps) >= -1e-12).all()

    def test_best_is_minimal_feasible_fraction(self):
        adv = self.adv(sla_s=(1 << 24) / 4e9 * 10)   # generously feasible
        assert adv["best"] == adv["rows"][0]
        # bytes/query at the full fast rate takes (1<<24)/10e9 s; no
        # fraction can beat that
        assert self.adv(sla_s=(1 << 24) / 10e9 * 0.5)["best"] is None

    def test_measured_hit_points_interpolate(self):
        adv = advise_tier_split(
            1 << 30, 1 << 24, 0.010, hit_curve={0.25: 0.6, 0.5: 0.8},
            fast_gbps=10.0, capacity_gbps=4.0)
        r = next(r for r in adv["rows"]
                 if r["fast_fraction"] == pytest.approx(0.25))
        assert r["hit_rate"] == pytest.approx(0.6)

    def test_measured_endpoint_is_not_shadowed(self):
        """Regression: a measured point at full residency must win over
        the synthetic hit(1.0)=1.0 anchor, and the curve must clamp (not
        assume perfection) beyond the last measured point."""
        adv = advise_tier_split(
            1 << 30, 1 << 24, 0.010, hit_curve={1.0: 0.5},
            fast_gbps=10.0, capacity_gbps=4.0)
        assert adv["rows"][-1]["hit_rate"] == pytest.approx(0.5)
        half = next(r for r in adv["rows"]
                    if r["fast_fraction"] == pytest.approx(0.5))
        assert half["hit_rate"] == pytest.approx(0.25)
        with pytest.raises(ValueError, match="hit_curve"):
            advise_tier_split(1, 1, 0.1, hit_curve={1.5: 0.5},
                              fast_gbps=1.0, capacity_gbps=1.0)

    def test_guards_degenerate_inputs(self):
        with pytest.raises(ValueError, match="positive"):
            self.adv(fast_gbps=0.0)
        with pytest.raises(ValueError, match="sla_s"):
            self.adv(sla_s=0.0)
        with pytest.raises(ValueError, match="positive"):
            advise_tier_split(0, 1, 0.1, hit_curve=lambda f: f,
                              fast_gbps=1.0, capacity_gbps=1.0)


# --------------------------------------------------------------------------
# bench wiring: run.py --only tier appends to BENCH_tier.json
# --------------------------------------------------------------------------
def test_tier_bench_appends_record(tmp_path, monkeypatch, capsys):
    import benchmarks.run as bench_run
    import benchmarks.tier_bench as tier_bench
    monkeypatch.setenv("REPRO_TIER_BENCH_QUICK", "1")
    monkeypatch.setattr(tier_bench, "BENCH_PATH", tmp_path / "B.json")
    bench_run.main(["--only", "tier", "--json"])
    records = json.loads(capsys.readouterr().out)
    assert any(r["name"].startswith("tier/") for r in records)
    hist = json.loads((tmp_path / "B.json").read_text())
    assert len(hist) == 1
    rec = hist[0]
    assert set(rec["policies"]) == {"static", "cache", "memcache"}
    for pol in rec["policies"].values():
        for skew_row in pol.values():
            assert 0.0 <= skew_row["hit_rate"] <= 1.0
            assert math.isfinite(skew_row["blended_gbps"])


class TestPrefetch:
    """PrefetchPipeline: overlap = max per stage (not sum), bounded
    staging budget, in-flight chunks never double-projected, stall ->
    synchronous degradation. Hand-computed against paper_tiers at
    fast=10 GB/s, capacity=4 GB/s (the 2.5x Table-1 ratio)."""

    B = 1000                           # bytes per chunk
    FAST = 10e9
    CAP = 4e9

    def _pe(self, policy=Policy.STATIC, fast_capacity=2000, pin=(0,)):
        from repro.tier import PlacementEngine
        ids = [("c", 0), ("c", 1), ("c", 2)]
        return PlacementEngine(ids, [self.B] * 3,
                               paper_tiers(fast_capacity, fast_gbps=10.0),
                               policy, chunk_rows=256,
                               pin_order=list(pin))

    def test_service_is_max_per_stage_not_sum(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()                # only chunk 0 pinned fast
        pf = PrefetchPipeline(pe, self.B)
        chunks = {("c", 0): self.B, ("c", 1): self.B, ("c", 2): self.B}
        plan = pf.plan(chunks)
        # hit c0 scans 1e-7; first miss c1 reads sync 2.5e-7 (fill);
        # c2 streams under c1's scan: service = 1e-7 + max(2.5e-7,
        # 2.5e-7) + 1e-7 = 4.5e-7, vs sync 1e-7 + 5e-7 = 6e-7
        assert plan.sync_service_s == pytest.approx(6.0e-7)
        assert plan.service_s == pytest.approx(4.5e-7)
        assert plan.used and plan.staged_bytes == self.B
        assert plan.staged_cids == (("c", 2),)
        pf.close()

    def test_pipelined_never_worse_and_identical_placement(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()
        pf = PrefetchPipeline(pe, self.B)
        chunks = {("c", 0): self.B, ("c", 1): self.B, ("c", 2): self.B}
        plan = pf.plan(chunks)
        assert plan.service_s <= plan.sync_service_s
        before = pe.in_fast.copy()
        acc = pe.on_access(chunks, qid=1, tenant=0)       # unchanged path
        assert (pe.in_fast == before).all()               # STATIC anyway
        # the nominal charge is untouched by the pipeline
        assert acc.fast_bytes == self.B
        assert acc.capacity_bytes == 2 * self.B
        line = pf.finish(plan, qid=1, tenant=0)
        assert line.kind == "prefetch"
        assert line.fast_bytes == self.B and line.capacity_bytes == 0
        assert pe.meter.prefetch_j == line.total_j
        # prefetch traffic never pollutes the demand (hit-rate) totals
        assert pe.fast_bytes_total == self.B
        assert pe.capacity_bytes_total == 2 * self.B
        pf.close()

    def test_inflight_projects_as_fast_exactly_once(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()
        pf = PrefetchPipeline(pe, self.B)
        chunks = {("c", 0): self.B, ("c", 1): self.B, ("c", 2): self.B}
        plan = pf.plan(chunks)
        assert pe.project(chunks).fast_bytes == self.B
        pf.begin(plan, chunks)
        # c2 is streaming: admission now projects it fast, not a second
        # capacity read
        assert pe.project(chunks).fast_bytes == 2 * self.B
        pf.finish(plan)
        assert pe.project(chunks).fast_bytes == self.B
        pf.close()

    def test_chunk_larger_than_buffer_never_staged(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()
        pf = PrefetchPipeline(pe, self.B // 2)
        plan = pf.plan({("c", 0): self.B, ("c", 1): self.B,
                        ("c", 2): self.B})
        assert not plan.used
        assert plan.service_s == pytest.approx(plan.sync_service_s)
        pf.close()

    def test_memcache_first_touch_not_staged(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe(policy=Policy.MEMCACHE, pin=())
        pf = PrefetchPipeline(pe, self.B)
        chunks = {("c", 0): self.B, ("c", 1): self.B, ("c", 2): self.B}
        assert not pf.plan(chunks).used    # no frequency evidence yet
        pe.on_access(chunks)               # first touch builds evidence
        pe.demoted = False
        plan = pf.plan(chunks)
        assert plan.used                   # admission bar now cleared
        pf.close()

    def test_demoted_fast_tier_stages_nothing(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()
        pf = PrefetchPipeline(pe, self.B)
        pe.demoted = True
        chunks = {("c", 0): self.B, ("c", 1): self.B, ("c", 2): self.B}
        plan = pf.plan(chunks)
        assert not plan.used
        # everything reads from the durable capacity tier
        assert plan.sync_service_s == pytest.approx(3 * self.B / self.CAP)
        assert plan.service_s == pytest.approx(plan.sync_service_s)
        pf.close()

    def test_stall_degrades_to_sync_and_reports_waste(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()
        pf = PrefetchPipeline(pe, self.B)
        chunks = {("c", 0): self.B, ("c", 1): self.B, ("c", 2): self.B}
        plan = pf.plan(chunks, stalled=lambda cid: cid == ("c", 2))
        # the stalled stream re-reads synchronously: overlap gone
        assert plan.service_s == pytest.approx(plan.sync_service_s)
        assert plan.stalled_bytes == self.B
        assert plan.staged_bytes == 0      # nothing usefully streamed
        line = pf.finish(plan, qid=9)
        assert line is None                # stalled waste is the caller's
        assert pf.stats()["stalled_chunks"] == 1
        pf.close()

    def test_reservation_bounded_and_restored(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe()
        with pytest.raises(ValueError, match="exceeds fast tier"):
            PrefetchPipeline(pe, 10_000)
        pf = PrefetchPipeline(pe, self.B)
        assert pe.prefetch_reserved_bytes == self.B
        assert pe.stats()["prefetch_reserved_bytes"] == self.B
        pf.close()
        assert pe.prefetch_reserved_bytes == 0

    def test_reservation_evicts_lru_when_tier_full(self):
        from repro.tier import PrefetchPipeline
        pe = self._pe(policy=Policy.CACHE, fast_capacity=2000, pin=())
        chunks = {("c", 0): self.B, ("c", 1): self.B}
        pe.on_access(chunks)               # CACHE promotes both; tier full
        assert pe.in_fast.sum() == 2
        pf = PrefetchPipeline(pe, self.B)  # must evict the LRU resident
        assert pe.in_fast.sum() == 1
        assert int(pe.budget.remaining) == 0
        pf.close()

    def test_engine_requires_matching_placement(self):
        from repro.query import QueryEngine
        from repro.serve.sla import VirtualClock
        from repro.tier import PrefetchPipeline
        pe, other = self._pe(), self._pe()
        pf = PrefetchPipeline(other, self.B)
        with pytest.raises(ValueError, match="different PlacementEngine"):
            QueryEngine(Table.synthetic("t", 256, {"a": 8, "b": 8},
                                        seed=0),
                        tiered=pe, clock=VirtualClock(), prefetch=pf)
        pf.close()
