"""Observability: deterministic tracing, scoped metrics, conservation.

Pins down PR 9's contracts:

- the conservation audit holds on every execution path — plain tiered,
  encoded (compressed store), sharded, grouped, prefetch on, chaos on —
  and *fails* on a deliberately double-charged synthetic ledger;
- a seeded chaos replay exports byte-identical Chrome trace JSON twice;
- the launch-counter migration: dispatch shims read the default scope
  unchanged, two engines' scoped registries don't pollute each other;
- the unified snapshot's canonical byte keys agree with both
  PlacementEngine totals and PrefetchPipeline.stats() (the
  overlapping-key normalization regression test);
- the bench regression gate trips on a >30% drop and passes otherwise.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.db import Table
from repro.kernels import dispatch
from repro.launch.mesh import make_mesh
from repro.obs import (ConservationError, MetricsRegistry, NullTracer,
                       Tracer, audit, check, chrome_trace,
                       chrome_trace_json, scoped, unified_snapshot,
                       waterfall)
from repro.obs.trace import NULL_TRACE
from repro.query import Query, QueryEngine, ShardedTable
from repro.query.plan import GroupBy, Pred
from repro.resilience import (ChaosHarness, ChunkGuard, FaultSpec,
                              RetryPolicy)
from repro.serve.sla import VirtualClock
from repro.store import EncodedTable
from repro.tier import (PlacementEngine, Policy, TraceSpec, make_trace,
                        paper_tiers, replay_trace)
from repro.tier.prefetch import PrefetchPipeline

N_ROWS, CHUNK_ROWS = 4096, 512


def make_table(seed=1, n_cols=8):
    return Table.synthetic("obs", N_ROWS,
                           {f"c{i:02d}": 8 for i in range(n_cols)},
                           seed=seed)


def tiered_engine(table, *, policy=Policy.CACHE, fast_frac=0.5,
                  compute_w=0.0, **kw):
    from repro.energy.meter import EnergyMeter
    tiers = paper_tiers(table.nbytes * fast_frac, fast_gbps=10.0)
    pe = PlacementEngine.for_table(table, tiers, policy,
                                   chunk_rows=CHUNK_ROWS,
                                   meter=EnergyMeter(tiers, compute_w))
    tracer = Tracer()
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock(), tracer=tracer, **kw)
    return eng, pe, tracer


def run_queries(eng, n=4):
    for _ in range(n):
        q = Query(Pred("c00", "ge", 10), aggregates=("c01",))
        assert eng.submit(q, deadline=eng.clock() + 100.0) is not None
        eng.run()


# --------------------------------------------------------------------------
# conservation audit across execution paths
# --------------------------------------------------------------------------

def test_audit_plain_tiered():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng)
    report = check(tracer, pe.meter)
    assert report.ok and len(report.queries) == 4
    # query-kind bytes match the engine's accounting exactly
    for qa, res in zip(report.queries, eng.results):
        assert sum(qa.span_bytes["query"]) == res.bytes_scanned


def test_audit_with_compute_term():
    eng, pe, tracer = tiered_engine(make_table(), compute_w=7.5)
    run_queries(eng)
    assert pe.meter.compute_j > 0
    check(tracer, pe.meter)


def test_audit_encoded():
    table = make_table()
    enc = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    eng, pe, tracer = tiered_engine(enc)
    run_queries(eng)
    check(tracer, pe.meter)


def test_audit_sharded():
    st = ShardedTable.shard(make_table(), make_mesh((1,), ("data",)))
    eng, pe, tracer = tiered_engine(st)
    run_queries(eng)
    check(tracer, pe.meter)


def test_audit_grouped():
    table = make_table()
    enc = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    eng, pe, tracer = tiered_engine(enc)
    q = GroupBy(keys=("c00",), aggs=("c01",),
                where=Pred("c02", "ge", 4))
    assert eng.submit(q, deadline=eng.clock() + 100.0) is not None
    eng.run()
    check(tracer, pe.meter)
    # grouped execution attributed its batched launches to the query
    kinds = tracer.queries[0].span_kinds()
    assert kinds.get("launch", 0) >= 1


def test_audit_prefetch():
    table = make_table()
    from repro.energy.meter import EnergyMeter
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=10.0)
    pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                   chunk_rows=CHUNK_ROWS,
                                   meter=EnergyMeter(tiers))
    pf = PrefetchPipeline(pe, table.nbytes // 8)
    tracer = Tracer()
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock(), prefetch=pf, tracer=tracer)
    run_queries(eng, n=6)
    check(tracer, pe.meter)
    kinds = {}
    for qt in tracer.queries:
        for k, n in qt.span_kinds().items():
            kinds[k] = kinds.get(k, 0) + n
    assert kinds.get("prefetch_read", 0) > 0, \
        "pipeline never staged a chunk in the trace"
    assert pe.prefetch_streamed_bytes_total == sum(
        sp.nbytes for qt in tracer.queries for sp in qt.spans
        if sp.kind == "prefetch_read")


def chaos_traced_run(n_queries=60, prefetch=True):
    """Seeded fault-injected replay with tracing; fresh state per call."""
    table = Table.synthetic("events", 8192,
                            {f"c{i:02d}": 8 for i in range(8)}, seed=0)
    enc = EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=0.016)
    qtrace = make_trace(table, TraceSpec(n_queries=n_queries, skew=1.2,
                                         seed=11))
    clean_s = (enc.nbytes
               / sum(len(c.chunks) for c in enc.columns.values())
               / tiers.fast.bandwidth)
    chaos = ChaosHarness(
        FaultSpec(seed=42, stall_rate=0.1, corrupt_rate=0.05),
        guard=ChunkGuard(enc),
        retry=RetryPolicy(timeout_s=2.0 * clean_s,
                          backoff_s=0.5 * clean_s, max_retries=2))
    chaos.inject_corruption()
    tracer = Tracer()
    pe, eng, att = replay_trace(
        enc, qtrace, tiers, Policy.CACHE, sla_s=5e-2,
        chunk_rows=CHUNK_ROWS, chaos=chaos,
        prefetch_bytes=(table.nbytes // 16 if prefetch else 0),
        tracer=tracer)
    return tracer, pe, eng


def test_audit_chaos():
    tracer, pe, eng = chaos_traced_run()
    report = check(tracer, pe.meter)
    assert report.ok
    kinds = {}
    for qt in tracer.queries:
        for k, n in qt.span_kinds().items():
            kinds[k] = kinds.get(k, 0) + n
    # the fault machinery actually fired and was traced
    assert kinds.get("retry", 0) > 0
    assert kinds.get("repair", 0) > 0
    assert kinds.get("prefetch_stall", 0) > 0
    # recovery span bytes == the placement engine's recovery total
    rec_span_b = sum(sp.nbytes for qt in tracer.queries
                     for sp in qt.spans if sp.ledger == "recovery")
    assert rec_span_b == pe.recovery_bytes_total


def test_audit_fails_on_double_charge():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng, n=2)
    check(tracer, pe.meter)
    # charge the same recovery bytes a second time against a traced qid —
    # the PR 6-7 double-charge bug class, now structurally detectable
    pe.meter.charge(0, 4096, qid=tracer.queries[0].qid, kind="recovery")
    report = audit(tracer, pe.meter)
    assert not report.ok
    with pytest.raises(ConservationError, match="recovery"):
        check(tracer, pe.meter)


def test_audit_flags_untraced_ledger_lines():
    eng, pe, tracer = tiered_engine(make_table())
    run_queries(eng, n=1)
    pe.meter.charge(0, 512, qid=999, kind="query")
    report = audit(tracer, pe.meter)
    assert not report.ok
    assert any("untraced" in p for p in report.problems)


# --------------------------------------------------------------------------
# determinism + export
# --------------------------------------------------------------------------

def test_chaos_trace_byte_identical():
    j1 = chrome_trace_json(chaos_traced_run(n_queries=40)[0])
    j2 = chrome_trace_json(chaos_traced_run(n_queries=40)[0])
    assert j1 == j2
    assert len(j1) > 1000


def test_chrome_trace_loadable():
    tracer, pe, eng = chaos_traced_run(n_queries=20)
    doc = json.loads(chrome_trace_json(tracer))
    events = doc["traceEvents"]
    assert events, "empty trace"
    assert {e["ph"] for e in events} <= {"X", "M"}
    xs = [e for e in events if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    # one root lane event per served query
    roots = [e for e in xs if e["tid"] == 0]
    assert len(roots) == len(tracer.queries)
    # round-trips through chrome_trace() identically
    assert doc == chrome_trace(tracer)


def test_waterfall_renders():
    tracer, pe, eng = chaos_traced_run(n_queries=10)
    text = waterfall(tracer, max_queries=3)
    assert "read" in text and "q" in text
    assert len(text.splitlines()) > 3


# --------------------------------------------------------------------------
# tracer surface + disabled path
# --------------------------------------------------------------------------

def test_null_tracer_is_allocation_free():
    nt = NullTracer()
    qt = nt.begin_query(1)
    assert qt is NULL_TRACE and not qt.enabled
    qt.begin_run(0.0)
    assert qt.read((0, 0), 1, tier="fast", hit=True) is None
    qt.close(1.0, met=True)
    assert len(nt) == 0


def test_engine_default_has_no_tracing_overhead():
    eng, pe, _ = tiered_engine(make_table())
    eng2 = QueryEngine(make_table(), mode="xla_ref",
                       tiered=PlacementEngine.for_table(
                           make_table(),
                           paper_tiers(make_table().nbytes * 0.5,
                                       fast_gbps=10.0),
                           Policy.CACHE, chunk_rows=CHUNK_ROWS),
                       clock=VirtualClock())
    assert isinstance(eng2.tracer, NullTracer)
    run_queries(eng2, n=1)   # runs clean with tracing off


def test_tracer_requires_tiered():
    with pytest.raises(ValueError, match="tiered"):
        QueryEngine(make_table(), mode="xla_ref", tracer=Tracer())


# --------------------------------------------------------------------------
# scoped metrics + dispatch shims (the launch-counter migration)
# --------------------------------------------------------------------------

def test_dispatch_shims_default_scope():
    dispatch.reset_launch_counts()
    dispatch.count_launch("fam_a", 2)
    dispatch.count_launch("fam_b")
    assert dispatch.launch_counts() == {"fam_a": 2, "fam_b": 1}
    assert dispatch.total_launches() == 3
    dispatch.reset_launch_counts()
    assert dispatch.launch_counts() == {}


def test_scoped_isolation_between_engines():
    dispatch.reset_launch_counts()
    r1, r2 = MetricsRegistry("e1"), MetricsRegistry("e2")
    with scoped(r1):
        dispatch.count_launch("fam", 3)
    with scoped(r2):
        dispatch.count_launch("fam", 5)
    assert r1.launch_counts() == {"fam": 3}
    assert r2.launch_counts() == {"fam": 5}
    # the default scope (the legacy shims) still sees the global view
    assert dispatch.launch_counts() == {"fam": 8}
    dispatch.reset_launch_counts()
    # resetting the default does not clear engine scopes
    assert r1.launch_counts() == {"fam": 3}


def test_engine_scope_attributes_launches():
    t = make_table()
    eng, pe, tracer = tiered_engine(t)
    run_queries(eng, n=2)
    assert eng.metrics.launch_counts().get("scan_aggregate") == 2
    # the trace carries one launch span per family per query
    for qt in tracer.queries:
        fams = [sp.attrs["family"] for sp in qt.spans
                if sp.kind == "launch"]
        assert fams == ["scan_aggregate"]


def test_registry_histogram_and_gauge():
    r = MetricsRegistry("x")
    r.gauge("depth").set(3.5)
    h = r.histogram("lat")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = r.snapshot()
    assert snap["gauges"]["depth"] == 3.5
    assert snap["histograms"]["lat"]["count"] == 3
    assert snap["histograms"]["lat"]["mean"] == 2.0
    with pytest.raises(ValueError):
        r.counter("c").inc(-1)


# --------------------------------------------------------------------------
# unified snapshot: the overlapping-key normalization (satellite fix)
# --------------------------------------------------------------------------

def test_snapshot_normalizes_prefetch_keys():
    table = make_table()
    from repro.energy.meter import EnergyMeter
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=10.0)
    pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                   chunk_rows=CHUNK_ROWS,
                                   meter=EnergyMeter(tiers))
    pf = PrefetchPipeline(pe, table.nbytes // 8)
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock(), prefetch=pf)
    run_queries(eng, n=6)
    snap = unified_snapshot(eng)
    # one canonical name per byte stream, cross-checked against both the
    # placement totals and the pipeline's stats() dialect
    assert snap["prefetch.streamed_bytes"] \
        == pe.prefetch_streamed_bytes_total \
        == pf.stats()["streamed_bytes"]
    assert snap["prefetch.wasted_bytes"] \
        == pe.prefetch_wasted_bytes_total == pf.stats()["wasted_bytes"]
    assert snap["tier.recovery_bytes"] == pe.recovery_bytes_total \
        == pe.stats()["recovery_bytes"]
    assert snap["tier.fast_bytes"] == pe.stats()["fast_bytes"]
    assert snap["energy.prefetch_j"] == pe.meter.prefetch_j
    assert snap["sla.served"] == 6


def test_snapshot_detects_key_drift():
    # the placement totals and the pipeline's own ledger are maintained
    # independently; drift one byte apart and the snapshot must refuse to
    # tell two stories
    table = make_table()
    from repro.energy.meter import EnergyMeter
    tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=10.0)
    pe = PlacementEngine.for_table(table, tiers, Policy.CACHE,
                                   chunk_rows=CHUNK_ROWS,
                                   meter=EnergyMeter(tiers))
    pf = PrefetchPipeline(pe, table.nbytes // 8)
    eng = QueryEngine(table, mode="xla_ref", tiered=pe,
                      clock=VirtualClock(), prefetch=pf)
    run_queries(eng, n=6)
    assert pf.streamed_bytes_total > 0   # the pair must be live, not 0==0
    pe.prefetch_streamed_bytes_total += 1
    with pytest.raises(ValueError, match="streamed_bytes"):
        unified_snapshot(eng)


# --------------------------------------------------------------------------
# bench regression gate
# --------------------------------------------------------------------------

def test_check_regress_gate(tmp_path, monkeypatch):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import check_regress
    monkeypatch.setattr(check_regress, "ROOT", tmp_path)
    path = tmp_path / "BENCH_kernels.json"
    path.write_text(json.dumps(
        [{"tuned_gbps": v} for v in (10.0, 11.0, 10.5, 10.8)]))
    ok, msg = check_regress.check_bench("kernels")
    assert ok, msg
    # >30% drop from the median trips the gate
    path.write_text(json.dumps(
        [{"tuned_gbps": v} for v in (10.0, 11.0, 10.5, 6.0)]))
    ok, msg = check_regress.check_bench("kernels")
    assert not ok and "REGRESSION" in msg
    assert check_regress.main(["kernels"]) == 1
    # a missing file is a skip, not a failure
    ok, msg = check_regress.check_bench("store")
    assert ok and "SKIP" in msg
