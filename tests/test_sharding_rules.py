"""Logical-axis sharding resolution unit tests (no devices needed beyond 1:
resolution is pure math over the mesh shape)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DEFAULT_RULES, resolve_spec

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


class FakeMesh:
    """Duck-typed mesh: resolve_spec only reads axis_names and shape."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def spec(shape, names, mesh=SINGLE, rules=None):
    return resolve_spec(shape, names, mesh, dict(DEFAULT_RULES,
                                                 **(rules or {})))


def test_tp_plus_fsdp():
    assert spec((4096, 8192), ("embed", "mlp")) == P("data", "model")


def test_missing_axis_dropped():
    # 'pod' not in the single mesh -> silently dropped
    assert spec((256, 64), ("batch", None)) == P("data")
    assert spec((256, 64), ("batch", None), MULTI) == P(("pod", "data"))


def test_indivisible_falls_back():
    # 24 heads over 16-way model axis: dropped (jit needs divisibility)
    assert spec((3072, 24, 128), ("embed", "heads", "head_dim")) == P("data")
    # divisible head counts shard
    assert spec((4096, 32, 128), ("embed", "heads", "head_dim")) == \
        P("data", "model")


def test_axis_never_reused_within_array():
    # both dims want 'model'; the second claim loses
    s = spec((1024, 2048), ("mlp", "vocab"))
    assert s == P("model")


def test_fsdp_over_pod_and_data():
    s = spec((16384, 53248), ("embed", "mlp"), MULTI,
             rules={"embed": ("data", "pod")})
    assert s == P(("data", "pod"), "model")


def test_experts_ep_vs_tp():
    # 64 experts: EP over model
    assert spec((64, 2048, 1408), ("experts", "embed", "expert_mlp")) == \
        P("model", "data")
    # 8 experts < 16: EP dropped, expert-TP picks up the ffn dim
    assert spec((8, 6144, 16384), ("experts", "embed", "expert_mlp")) == \
        P(None, "data", "model")


def test_trailing_nones_trimmed():
    s = spec((32, 128), (None, None))
    assert s == P()


def test_scalar():
    assert spec((), "_scalar_") == P()


def test_string_axes_leaf():
    assert spec((4096, 8192), "embed mlp") == P("data", "model")
    assert spec((128, 64), "batch _") == P("data")
