"""Compressed store tests: encodings, selector, scan-over-compressed
parity, byte accounting, tier interplay, and the compression axis of the
decision surface.

Parity contract (ISSUE 5): every encoding and query shape produces
results bit-identical to the plain-format engine under PALLAS, XLA_REF,
and AUTO, including through the sharded delta view — and every path
returns the same empty-selection identity (count=0, sum=0, min=vmax,
max=0 at the logical width).
"""
import numpy as np
import pytest

from repro.db.columnar import BitPackedColumn, Table
from repro.kernels.aggregate import ops as agg_ops
from repro.kernels.scan_compressed import ops as rle_ops
from repro.kernels.scan_compressed import ref as rle_ref
from repro.launch.mesh import make_mesh
from repro.query import And, Or, Pred, Query, QueryEngine
from repro.store import (EncodedTable, Encoding, ShardedEncodedTable,
                         encode_chunk, execute_encoded)
from repro.store.exec import fixup_base, identity_ints, translate_pred

MODES = ("pallas", "xla_ref", "auto")

# 6001 rows: not a multiple of any codes-per-word or the chunking, so
# every column carries tail padding in its last chunk
N_ROWS = 6001
CHUNK_ROWS = 1024


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(3)
    t = Table("t")
    t.add(BitPackedColumn.from_values(          # sorted low-card -> RLE
        "r", np.sort(rng.integers(0, 8, N_ROWS)), 8))
    t.add(BitPackedColumn.from_values(          # clustered -> FOR at 4
        "f", 40 + rng.integers(0, 8, N_ROWS), 8))
    t.add(BitPackedColumn.from_values(          # 16-bit clustered -> FOR
        "w", 9000 + rng.integers(0, 100, N_ROWS), 16))
    t.add(BitPackedColumn.from_values(          # uniform -> plain
        "u", rng.integers(0, 128, N_ROWS), 8))
    t.add(BitPackedColumn.from_values(          # narrow width
        "x", rng.integers(0, 8, N_ROWS), 4))
    return t


@pytest.fixture(scope="module")
def encoded(table):
    return EncodedTable.from_table(table, chunk_rows=CHUNK_ROWS)


@pytest.fixture(scope="module")
def decoded(table):
    return {c: table.columns[c].decode() for c in table.columns}


# --------------------------------------------------------------------------
# encodings & selector
# --------------------------------------------------------------------------
class TestEncoding:
    def test_roundtrip_every_column(self, table, encoded):
        for name, col in table.columns.items():
            np.testing.assert_array_equal(encoded.columns[name].decode(),
                                          col.decode())

    def test_selector_picks_the_expected_formats(self, encoded):
        assert set(encoded.columns["r"].encodings().items()) >= \
            {("rle", len(encoded.columns["r"].chunks))}
        assert encoded.columns["f"].encodings()["for"] > 0
        assert encoded.columns["w"].encodings()["for"] > 0
        assert encoded.columns["u"].encodings()["plain"] == \
            len(encoded.columns["u"].chunks)

    def test_never_larger_than_plain(self, encoded):
        for col in encoded.columns.values():
            for ch in col.chunks:
                assert ch.nbytes <= ch.stats.plain_nbytes, (col.name,
                                                            ch.encoding)
        assert encoded.nbytes < encoded.logical_nbytes
        assert encoded.ratio > 1.5

    def test_forced_encoding_roundtrip(self):
        codes = np.asarray([5, 5, 5, 9, 9, 0, 1, 2, 3], np.uint32)
        for enc in Encoding:
            ch = encode_chunk(codes, 8, enc)
            assert ch.encoding is enc
            np.testing.assert_array_equal(ch.decode(), codes)

    def test_for_chunk_packs_at_narrower_width(self):
        ch = encode_chunk(1000 + np.arange(8, dtype=np.uint32), 16)
        assert ch.encoding is Encoding.FOR
        assert ch.width == 4 and ch.base == 1000

    def test_oversized_chunk_rejected(self):
        with pytest.raises(ValueError, match="MAX_CHUNK_ROWS"):
            encode_chunk(np.zeros(70000, np.uint32), 8)

    def test_too_wide_codes_rejected(self):
        with pytest.raises(ValueError, match="payload max"):
            encode_chunk(np.asarray([300], np.uint32), 8)

    def test_unknown_pinned_column_rejected(self, table):
        with pytest.raises(ValueError, match="unknown column"):
            EncodedTable.from_table(table, encodings={"nope": Encoding.RLE})

    def test_zero_row_chunk_every_encoding(self):
        for enc in (None, *Encoding):
            ch = encode_chunk(np.zeros(0, np.uint32), 8, enc)
            assert ch.n_rows == 0 and ch.nbytes == 0
            assert ch.decode().size == 0

    def test_placement_chunking_must_match_store(self, encoded):
        col = next(iter(encoded.columns.values()))
        with pytest.raises(ValueError, match="multiple of the store"):
            col.chunk_physical_bytes(CHUNK_ROWS + 8)
        merged = col.chunk_physical_bytes(2 * CHUNK_ROWS)
        assert sum(merged) == col.nbytes


# --------------------------------------------------------------------------
# scan-over-compressed parity (the acceptance core)
# --------------------------------------------------------------------------
PLAN_SHAPES = [
    # (name, plan factory, aggregates) — every encoding x shape combo:
    ("rle_fused_self_agg", lambda: Pred("r", "lt", 4), ("r",)),
    ("rle_fused_eq", lambda: Pred("r", "eq", 3), ("r",)),
    ("rle_fused_ne", lambda: Pred("r", "ne", 3), ("r",)),
    ("rle_pred_other_agg", lambda: Pred("r", "ge", 6), ("f",)),
    ("for_fused_same_width", lambda: Pred("f", "ge", 44), ("f",)),
    ("for_cross_column", lambda: Pred("f", "lt", 44), ("w",)),
    ("for16_pred", lambda: Pred("w", "ge", 9050), ("u",)),
    ("plain_pred_for_agg", lambda: Pred("u", "lt", 64), ("w",)),
    ("and_mixed_encodings",
     lambda: Pred("f", "ge", 42) & Pred("w", "lt", 9080), ("w", "x")),
    ("or_mixed_widths",
     lambda: Pred("x", "eq", 3) | Pred("w", "lt", 9010), ("u",)),
    ("nested_and_or",
     lambda: And.of(Or.of(Pred("r", "le", 2), Pred("u", "gt", 120)),
                    Pred("x", "ne", 0)), ("f",)),
    ("multi_agg_all_encodings", lambda: Pred("f", "ge", 43),
     ("r", "f", "w", "u", "x")),
    ("empty_selection_rle", lambda: Pred("r", "gt", 7), ("r",)),
    ("empty_selection_for", lambda: Pred("f", "lt", 40), ("w",)),
    ("all_match_for", lambda: Pred("w", "ge", 0), ("w",)),
    ("below_frame_constant", lambda: Pred("w", "lt", 5), ("w",)),
]


@pytest.mark.parametrize("name,mkplan,aggs", PLAN_SHAPES,
                         ids=[p[0] for p in PLAN_SHAPES])
def test_encoded_matches_plain_all_modes(table, encoded, name, mkplan,
                                         aggs):
    q = Query(mkplan(), aggregates=aggs)
    got_by_mode = {}
    for mode in MODES:
        e_plain = QueryEngine(table, mode=mode)
        e_comp = QueryEngine(encoded, mode=mode)
        e_plain.submit(q)
        e_comp.submit(q)
        want, got = e_plain.run()[0], e_comp.run()[0]
        assert got.aggregates == want.aggregates, (name, mode)
        assert got.count == want.count
        got_by_mode[mode] = got.aggregates
    assert got_by_mode["pallas"] == got_by_mode["xla_ref"]


@pytest.mark.parametrize("name,mkplan,aggs", PLAN_SHAPES,
                         ids=[p[0] for p in PLAN_SHAPES])
def test_sharded_encoded_matches_plain(table, encoded, name, mkplan, aggs):
    """1-device mesh in-process; the 8-device run lives in
    tests/multidevice_child.py (device count locks at first jax init)."""
    st = ShardedEncodedTable.shard(encoded, make_mesh((1,), ("data",)))
    q = Query(mkplan(), aggregates=aggs)
    for mode in ("pallas", "xla_ref"):
        e_plain = QueryEngine(table, mode=mode)
        e_shard = QueryEngine(st, mode=mode)
        e_plain.submit(q)
        e_shard.submit(q)
        assert e_shard.run()[0].aggregates == e_plain.run()[0].aggregates, \
            (name, mode)


def test_sharded_view_is_compressed(encoded):
    st = ShardedEncodedTable.shard(encoded, make_mesh((1,), ("data",)))
    assert st.nbytes < sum(c.logical_nbytes
                           for c in encoded.columns.values())
    assert st.n_shards == 1 and st.num_rows == encoded.num_rows


# --------------------------------------------------------------------------
# empty-selection / zero-row identities (satellite)
# --------------------------------------------------------------------------
class TestIdentities:
    def test_identity_constants(self):
        assert identity_ints(8) == {"sum": 0, "count": 0, "min": 127,
                                    "max": 0}

    def test_rle_kernel_empty_runs(self):
        for mode in MODES:
            d = rle_ops.rle_scan_aggregate(
                np.zeros(0, np.int32), np.zeros(0, np.int32), 3, "lt", 8,
                mode=mode)
            assert agg_ops.finalize(d) == identity_ints(8)

    def test_rle_kernel_no_match(self):
        v = np.asarray([5, 9, 5], np.int32)
        l = np.asarray([4, 4, 4], np.int32)
        for mode in MODES:
            d = rle_ops.rle_scan_aggregate(v, l, 100, "gt", 8, mode=mode)
            assert agg_ops.finalize(d) == identity_ints(8)

    def test_fixup_never_leaks_delta_sentinel(self):
        """A FOR chunk's empty selection must collapse to the *logical*
        identity, not base + delta-domain sentinel."""
        delta_empty = {"sum": 0, "count": 0, "min": 7, "max": 0}  # 4-bit
        assert fixup_base(delta_empty, base=40, code_bits=8) == \
            identity_ints(8)

    def test_zero_row_encoded_table(self):
        t = Table("empty")
        t.add(BitPackedColumn.from_values("a", np.zeros(0, np.uint32), 8))
        t.add(BitPackedColumn.from_values("b", np.zeros(0, np.uint32), 8))
        et = EncodedTable.from_table(t)
        q = Query(Pred("a", "lt", 5), aggregates=("b",))
        for mode in ("pallas", "xla_ref"):
            eng = QueryEngine(et, mode=mode)
            eng.submit(q)
            res = eng.run()[0]
            assert res.aggregates["b"] == identity_ints(8)
            assert res.count == 0

    def test_empty_selection_identical_across_paths(self, table, encoded):
        """count=0 must produce bit-identical dicts on plain, encoded,
        and sharded-encoded paths under every mode."""
        q = Query(Pred("f", "lt", 40), aggregates=("f", "w"))
        st = ShardedEncodedTable.shard(encoded,
                                       make_mesh((1,), ("data",)))
        outs = []
        for tbl in (table, encoded, st):
            for mode in ("pallas", "xla_ref"):
                eng = QueryEngine(tbl, mode=mode)
                eng.submit(q)
                outs.append(eng.run()[0].aggregates)
        assert all(o == {"f": identity_ints(8), "w": identity_ints(16)}
                   for o in outs), outs


# --------------------------------------------------------------------------
# the scan_compressed kernel family
# --------------------------------------------------------------------------
class TestRLEKernel:
    @pytest.mark.parametrize("op", ("lt", "le", "gt", "ge", "eq", "ne"))
    def test_kernel_matches_ref_and_rows(self, op):
        rng = np.random.default_rng(5)
        v = rng.integers(0, 128, 300).astype(np.int32)
        l = rng.integers(0, 5, 300).astype(np.int32)   # zero-length runs
        rows = np.repeat(v, l)
        want_sel = {"lt": rows < 64, "le": rows <= 64, "gt": rows > 64,
                    "ge": rows >= 64, "eq": rows == 64,
                    "ne": rows != 64}[op]
        want = {
            "sum": int(rows[want_sel].sum()),
            "count": int(want_sel.sum()),
            "min": int(rows[want_sel].min()) if want_sel.any() else 127,
            "max": int(rows[want_sel].max()) if want_sel.any() else 0,
        }
        for mode in MODES:
            got = agg_ops.finalize(rle_ops.rle_scan_aggregate(
                v, l, 64, op, 8, mode=mode))
            assert got == want, (op, mode)

    def test_sum_exact_at_chunk_bound(self):
        """vmax runs filling a max chunk: the sum partial grazes int32."""
        v = np.full(64, 127, np.int32)
        l = np.full(64, 512, np.int32)          # 32768 rows of 127
        for mode in ("pallas", "xla_ref"):
            got = agg_ops.finalize(rle_ops.rle_scan_aggregate(
                v, l, 0, "ge", 8, mode=mode))
            assert got["sum"] == 127 * 32768 and got["count"] == 32768

    def test_block_rows_sweep_bit_exact(self):
        rng = np.random.default_rng(6)
        v = rng.integers(0, 8, 1000).astype(np.int32)
        l = rng.integers(1, 9, 1000).astype(np.int32)
        want = agg_ops.finalize(rle_ref.rle_scan_aggregate_ref(
            v, l, 4, "ge", 8))
        for br in (1, 2, 3, 8):
            got = agg_ops.finalize(rle_ops.rle_scan_aggregate(
                v, l, 4, "ge", 8, block_rows=br, mode="pallas"))
            assert got == want, br

    def test_bad_op_raises(self):
        with pytest.raises(ValueError, match="unknown predicate op"):
            rle_ops.rle_scan_aggregate(np.zeros(1, np.int32),
                                       np.ones(1, np.int32), 1, "like", 8)


# --------------------------------------------------------------------------
# plan translation into the delta domain
# --------------------------------------------------------------------------
class TestTranslation:
    @pytest.mark.parametrize("op", ("lt", "le", "gt", "ge", "eq", "ne"))
    def test_translation_semantics_exhaustive(self, op):
        """For every constant around and beyond the frame, the translated
        predicate selects exactly the rows the logical one does."""
        base, width = 40, 4                     # deltas 0..7 representable
        deltas = np.arange(8)
        codes = base + deltas
        fn = {"lt": np.less, "le": np.less_equal, "gt": np.greater,
              "ge": np.greater_equal, "eq": np.equal,
              "ne": np.not_equal}[op]
        for c in range(0, 128):
            top, tc = translate_pred(op, c, base, width)
            want = fn(codes, c)
            got = {"lt": deltas < tc, "le": deltas <= tc,
                   "gt": deltas > tc, "ge": deltas >= tc,
                   "eq": deltas == tc, "ne": deltas != tc}[top]
            np.testing.assert_array_equal(got, want, err_msg=f"{op} {c}")

    def test_unknown_op_raises(self):
        with pytest.raises(ValueError, match="unknown predicate op"):
            translate_pred("like", 3, 0, 8)


# --------------------------------------------------------------------------
# byte accounting: physical vs logical
# --------------------------------------------------------------------------
class TestBytes:
    def test_physical_less_than_logical_on_compressed(self, encoded):
        eng = QueryEngine(encoded)
        eng.submit(Query(Pred("f", "ge", 44), aggregates=("w",)))
        res = eng.run()[0]
        assert 0 < res.bytes_scanned < res.logical_bytes
        s = eng.summary()
        assert s["logical_bytes"] > s["bytes_scanned"]
        assert s["effective_gbps"] > s["measured_gbps"] > 0

    def test_plain_table_logical_equals_physical(self, table):
        eng = QueryEngine(table)
        eng.submit(Query(Pred("u", "lt", 64), aggregates=("u",)))
        res = eng.run()[0]
        assert res.bytes_scanned == res.logical_bytes
        s = eng.summary()
        assert s["effective_gbps"] == s["measured_gbps"]

    def test_rle_column_physical_is_tiny(self, encoded):
        eng = QueryEngine(encoded)
        eng.submit(Query(Pred("r", "lt", 4), aggregates=("r",)))
        res = eng.run()[0]
        assert res.bytes_scanned < 0.05 * res.logical_bytes


# --------------------------------------------------------------------------
# tier placement over the compressed store
# --------------------------------------------------------------------------
class TestTier:
    def test_placement_universe_holds_physical_bytes(self, encoded):
        from repro.tier import PlacementEngine, Policy, paper_tiers
        tiers = paper_tiers(encoded.logical_nbytes * 0.25, fast_gbps=8.0)
        pe = PlacementEngine.for_table(encoded, tiers, Policy.STATIC,
                                       chunk_rows=CHUNK_ROWS)
        assert pe.total_bytes == encoded.nbytes

    def test_hit_rate_improves_at_fixed_capacity(self, table, encoded):
        """The acceptance bar: same absolute fast-tier bytes, strictly
        higher byte-weighted hit rate once chunks are compressed."""
        from repro.tier import (Policy, TraceSpec, make_trace, paper_tiers,
                                replay_trace)
        tiers = paper_tiers(table.nbytes * 0.25, fast_gbps=8.0)
        trace = make_trace(table, TraceSpec(n_queries=60, skew=1.1,
                                            seed=7))
        pe_p, _, _ = replay_trace(table, trace, tiers, Policy.CACHE,
                                  chunk_rows=CHUNK_ROWS)
        pe_e, eng_e, _ = replay_trace(encoded, trace, tiers, Policy.CACHE,
                                      chunk_rows=CHUNK_ROWS)
        assert pe_e.hit_rate > pe_p.hit_rate
        # the meter billed the *physical* bytes
        assert eng_e.summary()["energy"]["memory_j"] > 0
        assert (pe_e.fast_bytes_total + pe_e.capacity_bytes_total
                < pe_p.fast_bytes_total + pe_p.capacity_bytes_total)

    def test_sharded_encoded_tiered_runs(self, encoded):
        from repro.serve.sla import VirtualClock
        from repro.tier import PlacementEngine, Policy, paper_tiers
        st = ShardedEncodedTable.shard(encoded,
                                       make_mesh((1,), ("data",)))
        tiers = paper_tiers(st.nbytes * 0.25, fast_gbps=8.0)
        pe = PlacementEngine.for_table(st, tiers, Policy.CACHE,
                                       chunk_rows=CHUNK_ROWS)
        assert pe.total_bytes == st.nbytes
        eng = QueryEngine(st, mode="xla_ref", tiered=pe,
                          clock=VirtualClock())
        eng.submit(Query(Pred("f", "ge", 44), aggregates=("w",)))
        res = eng.run()[0]
        assert res.tier is not None and res.tier["service_s"] > 0


# --------------------------------------------------------------------------
# the compression axis of the decision surface
# --------------------------------------------------------------------------
class TestSurface:
    DB = 16 * (1 << 40)
    BPQ = 0.2 * 16 * (1 << 40)

    def test_ratio_one_reproduces_datasheet_verdict(self):
        from repro.energy.tco import cheapest_architecture
        base = cheapest_architecture(self.DB, self.BPQ, 0.010, 1e6)
        with_axis = cheapest_architecture(self.DB, self.BPQ, 0.010, 1e6,
                                          compression_ratio=1.0)
        assert base["winner"] == with_axis["winner"] == "die-stacked"
        assert with_axis["usd_per_query"] == base["usd_per_query"]
        loose = cheapest_architecture(self.DB, self.BPQ, 0.060, 1e6,
                                      compression_ratio=1.0)
        assert loose["winner"] == "traditional"

    def test_compression_flips_the_10ms_cell(self):
        from repro.energy.tco import cheapest_architecture
        flipped = cheapest_architecture(self.DB, self.BPQ, 0.010, 1e6,
                                        compression_ratio=8.0)
        assert flipped["winner"] == "traditional"
        win = next(c for c in flipped["candidates"]
                   if c["name"] == "traditional")
        assert win["compressed"] is True
        ds = next(c for c in flipped["candidates"]
                  if c["name"] == "die-stacked")
        assert ds["compressed"] is False      # hardware bandwidth instead

    def test_crossover_finite_at_10ms(self):
        from repro.energy.tco import compression_crossover_ratio
        x = compression_crossover_ratio(self.DB, self.BPQ, 0.010, 1e6)
        assert x is not None and 1.0 < x < 64.0
        # already-winning cell: crossover is 1.0 by definition
        assert compression_crossover_ratio(self.DB, self.BPQ, 0.060,
                                           1e6) == 1.0
        # unreachable within the search bound: honest None
        assert compression_crossover_ratio(self.DB, self.BPQ, 0.010, 1e6,
                                           max_ratio=1.5) is None

    def test_surface_grows_a_ratio_axis(self):
        from repro.energy.tco import decision_surface
        surf = decision_surface(self.DB, self.BPQ, slas=(0.010,),
                                skews=(None,), power_budgets_w=(1e6,),
                                compression_ratios=(1.0, 8.0))
        assert len(surf["cells"]) == 2
        by_ratio = {c["compression_ratio"]: c["winner"]
                    for c in surf["cells"]}
        assert by_ratio[1.0] == "die-stacked"
        assert by_ratio[8.0] == "traditional"

    def test_bandwidth_rich_systems_stay_uncompressed(self):
        """A custom HBM-class spec (TPU) must keep the datasheet
        workload on the compression axis — the prefix list is the
        explicit contract, not an accident of Table-1 naming."""
        from repro.core.systems import TPU_V5E, TRADITIONAL, \
            as_paper_system
        from repro.energy.tco import cheapest_architecture
        tpu = as_paper_system(TPU_V5E)
        cell = cheapest_architecture(
            self.DB, self.BPQ, 0.010, 1e7, skew=None,
            systems=(TRADITIONAL, tpu), compression_ratio=8.0)
        by_name = {c["name"]: c for c in cell["candidates"]}
        assert by_name[tpu.name]["compressed"] is False
        assert by_name["traditional"]["compressed"] is True

    def test_ratio_validation(self):
        from repro.energy.tco import cheapest_architecture
        for bad in (0.5, 0.0, -1.0, float("nan"), float("inf")):
            with pytest.raises(ValueError, match="compression_ratio"):
                cheapest_architecture(self.DB, self.BPQ, 0.010, 1e6,
                                      compression_ratio=bad)

    def test_advise_cost_passthrough(self):
        from repro.core.advisor import advise_cost
        cell = advise_cost(self.DB, self.BPQ, 0.010, 1e6,
                           compression_ratio=8.0)
        assert cell["winner"] == "traditional"
        assert cell["compression_ratio"] == 8.0


# --------------------------------------------------------------------------
# validation messages (satellite)
# --------------------------------------------------------------------------
class TestValidationMessages:
    def test_from_values_names_column_bits_and_max(self):
        with pytest.raises(ValueError,
                           match=r"column 'a'.*max code 300.*8-bit.*127"):
            BitPackedColumn.from_values("a", [1, 300], 8)

    def test_from_values_names_negative_min(self):
        with pytest.raises(ValueError, match=r"column 'a'.*min code -2"):
            BitPackedColumn.from_values("a", [-2, 3], 8)

    def test_table_add_names_column_and_counts(self):
        t = Table("t")
        t.add(BitPackedColumn.from_values("a", [1, 2, 3], 8))
        with pytest.raises(ValueError, match=r"'b' has 2 rows.*has 3"):
            t.add(BitPackedColumn.from_values("b", [1, 2], 8))

    def test_scan_filter_bad_op_is_value_error(self):
        from repro.kernels.scan_filter import ops as scan_ops
        from repro.kernels.scan_filter import ref as scan_ref
        packed = scan_ref.pack(np.asarray([1, 2], np.uint32), 8)
        with pytest.raises(ValueError, match="unknown predicate op"):
            scan_ops.scan_filter(packed, 1, "like", 8)
        with pytest.raises(ValueError, match="unknown predicate op"):
            scan_ref.scan_ref(packed, 1, "like", 8)


class TestBatchedLaunches:
    """The tentpole's observable: all same-encoding chunks of a column
    group execute as ONE kernel launch, counted in kernels.dispatch."""

    def test_one_launch_per_group_not_per_chunk(self, encoded):
        from repro.kernels import dispatch

        plan, aggs = Pred("f", "ge", 42), ("u",)
        dispatch.reset_launch_counts()
        execute_encoded(plan, aggs, encoded, mode="xla_ref", batched=False)
        per_chunk = dispatch.total_launches()
        dispatch.reset_launch_counts()
        execute_encoded(plan, aggs, encoded, mode="xla_ref", batched=True)
        batched = dispatch.total_launches()
        assert per_chunk >= encoded.n_chunks       # the old loop: >= 1/chunk
        assert batched < encoded.n_chunks          # batched: 1 per group
        # fused single-pred/single-agg over one width group -> exactly 1
        assert dispatch.launch_counts().get("scan_aggregate") == 1

    def test_rle_chunks_batch_into_one_launch(self, encoded):
        from repro.kernels import dispatch

        dispatch.reset_launch_counts()
        execute_encoded(Pred("r", "lt", 3), ("r",), encoded,
                        mode="xla_ref", batched=True)
        assert dispatch.launch_counts().get("scan_compressed") == 1
        dispatch.reset_launch_counts()
        execute_encoded(Pred("r", "lt", 3), ("r",), encoded,
                        mode="xla_ref", batched=False)
        assert dispatch.launch_counts().get("scan_compressed") == \
            encoded.n_chunks

    @pytest.mark.parametrize("batched", (True, False))
    def test_translate_plan_memoized_on_frame_tuple(self, monkeypatch,
                                                    batched):
        """Chunks sharing a (base, width) frame translate the plan once
        per execute call, not once per chunk — the satellite regression:
        a plain column's frames are identical across chunks, so N chunks
        must cost exactly one translation."""
        import repro.store.exec as X

        rng = np.random.default_rng(0)
        t = Table("m")
        t.add(BitPackedColumn.from_values("a", rng.integers(0, 128, 4096),
                                          8))
        t.add(BitPackedColumn.from_values("b", rng.integers(0, 128, 4096),
                                          8))
        enc = EncodedTable.from_table(
            t, chunk_rows=512,
            encodings={"a": Encoding.PLAIN, "b": Encoding.PLAIN})
        assert enc.n_chunks == 8
        calls = []
        real = X.translate_plan
        monkeypatch.setattr(X, "translate_plan",
                            lambda plan, frames: calls.append(1) or
                            real(plan, frames))
        got = execute_encoded(Pred("a", "lt", 64), ("b",), enc,
                              mode="xla_ref", batched=batched)
        assert len(calls) == 1            # 8 chunks, 1 shared frame
        want = execute_encoded(Pred("a", "lt", 64), ("b",), enc,
                               mode="xla_ref", batched=batched)
        assert got == want
