"""Per-arch smoke tests on reduced configs (deliverable f).

For every assigned architecture: instantiate a tiny same-family config, run
forward + train step + prefill/decode on CPU, assert shapes + no NaNs, and
check decode-vs-full-forward consistency (the strongest correctness check:
the recurrent/cached path must reproduce the parallel path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.models.common import dtype_of
from repro.train import optim, step as step_lib

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def reduced(arch_id, **over):
    # fp32 for tight decode-vs-forward comparisons
    return get_config(arch_id).reduced(dtype="float32", **over)


def make_inputs(cfg, key, b=B, s=S):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch_id):
    cfg = reduced(arch_id)
    params, axes = lm.init(KEY, cfg)
    # axes tree mirrors params tree
    assert jax.tree.structure(params) == jax.tree.structure(axes)
    inputs = make_inputs(cfg, KEY)
    logits, _, aux = lm.prefill(params, cfg, inputs, caches=None)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_decreases_loss(arch_id):
    cfg = reduced(arch_id)
    opt_cfg = optim.AdamWConfig(lr=5e-3, warmup_steps=1, decay_steps=100)
    state, _ = step_lib.init_state(KEY, cfg, opt_cfg)
    step = jax.jit(step_lib.make_train_step(cfg, opt_cfg))
    batch = {"inputs": make_inputs(cfg, KEY),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        assert jnp.isfinite(metrics["loss"])
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses  # memorizes a fixed batch
    assert int(state["step"]) == 5


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_forward(arch_id):
    """Prefill s tokens then decode the rest one-by-one; logits must match
    the all-at-once forward pass."""
    cfg = reduced(arch_id)
    params, _ = lm.init(KEY, cfg)
    inputs = make_inputs(cfg, KEY)
    full_logits, _, _ = lm.prefill(params, cfg, inputs, caches=None)

    split = S // 2
    caches, _ = lm.init_caches(cfg, B, S, dtype_of(cfg.dtype))
    pre = inputs[:, :split]
    logits_pre, caches, _ = lm.prefill(params, cfg, pre, caches)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full_logits[:, :split], np.float32),
        rtol=2e-4, atol=2e-4)

    step = jax.jit(lambda tok, lens, caches: lm.decode_step(
        params, cfg, tok, lens, caches)[:2])
    for t in range(split, S):
        tok = inputs[:, t:t + 1]
        lens = jnp.full((B,), t, jnp.int32)
        logits_t, caches = step(tok, lens, caches)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch_id} pos {t}")


@pytest.mark.parametrize("arch_id", ["internlm2-1.8b", "mixtral-8x22b",
                                     "recurrentgemma-2b", "mamba2-1.3b"])
def test_scan_equals_unrolled(arch_id):
    """scan-over-layers and the unrolled python loop are the same program."""
    cfg = reduced(arch_id)
    params, _ = lm.init(KEY, cfg)
    inputs = make_inputs(cfg, KEY)
    a, _, _ = lm.prefill(params, cfg, inputs, caches=None)
    import dataclasses
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    b_, _, _ = lm.prefill(params, cfg2, inputs, caches=None)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b_, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_param_count_matches_analytic():
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        shapes = jax.eval_shape(lambda k: lm.init(k, cfg)[0], KEY)
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert actual == cfg.param_count(), (
            arch_id, actual, cfg.param_count())


def test_full_scale_param_counts_sane():
    """Published param counts within tolerance (arch name encodes size)."""
    # Expected totals follow the ASSIGNED configs (the task pins exact dims;
    # where a marketing name disagrees — e.g. moonshot "16b" at 48 layers of
    # 64 experts gives 28B — the assignment wins; see DESIGN.md §3).
    expect = {
        "mamba2-1.3b": (1.3e9, 0.08), "internlm2-1.8b": (1.8e9, 0.10),
        "minitron-4b": (4.19e9, 0.08), "llama3-405b": (405e9, 0.03),
        "mistral-large-123b": (123e9, 0.03), "mixtral-8x22b": (141e9, 0.05),
        "moonshot-v1-16b-a3b": (28e9, 0.05), "musicgen-large": (3.3e9, 0.05),
        "recurrentgemma-2b": (2.7e9, 0.08), "internvl2-76b": (69.5e9, 0.05),
    }
    for arch_id, (n, tol) in expect.items():
        got = get_config(arch_id).param_count()
        assert abs(got - n) / n < tol, (arch_id, got, n)
