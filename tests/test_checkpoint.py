"""Checkpoint store: roundtrip, atomicity, GC, async, elastic restore."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "scale": jnp.float32(2.5),
                   "groups": (jax.random.normal(k, (3, 4)),
                              jax.random.normal(k, (2, 2)))},
        "opt": {"m": jnp.zeros((8, 16)), "count": jnp.int32(7)},
        "step": jnp.int32(42),
    }


def assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = make_tree()
    mgr.save(3, tree, metadata={"data_step": 3})
    restored, meta = mgr.restore(tree)
    assert_tree_equal(tree, restored)
    assert meta["step"] == 3 and meta["user"]["data_step"] == 3


def test_versioning_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    restored, _ = mgr.restore(make_tree(), step=3)
    assert_tree_equal(make_tree(3), restored)


def test_atomicity_tmp_dirs_invisible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, make_tree())
    # a crashed half-write must not be listed or restored
    (tmp_path / "step_0000000009.tmp").mkdir()
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    tree = make_tree()
    mgr.save(5, tree)
    mgr.wait()
    restored, _ = mgr.restore(tree)
    assert_tree_equal(tree, restored)


def test_elastic_restore_reshard(tmp_path):
    """Restore with explicit NamedShardings (the re-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh
    mgr = CheckpointManager(tmp_path)
    tree = make_tree()
    mgr.save(1, tree)
    mesh = make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = mgr.restore(tree, shardings=shardings)
    assert_tree_equal(tree, restored)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding.mesh.shape == mesh.shape


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(make_tree())
