"""Fault tolerance: heartbeats, stragglers, supervised restart resumes
training from the checkpoint with a bitwise-identical data stream."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.fault_tolerance import (Heartbeat, RestartPolicy,
                                        StragglerDetector, run_supervised)
from repro.models import lm
from repro.train import optim, step as step_lib


class TestHeartbeat:
    def test_fleet_and_death(self, tmp_path):
        a = Heartbeat(tmp_path, "host-a", timeout_s=0.2)
        b = Heartbeat(tmp_path, "host-b", timeout_s=0.2)
        a.beat(5)
        b.beat(9)
        assert set(a.fleet()) == {"host-a", "host-b"}
        assert a.dead_hosts() == []
        time.sleep(0.25)
        a.beat(6)
        assert a.dead_hosts() == ["host-b"]

    def test_lagging(self, tmp_path):
        hb = Heartbeat(tmp_path, "h0")
        hb.beat(100)
        Heartbeat(tmp_path, "h1").beat(80)
        assert hb.lagging_hosts(behind_steps=10) == ["h1"]


class TestStraggler:
    def test_flags_slow_steps(self):
        det = StragglerDetector(threshold=2.0, warmup=3)
        for s in range(10):
            assert not det.observe(s, 1.0)
        assert det.observe(10, 5.0)           # 5x median
        assert det.flagged == [(10, 5.0)]
        assert not det.observe(11, 1.1)       # baseline not poisoned


class TestSupervisedRestart:
    def test_resumes_from_checkpoint_identically(self, tmp_path):
        """Train 6 steps with a crash at step 3; final state must equal an
        uninterrupted 6-step run."""
        cfg = get_config("internlm2-1.8b").reduced(dtype="float32",
                                                   num_layers=2)
        opt_cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
        ds = SyntheticLM(DataConfig(seed=1, vocab_size=cfg.vocab_size,
                                    seq_len=16, global_batch=2))
        step_fn = jax.jit(step_lib.make_train_step(cfg, opt_cfg))

        def train(state, until, crash_at=None):
            s = int(state["step"])
            while s < until:
                if crash_at is not None and s == crash_at:
                    raise RuntimeError("simulated host failure")
                batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
                state, _ = step_fn(state, batch)
                s = int(state["step"])
            return state

        init, _ = step_lib.init_state(jax.random.PRNGKey(0), cfg, opt_cfg)

        # uninterrupted reference
        ref = train(init, 6)

        # crashing run under the supervisor
        mgr = CheckpointManager(tmp_path)
        mgr.save(0, init)
        crashed = {"armed": True}

        def restore():
            state, _ = mgr.restore(init)
            return state

        def loop(state):
            s = int(state["step"])
            while s < 6:
                if crashed["armed"] and s == 3:
                    crashed["armed"] = False
                    raise RuntimeError("simulated host failure")
                batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
                state, _ = step_fn(state, batch)
                s = int(state["step"])
                mgr.save(s, state)
            return state

        final, policy = run_supervised(loop, restore,
                                       RestartPolicy(max_restarts=2))
        assert policy.restarts == 1
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), ref, final)

    def test_gives_up_after_max_restarts(self, tmp_path):
        def loop(_):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError, match="persistent"):
            run_supervised(loop, lambda: None, RestartPolicy(max_restarts=2))
