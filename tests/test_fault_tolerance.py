"""Fault tolerance: heartbeats, stragglers, supervised restart resumes
training from the checkpoint with a bitwise-identical data stream."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.fault_tolerance import (Heartbeat, RestartPolicy,
                                        StragglerDetector, run_supervised)
from repro.models import lm
from repro.train import optim, step as step_lib


class TestHeartbeat:
    def test_fleet_and_death(self, tmp_path):
        a = Heartbeat(tmp_path, "host-a", timeout_s=0.2)
        b = Heartbeat(tmp_path, "host-b", timeout_s=0.2)
        a.beat(5)
        b.beat(9)
        assert set(a.fleet()) == {"host-a", "host-b"}
        assert a.dead_hosts() == []
        time.sleep(0.25)
        a.beat(6)
        assert a.dead_hosts() == ["host-b"]

    def test_lagging(self, tmp_path):
        hb = Heartbeat(tmp_path, "h0")
        hb.beat(100)
        Heartbeat(tmp_path, "h1").beat(80)
        assert hb.lagging_hosts(behind_steps=10) == ["h1"]


class TestHeartbeatDeterminism:
    def test_injectable_clock_no_sleeps(self, tmp_path):
        from repro.serve.sla import VirtualClock
        clk = VirtualClock()
        a = Heartbeat(tmp_path, "host-a", timeout_s=10.0, clock=clk)
        b = Heartbeat(tmp_path, "host-b", timeout_s=10.0, clock=clk)
        a.beat(1)
        b.beat(1)
        assert a.dead_hosts() == []
        clk.advance(11.0)
        a.beat(2)
        assert a.dead_hosts() == ["host-b"]
        b.beat(2)
        assert a.dead_hosts() == []

    def test_dotted_hostnames_beat_atomically(self, tmp_path):
        """Hosts named like 'node.0' must write their own heartbeat file
        (the old with_suffix(.tmp) path mangled dotted names) and leave
        no temp files behind."""
        for host in ("node.0", "node.1", "plain"):
            Heartbeat(tmp_path, host).beat(1)
        hb = Heartbeat(tmp_path, "node.0")
        assert hb.fleet() == ["node.0", "node.1", "plain"]
        leftovers = [p.name for p in tmp_path.iterdir()
                     if not p.name.endswith(".heartbeat")]
        assert leftovers == []


class TestStraggler:
    def test_flags_slow_steps(self):
        det = StragglerDetector(threshold=2.0, warmup=3)
        for s in range(10):
            assert not det.observe(s, 1.0)
        assert det.observe(10, 5.0)           # 5x median
        assert det.flagged == [(10, 5.0)]
        assert not det.observe(11, 1.1)       # baseline not poisoned


class TestSupervisedRestart:
    def test_resumes_from_checkpoint_identically(self, tmp_path):
        """Train 6 steps with a crash at step 3; final state must equal an
        uninterrupted 6-step run."""
        cfg = get_config("internlm2-1.8b").reduced(dtype="float32",
                                                   num_layers=2)
        opt_cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
        ds = SyntheticLM(DataConfig(seed=1, vocab_size=cfg.vocab_size,
                                    seq_len=16, global_batch=2))
        step_fn = jax.jit(step_lib.make_train_step(cfg, opt_cfg))

        def train(state, until, crash_at=None):
            s = int(state["step"])
            while s < until:
                if crash_at is not None and s == crash_at:
                    raise RuntimeError("simulated host failure")
                batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
                state, _ = step_fn(state, batch)
                s = int(state["step"])
            return state

        init, _ = step_lib.init_state(jax.random.PRNGKey(0), cfg, opt_cfg)

        # uninterrupted reference
        ref = train(init, 6)

        # crashing run under the supervisor
        mgr = CheckpointManager(tmp_path)
        mgr.save(0, init)
        crashed = {"armed": True}

        def restore():
            state, _ = mgr.restore(init)
            return state

        def loop(state):
            s = int(state["step"])
            while s < 6:
                if crashed["armed"] and s == 3:
                    crashed["armed"] = False
                    raise RuntimeError("simulated host failure")
                batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
                state, _ = step_fn(state, batch)
                s = int(state["step"])
                mgr.save(s, state)
            return state

        final, policy = run_supervised(loop, restore,
                                       RestartPolicy(max_restarts=2))
        assert policy.restarts == 1
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), ref, final)

    def test_gives_up_after_max_restarts(self, tmp_path):
        def loop(_):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError, match="persistent"):
            run_supervised(loop, lambda: None, RestartPolicy(max_restarts=2))


class TestRestartBackoff:
    def test_backoff_applied_on_virtual_clock(self):
        """Regression: backoff_s used to be ignored between restarts.
        Linear backoff — restart k waits k * backoff_s — on the injected
        clock, no wall sleeps."""
        from repro.serve.sla import VirtualClock
        clk = VirtualClock()
        calls = {"n": 0}

        def loop(_):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise RuntimeError("boom")
            return "done"

        out, policy = run_supervised(
            loop, lambda: None,
            RestartPolicy(max_restarts=3, backoff_s=0.5), clock=clk)
        assert out == "done"
        assert policy.restarts == 2
        assert clk() == pytest.approx(0.5 * 1 + 0.5 * 2)

    def test_backoff_sleeps_on_wall_clock(self, monkeypatch):
        slept = []
        monkeypatch.setattr(time, "sleep", slept.append)
        calls = {"n": 0}

        def loop(_):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("boom")
            return "ok"

        out, policy = run_supervised(
            loop, lambda: None, RestartPolicy(max_restarts=1, backoff_s=0.2))
        assert out == "ok"
        assert slept == [pytest.approx(0.2)]

    def test_policy_validation(self):
        with pytest.raises(ValueError, match="backoff_s"):
            RestartPolicy(backoff_s=-1.0)
        with pytest.raises(ValueError, match="max_restarts"):
            RestartPolicy(max_restarts=-1)
        with pytest.raises(ValueError, match="restart="):
            RestartPolicy().backoff(0)
