"""Validate the analytical model against every quantitative claim in the paper.

Each test cites the paper section making the claim. Where the paper's own
arithmetic is internally inconsistent (Table 2 rounding, see DESIGN.md §2.1)
we assert our exact derivation and that the paper's number is within 10%.
"""
import math

import pytest

from repro.core import (BIG_MEMORY, DIE_STACKED, TRADITIONAL, Workload,
                        power_crossover_sla, provision_capacity,
                        provision_performance, provision_power)
from repro.core.systems import GB, TiB

WL = Workload(db_size=16 * TiB, percent_accessed=0.20)


def within(x, ref, tol):
    return abs(x - ref) <= tol * ref


# --------------------------------------------------------------------------
# §1 / Fig. 1 — bandwidth-capacity ratios
# --------------------------------------------------------------------------
class TestBandwidthCapacityRatio:
    def test_die_vs_traditional_80x(self):
        r = DIE_STACKED.bandwidth_capacity_ratio / TRADITIONAL.bandwidth_capacity_ratio
        assert within(r, 80.0, 0.02), r

    def test_die_vs_big_memory_341x(self):
        r = DIE_STACKED.bandwidth_capacity_ratio / BIG_MEMORY.bandwidth_capacity_ratio
        assert within(r, 341.0, 0.02), r

    def test_chip_level_datasheet(self):
        # §3: 102 GB/s and 256 GiB per traditional socket; 192 GB/s big-memory
        assert TRADITIONAL.chip_bandwidth == pytest.approx(102.4 * GB)
        assert TRADITIONAL.chip_capacity == pytest.approx(256 * 2**30)
        assert BIG_MEMORY.chip_bandwidth == pytest.approx(192 * GB)
        assert DIE_STACKED.chip_bandwidth == pytest.approx(256 * GB)
        # Eq. 4: die-stacked chips are *compute*-limited (32 x 6 GB/s < 256 GB/s)
        assert DIE_STACKED.chip_peak_perf == pytest.approx(192 * GB)


# --------------------------------------------------------------------------
# §5.3 / Fig. 5 — capacity provisioning (16 TiB, 20% accessed)
# --------------------------------------------------------------------------
class TestCapacityProvisioning:
    def designs(self):
        return {s.name: provision_capacity(s, WL)
                for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED)}

    def test_cluster_shapes(self):
        d = self.designs()
        assert d["traditional"].compute_chips == 64
        assert d["big-memory"].compute_chips == 8
        assert d["die-stacked"].compute_chips == 2048   # "over 2000 stacks" §7
        assert d["die-stacked"].blades == 228           # Table 2
        assert all(x.holds_workload for x in d.values())

    def test_response_times_intro_claim(self):
        # §1: "big-memory takes over 2 seconds, traditional 500 ms,
        #      die-stacked less than 10 ms"
        d = self.designs()
        assert d["big-memory"].response_time > 2.0
        assert within(d["traditional"].response_time, 0.5, 0.1)
        assert d["die-stacked"].response_time < 0.010

    def test_speedups_256x_and_60x(self):
        # §5.3: die-stacked 256x faster than big-memory, 60x than traditional
        d = self.designs()
        s_big = d["big-memory"].response_time / d["die-stacked"].response_time
        s_trad = d["traditional"].response_time / d["die-stacked"].response_time
        assert within(s_big, 256.0, 0.01), s_big
        assert within(s_trad, 60.0, 0.01), s_trad

    def test_aggregate_bandwidths(self):
        # §5.3: 512 TB/s (die), 6.4 TB/s (trad), 1.5 TB/s (big)
        d = self.designs()
        assert within(d["die-stacked"].aggregate_bandwidth, 512e12, 0.03)
        assert within(d["traditional"].aggregate_bandwidth, 6.4e12, 0.03)
        assert within(d["big-memory"].aggregate_bandwidth, 1.5e12, 0.03)

    def test_power_26_to_50x(self):
        # §5.3: die-stacked uses 26-50x more power
        d = self.designs()
        r_trad = d["die-stacked"].power / d["traditional"].power
        r_big = d["die-stacked"].power / d["big-memory"].power
        assert within(r_trad, 26.0, 0.05), r_trad
        assert within(r_big, 50.0, 0.05), r_big

    def test_energy_die_5x_less_than_big(self):
        # §5.3 / Fig. 6a: about 5x less energy
        d = self.designs()
        r = d["big-memory"].energy_per_query / d["die-stacked"].energy_per_query
        assert within(r, 5.0, 0.1), r

    def test_fig5_larger_corpora_constant_access(self):
        # Fig. 5: 160 TiB and 32 TiB rows keep bytes_accessed = 3.2 TiB
        big = provision_capacity(TRADITIONAL, WL, capacity=160 * TiB)
        assert big.workload.bytes_accessed == pytest.approx(WL.bytes_accessed)
        assert big.compute_chips == 640
        # 10x the machine streaming the same bytes -> 10x faster
        base = provision_capacity(TRADITIONAL, WL)
        assert within(base.response_time / big.response_time, 10.0, 0.02)


# --------------------------------------------------------------------------
# §5.1 / Fig. 3 / Table 2 — performance provisioning
# --------------------------------------------------------------------------
class TestPerformanceProvisioning:
    def test_table2_10ms(self):
        trad = provision_performance(TRADITIONAL, WL, 0.010)
        big = provision_performance(BIG_MEMORY, WL, 0.010)
        die = provision_performance(DIE_STACKED, WL, 0.010)

        # our exact derivations
        assert trad.compute_chips == 3436 and trad.blades == 859
        assert big.compute_chips == 1833 and big.blades == 1833
        assert die.compute_chips == 2048 and die.blades == 228

        # paper's rounded Table 2 within 10% (DESIGN.md §2.1):
        assert within(trad.compute_chips, 3200, 0.10)
        assert within(trad.blades, 800, 0.10)
        assert within(big.compute_chips, 1700, 0.10)
        assert within(die.aggregate_bandwidth, 384e12 * 256 / 192, 0.05)

        # every design actually meets the SLA and holds the data
        for d in (trad, big, die):
            assert d.response_time <= 0.010 * 1.001
            assert d.holds_workload

    def test_overprovisioning_50x_213x(self):
        # §5.1: traditional 50x, big-memory 213x over-provisioned at 10 ms
        trad = provision_performance(TRADITIONAL, WL, 0.010)
        big = provision_performance(BIG_MEMORY, WL, 0.010)
        die = provision_performance(DIE_STACKED, WL, 0.010)
        assert within(trad.overprovision_factor, 50.0, 0.12)
        assert within(big.overprovision_factor, 213.0, 0.10)
        assert die.overprovision_factor <= 1.01   # "not over provisioned at all"

    def test_die_5x_less_power_at_10ms(self):
        # §5.1: "die-stacked uses almost 5x less power" (vs big-memory)
        big = provision_performance(BIG_MEMORY, WL, 0.010)
        die = provision_performance(DIE_STACKED, WL, 0.010)
        assert 3.5 <= big.power / die.power <= 5.5

    def test_relaxed_sla_favors_current_systems(self):
        # §5.1: at 100 ms / 1 s die-stacked uses about the same or more power
        for sla in (0.100, 1.0):
            trad = provision_performance(TRADITIONAL, WL, sla)
            die = provision_performance(DIE_STACKED, WL, sla)
            assert die.power >= 0.95 * trad.power

    def test_crossover_60ms(self):
        t = power_crossover_sla(TRADITIONAL, DIE_STACKED, WL)
        assert t is not None and 0.045 <= t <= 0.075, t

    def test_crossover_170ms_at_50pct(self):
        wl = Workload(db_size=16 * TiB, percent_accessed=0.50)
        t = power_crossover_sla(TRADITIONAL, DIE_STACKED, wl)
        assert t is not None and 0.13 <= t <= 0.21, t

    def test_crossover_800ms_with_8x_density(self):
        # §5.1/§6.1: 8x denser die-stacks move the crossover to ~800 ms.
        # In this regime both systems are capacity-bound and their continuous
        # power curves are *parallel* (constant ~3% gap), so the ceil-induced
        # discrete curves oscillate through zero across [0.5s, ~5s]; the
        # paper's "about 800 ms" is a point in that band. We assert (a) the
        # first crossing falls in the band and (b) at 800 ms the two systems'
        # power is within 5% — i.e. the curves have met by then.
        die8 = DIE_STACKED.with_density(8)
        t = power_crossover_sla(TRADITIONAL, die8, WL)
        assert t is not None and 0.45 <= t <= 1.2, t
        p_trad = provision_performance(TRADITIONAL, WL, 0.800).power
        p_die = provision_performance(die8, WL, 0.800).power
        assert abs(p_trad - p_die) / p_trad < 0.05
        # and well before the band the die-stacked system is strictly cheaper
        assert provision_performance(die8, WL, 0.100).power < \
            provision_performance(TRADITIONAL, WL, 0.100).power

    def test_denser_memory_never_helps_performance(self):
        # §6.1: "increasing density does not directly affect performance"
        for s in (TRADITIONAL, DIE_STACKED):
            a = provision_capacity(s, WL)
            b = provision_capacity(s.with_density(8), WL)
            assert b.response_time >= a.response_time  # fewer chips => slower or equal


# --------------------------------------------------------------------------
# §5.2 / Fig. 4 — power provisioning
# --------------------------------------------------------------------------
class TestPowerProvisioning:
    def test_1mw_all_meet_10ms(self):
        for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
            d = provision_power(s, WL, 1e6)
            assert d.response_time <= 0.011, (s.name, d.response_time)
            assert d.holds_workload

    def test_1mw_traditional_blades_over_1300(self):
        d = provision_power(TRADITIONAL, WL, 1e6)
        assert 1300 <= d.blades <= 1400, d.blades

    def test_1mw_die_5x_faster_than_big(self):
        die = provision_power(DIE_STACKED, WL, 1e6)
        big = provision_power(BIG_MEMORY, WL, 1e6)
        assert within(big.response_time / die.response_time, 5.0, 0.1)

    def test_50kw_die_is_slowest_with_1_core(self):
        # §5.2: strict budgets invert the ranking; die-stacked runs 1 core/chip
        die = provision_power(DIE_STACKED, WL, 50e3)
        trad = provision_power(TRADITIONAL, WL, 50e3)
        big = provision_power(BIG_MEMORY, WL, 50e3)
        assert die.cores_per_chip == 1
        assert die.response_time > trad.response_time
        assert die.response_time > big.response_time
        for d in (die, trad, big):
            assert d.power <= 50e3 * 1.001
            assert d.holds_workload

    def test_budget_is_respected(self):
        for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
            for budget in (60e3, 250e3, 1e6):
                d = provision_power(s, WL, budget)
                assert d.power <= budget * 1.001, (s.name, budget, d.power)

    def test_big_memory_has_most_capacity_at_fixed_power(self):
        # §1 finding: "the big-memory system provides the most memory capacity"
        caps = {s.name: provision_power(s, WL, 1e6).memory_capacity
                for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED)}
        assert caps["big-memory"] == max(caps.values())


# --------------------------------------------------------------------------
# §6.1 — improvement levers
# --------------------------------------------------------------------------
class TestImprovementLevers:
    def test_10x_lower_compute_power_helps_die_stacked(self):
        die10 = DIE_STACKED.with_compute_power(0.1)
        base = provision_capacity(DIE_STACKED, WL)
        better = provision_capacity(die10, WL)
        assert better.power < base.power
        assert better.response_time == base.response_time  # perf unchanged
        assert better.energy_per_query < base.energy_per_query

    def test_density_cuts_power_for_all(self):
        for s in (TRADITIONAL, BIG_MEMORY, DIE_STACKED):
            a = provision_capacity(s, WL)
            b = provision_capacity(s.with_density(8), WL)
            assert b.power < a.power
