"""Kernel-vs-model parity: the Pallas paths plugged into the LM must match
the pure-jnp model paths bit-for-tolerance (attn_impl='flash')."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.common import dtype_of

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ["internlm2-1.8b", "mixtral-8x22b"])
def test_decode_kernel_matches_naive_in_model(arch_id):
    """Full-stack decode with attn_impl='flash' (split-K Pallas kernel in
    interpret mode) vs the naive cached path."""
    cfg = get_config(arch_id).reduced(dtype="float32", num_layers=2,
                                      head_dim=64)
    params, _ = lm.init(KEY, cfg)
    B, S = 2, 64
    inputs = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    def run(cfg_run):
        caches, _ = lm.init_caches(cfg_run, B, S, dtype_of(cfg_run.dtype))
        _, caches, _ = lm.prefill(params, cfg_run, inputs[:, :S // 2], caches)
        outs = []
        for t in range(S // 2, S // 2 + 4):
            lens = jnp.full((B,), t, jnp.int32)
            logits, caches, _ = lm.decode_step(params, cfg_run,
                                               inputs[:, t:t + 1], lens,
                                               caches)
            outs.append(logits[:, 0])
        return jnp.stack(outs)

    naive = run(dataclasses.replace(cfg, attn_impl="naive"))
    kernel = run(dataclasses.replace(cfg, attn_impl="flash"))
    np.testing.assert_allclose(np.asarray(naive), np.asarray(kernel),
                               rtol=2e-4, atol=2e-4)


def test_prefill_flash_kernel_matches_blockwise_in_model():
    """Train/prefill path with the flash kernel (arange positions) vs the
    blockwise jnp path."""
    cfg = get_config("internlm2-1.8b").reduced(dtype="float32", num_layers=2,
                                               head_dim=64)
    params, _ = lm.init(KEY, cfg)
    B, S = 1, 128
    inputs = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    a, _, _ = lm.prefill(params, dataclasses.replace(cfg, attn_impl="blockwise"),
                         inputs, caches=None)
    b, _, _ = lm.prefill(params, dataclasses.replace(cfg, attn_impl="flash"),
                         inputs, caches=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
